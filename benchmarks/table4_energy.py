"""Table 4 reproduction: latency-optimal vs energy-optimal plans, and
energy-optimal at reduced GPU frequency (0.8 GHz) — energy, TTFT, TPOT."""

from __future__ import annotations

from repro.core import ApexSearch, get_trace, h100_node

from .common import Timer, csv_row, model_ir


def run(num_requests: int = 64, quick: bool = False):
    cluster = h100_node(8)
    model = model_ir("llama-3.1-70b")
    rows = []
    traces = [("summarization", 3.0)] if quick else \
        [("summarization", 3.0), ("creation", 6.0)]
    for trace, rate in traces:
        reqs = get_trace(trace, arrival_rate=rate,
                         num_requests=num_requests)
        variants = {}
        with Timer() as t:
            s_full = ApexSearch(model, cluster)
            variants["latency_opt_2.0GHz"] = s_full.search(
                reqs, objective="latency").best
            variants["energy_opt_2.0GHz"] = s_full.search(
                reqs, objective="energy").best
            s_slow = ApexSearch(model, cluster, freq_ghz=0.8)
            variants["energy_opt_0.8GHz"] = s_slow.search(
                reqs, objective="energy").best
        base_e = variants["latency_opt_2.0GHz"].total_energy
        for vname, rep in variants.items():
            rows.append(dict(trace=trace, variant=vname,
                             energy_kj=rep.total_energy / 1e3,
                             ttft_ms=rep.ttft_mean * 1e3,
                             tpot_ms=rep.tpot_mean * 1e3,
                             savings=1 - rep.total_energy / base_e))
            csv_row(f"table4/{trace}/{vname}", t.seconds * 1e6 / 3,
                    f"energy={rep.total_energy / 1e3:.2f}kJ "
                    f"save={1 - rep.total_energy / base_e:+.0%} "
                    f"TTFT={rep.ttft_mean * 1e3:.0f}ms "
                    f"TPOT={rep.tpot_mean * 1e3:.1f}ms")
    return rows


if __name__ == "__main__":
    run()
