"""Fig. 9 reproduction — max-batch-size sweeps for SLO tuning: TPOT
improves as the cap shrinks until over-restriction degrades end-to-end
latency."""

from __future__ import annotations

from repro.core import ApexSearch, BatchingPolicy, get_trace, h100_node

from .common import csv_row, model_ir

CAPS = (4, 8, 16, 32, None)


def run(quick: bool = False):
    rows = []
    models = ["llama-3.1-70b"] if quick else ["llama-3.1-70b",
                                              "mistral-large-123b"]
    cluster = h100_node(8)
    reqs = get_trace("creation", arrival_rate=6.0, num_requests=64)
    for name in models:
        model = model_ir(name)
        search = ApexSearch(model, cluster)
        for cap in (CAPS[:3] if quick else CAPS):
            rep = search.evaluate_baseline(
                reqs, policy=BatchingPolicy(max_batch_size=cap))
            rows.append(dict(model=name, cap=cap,
                             tpot_ms=rep.tpot_mean * 1e3,
                             e2e_s=rep.e2e_latency))
            csv_row(f"fig9/{name}/cap{cap or 'inf'}",
                    rep.tpot_mean * 1e6,
                    f"TPOT={rep.tpot_mean * 1e3:.2f}ms "
                    f"e2e={rep.e2e_latency:.0f}s")
    return rows


if __name__ == "__main__":
    run()
