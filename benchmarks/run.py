"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` runs the
complete settings (the EXPERIMENTS.md numbers); default is the quick
variant for CI-style validation.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (table2,fig6,...)")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from . import (disagg_frontier, fig6_fidelity, fig7_scaling,
                   fig8_scaling, fig9_slo, roofline, table2_plan_search,
                   table3_clusters, table4_energy, table5_extensibility)

    benches = {
        "disagg": lambda: disagg_frontier.run(quick=quick),
        "table2": lambda: table2_plan_search.run(quick=quick),
        "table3": lambda: table3_clusters.run(quick=quick),
        "table4": lambda: table4_energy.run(quick=quick),
        "table5": lambda: table5_extensibility.run(quick=quick),
        "fig6": lambda: fig6_fidelity.run(quick=quick),
        "fig7": lambda: fig7_scaling.run(quick=quick),
        "fig8": lambda: fig8_scaling.run(quick=quick),
        "fig9": lambda: fig9_slo.run(quick=quick),
        "roofline": lambda: roofline.run(quick=quick),
    }
    only = set(args.only.split(",")) if args.only else None
    failures = 0
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            fn()
        except Exception:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"{name},0,FAILED", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
