"""SLO-goodput benchmark: what does optimizing the right objective buy?

Runs a mixed two-tenant trace (latency-sensitive chat + batchy
summarization, each with its own SLO class) through the exact plan
search twice — once ranking by ``goodput`` (requests meeting their
class SLO per second), once by plain ``latency`` — and reports what the
latency-optimal plan gives up in SLO attainment.  Also times the
multi-fidelity goodput search (fluid screen + exact confirm) and checks
the exact goodput winner survived the fluid screen.

Writes ``BENCH_goodput.json`` next to the repo root (companion of
``BENCH_core.json``/``BENCH_search.json``):

    PYTHONPATH=src python benchmarks/bench_goodput.py [--smoke] [--jobs N]
                                                      [--out PATH]

``--smoke`` shrinks the model/cluster for CI (seconds, not minutes).
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

from repro.core import (ApexSearch, MultiFidelitySearch, SLOClass,
                        h100_node, ir_from_hf_config, mixed_trace)

SMOKE_CFG = dict(hidden_size=256, num_hidden_layers=4,
                 num_attention_heads=8, num_key_value_heads=4,
                 intermediate_size=1024, vocab_size=1024)
FULL_CFG = dict(hidden_size=2048, num_hidden_layers=16,
                num_attention_heads=16, num_key_value_heads=8,
                intermediate_size=8192, vocab_size=32000)


def build(smoke: bool):
    if smoke:
        model = ir_from_hf_config(SMOKE_CFG, name="tiny")
        cluster = h100_node(4)
        chat = SLOClass("chat", priority=1, ttft_target_s=0.005,
                        tpot_target_s=3e-4)
        summ = SLOClass("summarization", priority=0, ttft_target_s=0.03)
        n_chat, n_summ, rate = 48, 16, 4.0
    else:
        model = ir_from_hf_config(FULL_CFG, name="tiny-7b")
        cluster = h100_node(8)
        chat = SLOClass("chat", priority=1, ttft_target_s=4e-3,
                        tpot_target_s=1.4e-3)
        summ = SLOClass("summarization", priority=0, ttft_target_s=8e-3)
        n_chat, n_summ, rate = 96, 32, 48.0
    search = ApexSearch(model, cluster)
    reqs = mixed_trace([("chat", rate, chat, n_chat),
                        ("summarization", rate / 4, summ, n_summ)], seed=7)
    return search, reqs


def report_row(rep):
    return {
        "plan": rep.plan_label,
        "goodput_rps": round(rep.goodput_rps, 3),
        "ttft_p95_ms": round(rep.ttft_p95 * 1e3, 2),
        "tpot_p95_ms": round(rep.tpot_p95 * 1e3, 3),
        "classes": [{
            "name": c.name,
            "slo_met": c.slo_met,
            "n": c.num_requests,
            "ttft_p95_ms": round(c.ttft_p95 * 1e3, 2),
            "goodput_rps": round(c.goodput_rps, 3),
        } for c in rep.class_reports or ()],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizing for CI (seconds, not minutes)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="forked workers for the exact sweeps")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args()

    search, reqs = build(args.smoke)

    sweeps = {}
    for objective in ("goodput", "latency"):
        t0 = time.perf_counter()
        res = search.search(reqs, objective=objective, jobs=args.jobs)
        sweeps[objective] = (res, round(time.perf_counter() - t0, 3))

    goodput_best = sweeps["goodput"][0].best
    latency_best = sweeps["latency"][0].best

    t0 = time.perf_counter()
    mres = MultiFidelitySearch(search).search(reqs, objective="goodput",
                                              jobs=args.jobs)
    mf_seconds = round(time.perf_counter() - t0, 3)
    survived = {mres.surrogate_reports[i].plan_label
                for i in mres.survivor_indices}

    out = {
        "bench": "bench_goodput",
        "smoke": args.smoke,
        "jobs": args.jobs,
        "n_requests": len(reqs),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "num_candidates": sweeps["goodput"][0].num_schemes,
        # spread of SLO-goodput across feasible plans: shows how much
        # the plan choice moves attainment on this trace
        "goodput_rps_min_max": [
            round(min(r.goodput_rps for r in
                      sweeps["goodput"][0].all_reports if r.feasible), 3),
            round(max(r.goodput_rps for r in
                      sweeps["goodput"][0].all_reports if r.feasible), 3)],
        "goodput_optimal": report_row(goodput_best),
        "latency_optimal": report_row(latency_best),
        "goodput_gain_rps": round(
            goodput_best.goodput_rps - latency_best.goodput_rps, 3),
        "exact_seconds": {obj: s for obj, (_, s) in sweeps.items()},
        "multifid": {
            "total_seconds": mf_seconds,
            "screen_seconds": round(mres.screen_seconds, 3),
            "confirm_seconds": round(mres.confirm_seconds, 3),
            "num_survivors": mres.num_survivors,
            "best": mres.best.plan_label,
            "exact_winner_survived":
                goodput_best.plan_label in survived,
        },
    }
    path = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_goodput.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)

    for tag in ("goodput_optimal", "latency_optimal"):
        row = out[tag]
        cls = ", ".join(f"{c['name']}: {c['slo_met']}/{c['n']}"
                        for c in row["classes"])
        print(f"{tag}: {row['plan']} -> {row['goodput_rps']} req/s "
              f"({cls})")
    print(f"goodput gain over latency-optimal: "
          f"{out['goodput_gain_rps']} req/s")
    m = out["multifid"]
    print(f"multifid[goodput]: {out['num_candidates']} -> "
          f"{m['num_survivors']} survivors in {m['total_seconds']}s, "
          f"winner survived={m['exact_winner_survived']}")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
