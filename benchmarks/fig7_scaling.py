"""Fig. 7 reproduction — TPOT scalability trend across device counts.

The paper plots predicted vs actual TPOT for TP over 2/4/8 GPUs (two
y-axes; the TREND is the fidelity claim).  Here the simulator predicts
TPOT for TP degrees on the modeled H100 node; the 'actual' counterpart is
the REAL sharded serve_step wall-time measured on 2/4/8 forced host
devices (subprocess), normalized at the smallest degree — same
two-axis trend comparison.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.core import (ApexSearch, BatchingPolicy, get_trace, h100_node)
from repro.core.planner import generate_schemes

from .common import csv_row, model_ir

_MEASURE = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(n)d"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs as C
from repro.models import transformer as T
from repro.parallel.sharding import param_pspecs, cache_pspecs

cfg = C.get_reduced("internlm2_1_8b")
mesh = jax.make_mesh((1, %(n)d), ("data", "model"))
params = T.init_params(jax.random.PRNGKey(0), cfg)
cache = T.init_cache(cfg, 4, 128)
sh = lambda t, specs: jax.device_put(t, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs,
    is_leaf=lambda s: isinstance(s, P)))
from repro.launch.mesh import mesh_context
with mesh_context(mesh):
    ps = sh(params, param_pspecs(params, cfg, mesh))
    cs = sh(cache, cache_pspecs(cache, cfg, mesh))
    toks = jnp.ones((4, 1), jnp.int32)
    step = jax.jit(lambda p, t, c: T.decode_step(p, cfg, t, c))
    logits, cs2 = step(ps, toks, cs)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    N = 20
    for _ in range(N):
        logits, cs = step(ps, toks, cs)
    jax.block_until_ready(logits)
    print(json.dumps({"tpot_s": (time.perf_counter() - t0) / N}))
"""


def _measure(n: int) -> float:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, "-c", _MEASURE % {"n": n}],
                         env=env, capture_output=True, text=True,
                         timeout=420)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])["tpot_s"]


def run(quick: bool = False):
    degrees = (2, 4) if quick else (2, 4, 8)
    model = model_ir("qwen2.5-32b")   # fits TP=2 at fp16 w/ KV room
    cluster = h100_node(8)
    reqs = get_trace("chat", arrival_rate=4.0, num_requests=32)
    search = ApexSearch(model, cluster)

    predicted = {}
    for tp in degrees:
        scheme = [s for s in generate_schemes(model, tp,
                                              allow_cell_dp=False)
                  if s.model_dp == 1 and s.pp_stages == 1
                  and s.stage_devices == tp][0]
        rep = search.evaluate(scheme, reqs)
        if not rep.feasible or rep.tpot_mean <= 0:
            raise RuntimeError(f"TP={tp} plan infeasible for this model")
        predicted[tp] = rep.tpot_mean

    measured = {tp: _measure(tp) for tp in degrees}
    base = degrees[0]
    rows = []
    for tp in degrees:
        p_rel = predicted[tp] / predicted[base]
        m_rel = measured[tp] / measured[base]
        rows.append(dict(tp=tp, predicted_ms=predicted[tp] * 1e3,
                         measured_ms=measured[tp] * 1e3,
                         predicted_rel=p_rel, measured_rel=m_rel))
        csv_row(f"fig7/tp{tp}", measured[tp] * 1e6,
                f"pred_tpot={predicted[tp] * 1e3:.1f}ms "
                f"pred_rel={p_rel:.2f} meas_rel={m_rel:.2f}")
    return rows


if __name__ == "__main__":
    run()
