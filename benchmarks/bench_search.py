"""Multi-fidelity search benchmark: surrogate screening throughput and
end-to-end wall time on a 1000+-candidate joint search.

Times three things and writes ``BENCH_search.json`` next to the repo
root (the companion of ``BENCH_core.json``):

  * surrogate plans/s — fluid-ODE screening rate over the full
    candidate set (colocated + shared-cluster disagg + heterogeneous
    pool-menu disagg),
  * exact plans/s — event-engine rate on a spread sample of the same
    candidates, giving the screening speedup ratio,
  * multifid seconds — full ``MultiFidelitySearch.search`` wall time
    (screen everything, exact-confirm the survivor frontier) for the
    latency and throughput objectives.

    PYTHONPATH=src python benchmarks/bench_search.py [--smoke] [--verify]
                                                     [--jobs N] [--out PATH]

``--smoke`` shrinks the workload for CI; ``--verify`` additionally runs
the FULL exact search (minutes) and checks the exact winner survived the
surrogate frontier for both objectives.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

from repro.core import (ApexSearch, MultiFidelitySearch, get_trace,
                        h100_node, h200_node, ir_from_hf_config)
from repro.core.cluster import h100_multinode

MODEL_CFG = dict(hidden_size=2048, num_hidden_layers=16,
                 num_attention_heads=16, num_key_value_heads=8,
                 intermediate_size=8192, vocab_size=32000)


def build(smoke: bool):
    model = ir_from_hf_config(MODEL_CFG, name="tiny-7b")
    if smoke:
        cluster = h100_node(8)
        search_kw = dict(disaggregated=True, max_disagg_plans=12)
        n_req = 16
    else:
        cluster = h100_multinode(2, 8)
        search_kw = dict(
            disaggregated=True, max_disagg_plans=1600,
            pool_menu=[h100_node(8), h200_node(8),
                       h100_node(4), h200_node(4)])
        n_req = 56
    search = ApexSearch(model, cluster)
    # loaded trace: at light load most plans tie at the arrival span and
    # the tie-aware frontier (correctly) refuses to prune — the bench
    # regime is the one where the surrogate has ranking signal
    reqs = get_trace("chat", arrival_rate=32.0, seed=0,
                     num_requests=n_req)
    return search, reqs, search_kw


def bench_rates(search, reqs, search_kw, exact_sample: int):
    """Surrogate plans/s over ALL candidates vs exact plans/s on a
    spread sample (the full exact sweep is what multifid avoids)."""
    from repro.core.fluid import TraceSummary
    cands, kv = search.candidates(**search_kw)
    ts = TraceSummary.of(reqs)
    t0 = time.perf_counter()
    for c in cands:
        _, sim = search.make_simulator(c, kv, fluid=True)
        sim.simulate(reqs, summary=ts)
    t_screen = time.perf_counter() - t0
    sur_pps = len(cands) / t_screen

    idx = list(range(0, len(cands), max(1, len(cands) // exact_sample)))
    idx = idx[:exact_sample]
    t0 = time.perf_counter()
    for i in idx:
        _, sim = search.make_simulator(cands[i], kv)
        sim.simulate(reqs)
    t_exact = time.perf_counter() - t0
    exact_pps = len(idx) / t_exact
    return {
        "num_candidates": len(cands),
        "surrogate_seconds": round(t_screen, 3),
        "surrogate_plans_per_sec": round(sur_pps, 1),
        "exact_sample": len(idx),
        "exact_plans_per_sec": round(exact_pps, 2),
        "speedup_ratio": round(sur_pps / exact_pps, 1),
    }


def bench_multifid(search, reqs, search_kw, objective: str, jobs: int):
    mf = MultiFidelitySearch(search)
    t0 = time.perf_counter()
    res = mf.search(reqs, objective=objective, jobs=jobs, **search_kw)
    dt = time.perf_counter() - t0
    return res, {
        "objective": objective,
        "num_candidates": res.num_candidates,
        "num_survivors": res.num_survivors,
        "screen_seconds": round(res.screen_seconds, 3),
        "confirm_seconds": round(res.confirm_seconds, 3),
        "total_seconds": round(dt, 3),
        "best": res.best.plan_label,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizing for CI (seconds, not minutes)")
    ap.add_argument("--verify", action="store_true",
                    help="also run the full exact search and check the "
                         "exact winner survived the surrogate frontier")
    ap.add_argument("--jobs", type=int, default=1,
                    help="forked workers for exact confirmation")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args()

    search, reqs, search_kw = build(args.smoke)
    rates = bench_rates(search, reqs, search_kw,
                        exact_sample=4 if args.smoke else 8)
    searches = {}
    mf_results = {}
    for objective in ("latency", "throughput"):
        res, row = bench_multifid(search, reqs, search_kw, objective,
                                  args.jobs)
        searches[objective] = row
        mf_results[objective] = res

    verify = None
    if args.verify:
        verify = {}
        for objective in ("latency", "throughput"):
            exact = search.search(reqs, objective=objective,
                                  jobs=args.jobs, **search_kw)
            mres = mf_results[objective]
            survived = {mres.surrogate_reports[i].plan_label
                        for i in mres.survivor_indices}
            verify[objective] = {
                "exact_best": exact.best.plan_label,
                "exact_seconds": round(exact.search_seconds, 3),
                "winner_survived": exact.best.plan_label in survived,
            }

    out = {
        "bench": "bench_search",
        "smoke": args.smoke,
        "jobs": args.jobs,
        "n_requests": len(reqs),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "rates": rates,
        "multifid": searches,
        "verify": verify,
    }
    path = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_search.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)

    r = rates
    print(f"candidates: {r['num_candidates']}")
    print(f"surrogate: {r['surrogate_plans_per_sec']} plans/s, "
          f"exact: {r['exact_plans_per_sec']} plans/s "
          f"-> {r['speedup_ratio']}x")
    for objective, row in searches.items():
        print(f"multifid[{objective}]: {row['num_candidates']} -> "
              f"{row['num_survivors']} survivors in "
              f"{row['total_seconds']}s (best {row['best']})")
    if verify:
        for objective, v in verify.items():
            print(f"verify[{objective}]: exact best in "
                  f"{v['exact_seconds']}s, survived="
                  f"{v['winner_survived']}")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
