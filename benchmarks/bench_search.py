"""Multi-fidelity search benchmark: surrogate screening throughput and
end-to-end wall time on a 1000+-candidate joint search.

Times three things and writes ``BENCH_search.json`` next to the repo
root (the companion of ``BENCH_core.json``):

  * surrogate plans/s — fluid-ODE screening rate over the full
    candidate set (colocated + shared-cluster disagg + heterogeneous
    pool-menu disagg),
  * exact plans/s — event-engine rate on a spread sample of the same
    candidates with private caches, giving the screening speedup ratio,
  * multifid seconds — full ``MultiFidelitySearch.search`` wall time
    (screen everything, successive-halving rungs on trace prefixes,
    exact-confirm the finalists) for the latency and throughput
    objectives, with per-rung survivor counts and rung seconds, global
    shared-store hit rates, and a no-halving baseline for comparison.

    PYTHONPATH=src python benchmarks/bench_search.py [--smoke] [--verify]
        [--jobs N] [--no-halving] [--profile] [--out PATH]

``--smoke`` shrinks the workload for CI and asserts the halving path
picks the same best plan as the no-halving path; ``--verify``
additionally runs the FULL exact search (minutes) and checks the exact
winner survived screening AND every halving rung for both objectives;
``--profile`` wraps the benchmark in cProfile and prints the top-20
cumulative functions.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

from repro.core import (ApexSearch, MultiFidelitySearch, get_trace,
                        h100_node, h200_node, ir_from_hf_config)
from repro.core.cluster import h100_multinode

MODEL_CFG = dict(hidden_size=2048, num_hidden_layers=16,
                 num_attention_heads=16, num_key_value_heads=8,
                 intermediate_size=8192, vocab_size=32000)


def build(smoke: bool):
    model = ir_from_hf_config(MODEL_CFG, name="tiny-7b")
    if smoke:
        cluster = h100_node(8)
        search_kw = dict(disaggregated=True, max_disagg_plans=12)
        n_req = 16
    else:
        cluster = h100_multinode(2, 8)
        search_kw = dict(
            disaggregated=True, max_disagg_plans=1600,
            pool_menu=[h100_node(8), h200_node(8),
                       h100_node(4), h200_node(4)])
        n_req = 56
    # loaded trace: at light load most plans tie at the arrival span and
    # the tie-aware frontier (correctly) refuses to prune — the bench
    # regime is the one where the surrogate has ranking signal
    reqs = get_trace("chat", arrival_rate=32.0, seed=0,
                     num_requests=n_req)

    def make_search(**kw):
        # a FRESH context per timed run: the shared step-cost store
        # persists across search() calls, so reusing one context would
        # flatter later runs with earlier runs' entries
        return ApexSearch(model, cluster, **kw)

    return make_search, reqs, search_kw


def bench_rates(make_search, reqs, search_kw, exact_sample: int):
    """Surrogate plans/s over ALL candidates vs exact plans/s on a
    spread sample (the full exact sweep is what multifid avoids).  Both
    run with private per-simulator caches so the rates stay comparable
    across benchmark revisions."""
    from repro.core.fluid import TraceSummary
    search = make_search(share_step_costs=False)
    cands, kv = search.candidates(**search_kw)
    ts = TraceSummary.of(reqs)
    t0 = time.perf_counter()
    for c in cands:
        _, sim = search.make_simulator(c, kv, fluid=True)
        sim.simulate(reqs, summary=ts)
    t_screen = time.perf_counter() - t0
    sur_pps = len(cands) / t_screen

    idx = list(range(0, len(cands), max(1, len(cands) // exact_sample)))
    idx = idx[:exact_sample]
    t0 = time.perf_counter()
    for i in idx:
        _, sim = search.make_simulator(cands[i], kv)
        sim.simulate(reqs)
    t_exact = time.perf_counter() - t0
    exact_pps = len(idx) / t_exact
    return {
        "num_candidates": len(cands),
        "surrogate_seconds": round(t_screen, 3),
        "surrogate_plans_per_sec": round(sur_pps, 1),
        "exact_sample": len(idx),
        "exact_plans_per_sec": round(exact_pps, 2),
        "speedup_ratio": round(sur_pps / exact_pps, 1),
    }


def bench_multifid(make_search, reqs, search_kw, objective: str,
                   jobs: int, halving: bool):
    search = make_search()
    mf = MultiFidelitySearch(search)
    t0 = time.perf_counter()
    res = mf.search(reqs, objective=objective, jobs=jobs,
                    halving=halving, **search_kw)
    dt = time.perf_counter() - t0
    traffic = res.result.cache_hits + res.result.cache_misses
    row = {
        "objective": objective,
        "halving": halving,
        "num_candidates": res.num_candidates,
        "screen_survivors": res.screen_survivors,
        "num_finalists": res.num_survivors,
        "screen_seconds": round(res.screen_seconds, 3),
        "confirm_seconds": round(res.confirm_seconds, 3),
        "total_seconds": round(dt, 3),
        "rungs": [{
            "fraction": r.fraction,
            "n_requests": r.n_requests,
            "evaluated": r.evaluated,
            "promoted": r.promoted,
            "seconds": round(r.seconds, 3),
            "cache_hits": r.cache_hits,
            "cache_misses": r.cache_misses,
        } for r in res.rungs],
        "cache_hits": res.result.cache_hits,
        "cache_misses": res.result.cache_misses,
        "cache_hit_rate": round(res.result.cache_hits / traffic, 3)
        if traffic else 0.0,
        "cost_store": search.cost_store.stats()
        if search.cost_store is not None else None,
        "best": res.best.plan_label,
    }
    return res, row


def run_benchmark(args):
    make_search, reqs, search_kw = build(args.smoke)
    rates = bench_rates(make_search, reqs, search_kw,
                        exact_sample=4 if args.smoke else 8)
    searches = {}
    baselines = {}
    mf_results = {}
    for objective in ("latency", "throughput"):
        res, row = bench_multifid(make_search, reqs, search_kw, objective,
                                  args.jobs, halving=not args.no_halving)
        searches[objective] = row
        mf_results[objective] = res
        if not args.no_halving:
            # no-halving baseline: every screening survivor pays the
            # full trace (the PR 4 confirm path), for the ladder-vs-
            # cliff comparison recorded below
            _, base_row = bench_multifid(make_search, reqs, search_kw,
                                         objective, args.jobs,
                                         halving=False)
            baselines[objective] = base_row
            if args.smoke:
                assert row["best"] == base_row["best"], (
                    f"[{objective}] halving best {row['best']!r} != "
                    f"no-halving best {base_row['best']!r}")

    verify = None
    if args.verify:
        verify = {}
        for objective in ("latency", "throughput"):
            exact = make_search().search(reqs, objective=objective,
                                         jobs=args.jobs, **search_kw)
            mres = mf_results[objective]
            label = exact.best.plan_label
            survived = {mres.surrogate_reports[i].plan_label
                        for i in mres.survivor_indices}
            rungs_ok = all(
                label in {mres.surrogate_reports[i].plan_label
                          for i in r.survivor_indices}
                for r in mres.rungs)
            verify[objective] = {
                "exact_best": label,
                "exact_seconds": round(exact.search_seconds, 3),
                "winner_survived": label in survived and rungs_ok,
                "winner_survived_every_rung": rungs_ok,
            }

    return {
        "bench": "bench_search",
        "smoke": args.smoke,
        "jobs": args.jobs,
        "halving": not args.no_halving,
        "n_requests": len(reqs),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "rates": rates,
        "multifid": searches,
        "multifid_no_halving": baselines or None,
        "verify": verify,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizing for CI (seconds, not minutes); "
                         "asserts halving and no-halving agree on the "
                         "best plan")
    ap.add_argument("--verify", action="store_true",
                    help="also run the full exact search and check the "
                         "exact winner survived screening and every "
                         "halving rung")
    ap.add_argument("--jobs", type=int, default=1,
                    help="forked workers for exact confirmation")
    ap.add_argument("--no-halving", action="store_true",
                    help="disable successive halving (PR 4 behavior: "
                         "every screening survivor runs the full trace)")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the run in cProfile and print the top-20 "
                         "cumulative functions")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args()

    if args.profile:
        import cProfile
        import pstats
        prof = cProfile.Profile()
        prof.enable()
        out = run_benchmark(args)
        prof.disable()
        pstats.Stats(prof).sort_stats("cumulative").print_stats(20)
    else:
        out = run_benchmark(args)

    path = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_search.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)

    r = out["rates"]
    print(f"candidates: {r['num_candidates']}")
    print(f"surrogate: {r['surrogate_plans_per_sec']} plans/s, "
          f"exact: {r['exact_plans_per_sec']} plans/s "
          f"-> {r['speedup_ratio']}x")
    for objective, row in out["multifid"].items():
        ladder = " -> ".join(
            [str(row["screen_survivors"])]
            + [f"{rg['promoted']}@{rg['fraction']:.0%}"
               for rg in row["rungs"]])
        print(f"multifid[{objective}]: {row['num_candidates']} cands, "
              f"ladder {ladder}, confirm {row['confirm_seconds']}s, "
              f"total {row['total_seconds']}s, "
              f"hit rate {row['cache_hit_rate']:.0%} (best {row['best']})")
        base = (out.get("multifid_no_halving") or {}).get(objective)
        if base:
            speedup = (base["confirm_seconds"] / row["confirm_seconds"]
                       if row["confirm_seconds"] > 0 else float("inf"))
            print(f"  no-halving baseline: confirm "
                  f"{base['confirm_seconds']}s -> {speedup:.1f}x ladder "
                  f"speedup (same best: "
                  f"{base['best'] == row['best']})")
    if out["verify"]:
        for objective, v in out["verify"].items():
            print(f"verify[{objective}]: exact best in "
                  f"{v['exact_seconds']}s, survived="
                  f"{v['winner_survived']} "
                  f"(every rung: {v['winner_survived_every_rung']})")
    print(f"wrote {out and path}")


if __name__ == "__main__":
    main()
