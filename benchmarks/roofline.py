"""§Roofline analysis: three-term roofline per (arch x shape) on the
single-pod mesh, derived from the compiled dry-run artifacts.

Terms (TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI):
    compute    = per-device dot FLOPs (loop-corrected HLO) / peak
    memory     = per-device HBM traffic / bandwidth, where traffic =
                 argument bytes (params+opt+cache, each read >= once per
                 step) + analytic activation workspace
    collective = per-device collective bytes (loop-corrected HLO) / link bw

MODEL_FLOPS = 6*N*D (train, dense), 6*N_active*D (MoE), 2*N*D (prefill),
2*N_active*B (decode, per step); the MODEL/HLO ratio exposes redundant
compute (dense MoE dispatch, replicated attention, remat).
"""

from __future__ import annotations

import json
import os

from repro import configs as C
from repro.launch.shapes import SHAPES

from .common import csv_row

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def model_flops(arch: str, shape) -> float:
    cfg = C.get_config(arch)
    ir = cfg.to_ir()
    n_total = ir.total_params()
    # active params per token (MoE discount)
    if cfg.ffn_kind == "moe":
        from repro.core.ir import MoECell
        moe_total = sum(c.weight_params() for c in ir.block.cells
                        if isinstance(c, MoECell)) * ir.block.repeat
        active_frac = (cfg.top_k + cfg.n_shared) / max(
            cfg.n_routed + cfg.n_shared, 1)
        n_active = n_total - moe_total * (1 - active_frac)
    else:
        n_active = n_total
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * B * S
    if shape.kind == "prefill":
        return 2.0 * n_active * B * S
    return 2.0 * n_active * B              # decode: one token per sequence


def analyze(dryrun_path: str = "results/dryrun.json",
            mesh: str = "16x16", quick: bool = False):
    if not os.path.exists(dryrun_path):
        print(f"(roofline: {dryrun_path} missing — run "
              "python -m repro.launch.dryrun first)")
        return []
    recs = [r for r in json.load(open(dryrun_path))
            if r.get("status") == "ok" and r["mesh"] == mesh]
    rows = []
    for r in recs:
        shape = SHAPES[r["shape"]]
        n_dev = r["devices"]
        t_c = r["dot_flops"] / PEAK
        traffic = (r["memory"]["argument_size_in_bytes"]
                   + r.get("workspace_model", 0))
        t_m = traffic / HBM
        coll = sum(r["collective_bytes"].values())
        t_x = coll / ICI
        dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
        mf = model_flops(r["arch"], shape)
        hlo_total = r["dot_flops"] * n_dev
        ratio = mf / hlo_total if hlo_total else 0.0
        rows.append(dict(arch=r["arch"], shape=r["shape"],
                         compute_s=t_c, memory_s=t_m, collective_s=t_x,
                         dominant=dom[1], model_flops=mf,
                         hlo_flops=hlo_total, useful_ratio=ratio,
                         step_s=max(t_c, t_m, t_x),
                         roofline_frac=min(1.0, max(t_c, t_m) /
                                           max(t_c, t_m, t_x))))
        csv_row(f"roofline/{r['arch']}/{r['shape']}",
                max(t_c, t_m, t_x) * 1e6,
                f"dom={dom[1]} c={t_c * 1e3:.2f}ms m={t_m * 1e3:.2f}ms "
                f"x={t_x * 1e3:.2f}ms useful={ratio:.2f}")
    return rows


def run(quick: bool = False):
    return analyze(quick=quick)


if __name__ == "__main__":
    run()
