"""Dynamic re-planning benchmark: does plan switching beat the best
static plan on a non-stationary day?

The trace is a compressed "day": a quiet night, a steep business-hours
ramp, an evening shoulder, and a quiet tail (``PiecewiseRate`` — the
phase boundaries below scale a diurnal shape down to a benchmarkable
horizon without changing the question).  The benchmark runs one exact
static sweep and one ``dynamic=DynamicSpec(...)`` sweep (epoch-gated
schedules over the top static finalists, drain mechanism), then reports
the head-to-head: best-static vs best-dynamic SLO goodput, with every
reconfiguration itemized (re-shard seconds/bytes, drain overrun, stall,
energy).  When the static plan wins, that is the honest negative
result — the reconfiguration bill is the point of the subsystem.

Also demonstrates the fluid guard: the multi-fidelity surrogate REFUSES
this trace by default (one-rate screening would mis-rank) and is timed
in its ``nonstationary="peak"`` fallback.

Writes ``BENCH_dynamic.json`` next to the repo root:

    PYTHONPATH=src python benchmarks/bench_dynamic.py [--smoke] [--jobs N]
                                                      [--out PATH]

``--smoke`` shrinks the model/trace for CI and additionally ASSERTS the
subsystem's load-bearing properties: an empty ``DynamicSpec`` is
bit-identical to ``dynamic=None``, dynamic candidates carry itemized
nonzero reconfiguration bills, the dynamic run replays bit-identically
from a fresh context, and no request is lost across plan switches.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform
import time

from repro.core import (ApexSearch, DynamicPlanSimulator, DynamicSpec,
                        EpochSchedule, MultiFidelitySearch, PiecewiseRate,
                        get_trace, h100_node, ir_from_hf_config)

SMOKE_CFG = dict(hidden_size=256, num_hidden_layers=4,
                 num_attention_heads=8, num_key_value_heads=4,
                 intermediate_size=1024, vocab_size=1024)
FULL_CFG = dict(hidden_size=2048, num_hidden_layers=16,
                num_attention_heads=16, num_key_value_heads=8,
                intermediate_size=8192, vocab_size=32000)


def build(smoke: bool):
    """(search, requests, spec, slos): a day-shaped piecewise trace and
    the dynamic spec that searches epoch schedules over it.  The epoch
    grid tracks the phase length, and the explicit schedules are the
    oracle timetables a capacity planner would write down: switch to the
    runner-up finalist for the busy phase, switch back after."""
    if smoke:
        model = ir_from_hf_config(SMOKE_CFG, name="tiny")
        n_req = 60
        day = PiecewiseRate(starts=(0.0, 2.0), rates=(2.0, 80.0))
        epoch_s = 2.0
        slos = dict(slo_ttft_s=0.5, slo_tpot_s=0.2)
        oracle = (EpochSchedule(epochs=((0.0, 0), (2.0, 1))),
                  EpochSchedule(epochs=((0.0, 1), (2.0, 0))))
    else:
        model = ir_from_hf_config(FULL_CFG, name="tiny-7b")
        n_req = 500
        # night 1.5/s -> business hours 6/s -> evening tail 2/s
        day = PiecewiseRate(starts=(0.0, 60.0, 120.0),
                            rates=(1.5, 6.0, 2.0))
        epoch_s = 30.0
        slos = dict(slo_ttft_s=1.0, slo_tpot_s=0.25)
        oracle = (EpochSchedule(epochs=((0.0, 0), (60.0, 1), (120.0, 0))),
                  EpochSchedule(epochs=((0.0, 1), (60.0, 0), (120.0, 1))))
    cluster = h100_node(8)
    reqs = get_trace("summarization", arrival_rate=day, seed=3,
                     num_requests=n_req)
    spec = DynamicSpec(epoch_s=epoch_s, top_k=3, mechanism="drain",
                       schedules=oracle)
    return ApexSearch(model, cluster), reqs, spec, slos


def report_row(rep):
    row = {
        "plan": rep.plan_label,
        "goodput_rps": round(rep.goodput_rps, 3),
        "ttft_p95_ms": round(rep.ttft_p95 * 1e3, 2),
        "tpot_p95_ms": round(rep.tpot_p95 * 1e3, 2),
        "energy_kj": round(rep.total_energy / 1e3, 3),
    }
    if rep.reconfig is not None:
        rc = rep.reconfig
        row["reconfig"] = {
            "mechanism": rc.mechanism,
            "switches": [{
                "at_s": round(s.at_s, 2),
                "reshard_s": round(s.reshard_s, 6),
                "reshard_gb": round(s.reshard_bytes / 1e9, 4),
                "migrate_s": round(s.migrate_s, 6),
                "migrated": s.migrated,
                "drain_s": round(s.drain_s, 4),
                "drained": s.drained,
                "stall_s": round(s.stall_s, 4),
                "energy_j": round(s.energy_j, 3),
            } for s in rc.switches],
            "total_stall_s": round(rc.total_stall_s, 4),
            "total_energy_j": round(rc.total_energy_j, 3),
        }
    if rep.windows:
        row["windows"] = [{
            "start_s": round(w.start, 1), "end_s": round(w.end, 1),
            "arrivals": w.arrivals, "finished": w.finished,
            "goodput_rps": round(w.goodput_rps, 3),
            "ttft_p95_ms": round(w.ttft_p95 * 1e3, 2),
        } for w in rep.windows]
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizing for CI, plus correctness asserts")
    ap.add_argument("--jobs", type=int, default=1,
                    help="forked workers for the static sweep")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args()

    search, reqs, spec, slos = build(args.smoke)

    t0 = time.perf_counter()
    static = search.search(reqs, objective="goodput", max_model_dp=4,
                           jobs=args.jobs, **slos)
    static_s = round(time.perf_counter() - t0, 3)

    t0 = time.perf_counter()
    dyn = search.search(reqs, objective="goodput", max_model_dp=4,
                        jobs=args.jobs, dynamic=spec, **slos)
    dyn_s = round(time.perf_counter() - t0, 3)

    dyn_reports = [r for r in dyn.all_reports if r.reconfig is not None]
    best_dynamic = (max(dyn_reports, key=lambda r: r.goodput_rps)
                    if dyn_reports else None)
    switching_wins = dyn.best.reconfig is not None

    # fluid guard: the surrogate refuses this trace by default
    mf = MultiFidelitySearch(search, frontier_k=4)
    try:
        mf.search(reqs, objective="goodput", max_model_dp=4, **slos)
        guard_refused = False
    except ValueError:
        guard_refused = True
    t0 = time.perf_counter()
    mres = mf.search(reqs, objective="goodput", max_model_dp=4,
                     jobs=args.jobs, nonstationary="peak", **slos)
    mf_s = round(time.perf_counter() - t0, 3)

    out = {
        "bench": "bench_dynamic",
        "smoke": args.smoke,
        "jobs": args.jobs,
        "n_requests": len(reqs),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "num_static_candidates": static.num_schemes,
        "num_dynamic_candidates": len(dyn_reports),
        "best_static": report_row(static.best),
        "best_dynamic": (report_row(best_dynamic)
                         if best_dynamic is not None else None),
        "switching_wins": switching_wins,
        "goodput_delta_rps": (
            round(best_dynamic.goodput_rps - static.best.goodput_rps, 3)
            if best_dynamic is not None else None),
        "exact_seconds": {"static": static_s, "dynamic": dyn_s},
        "fluid_guard": {
            "refused_by_default": guard_refused,
            "peak_mode_seconds": mf_s,
            "peak_mode_best": mres.best.plan_label,
        },
    }

    if args.smoke:
        # empty spec == no spec, bit-identical
        empty = search.search(reqs, objective="goodput", max_model_dp=4,
                              jobs=args.jobs, dynamic=DynamicSpec(),
                              **slos)
        assert [dataclasses.asdict(r) for r in empty.all_reports] == \
            [dataclasses.asdict(r) for r in static.all_reports], \
            "empty DynamicSpec must be bit-identical to dynamic=None"
        # every dynamic candidate bills its switches
        assert dyn_reports, "dynamic sweep produced no candidates"
        for r in dyn_reports:
            assert r.reconfig.num_switches >= 1
            for s in r.reconfig.switches:
                assert s.reshard_s > 0 and s.reshard_bytes > 0
        # seeded determinism + request conservation through a switch,
        # from a rebuilt context (fresh cost caches, fresh RNG path)
        s2, reqs2, spec2, _ = build(args.smoke)
        cands2, kv2 = s2.candidates(quant="fp16")
        sched = spec2.schedules[0]
        runs = []
        for sch in (search, s2):
            c, k = sch.candidates(quant="fp16")
            d = DynamicPlanSimulator(sch, c, sched, kv_model=k,
                                     mechanism="drain")
            runs.append(d.simulate(reqs, keep_records=True))
        a, b = runs
        assert len(a.records) == len(reqs), "requests lost at the switch"
        assert [dataclasses.asdict(r) for r in a.records] == \
            [dataclasses.asdict(r) for r in b.records], \
            "dynamic run must replay bit-identically"
        assert guard_refused, "fluid guard must refuse by default"
        print("smoke asserts passed: empty-spec identity, itemized "
              "bills, replay determinism, request conservation, "
              "fluid-guard refusal")

    path = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_dynamic.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)

    print(f"best static:  {out['best_static']['plan']}  "
          f"goodput={out['best_static']['goodput_rps']} req/s")
    if best_dynamic is not None:
        print(f"best dynamic: {best_dynamic.plan_label}")
        print(f"  goodput={out['best_dynamic']['goodput_rps']} req/s, "
              f"{best_dynamic.reconfig.summary()}")
    print(f"switching wins: {switching_wins} "
          f"(delta {out['goodput_delta_rps']} req/s)")
    print(f"fluid guard refused by default: {guard_refused}; "
          f"peak-mode multifid in {mf_s}s -> "
          f"{out['fluid_guard']['peak_mode_best']}")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
