"""Table 3 reproduction: multi-node H100 (Llama-405B) + H200 cluster
(70B / Mistral-Large / Mixtral) — APEX Optimal vs baseline per trace."""

from __future__ import annotations

from repro.core import ApexSearch, get_trace, h100_multinode, h200_node

from .common import Timer, csv_row, model_ir

TRACES = [("summarization", 3.0), ("creation", 6.0), ("chat", 16.0)]


def run(num_requests: int = 64, quick: bool = False):
    rows = []
    cases = [("llama-3.1-405b", h100_multinode(2), "h100x16")]
    if not quick:
        cases += [(m, h200_node(8), "h200x8")
                  for m in ("llama-3.1-70b", "mistral-large-123b",
                            "mixtral-8x22b")]
    for name, cluster, cname in cases:
        model = model_ir(name)
        search = ApexSearch(model, cluster)
        for trace, rate in (TRACES[:1] if quick else TRACES):
            reqs = get_trace(trace, arrival_rate=rate,
                             num_requests=num_requests)
            with Timer() as t:
                base = search.evaluate_baseline(reqs)
                full = search.search(reqs)
            sp = base.e2e_latency / full.best.e2e_latency
            rows.append(dict(model=name, cluster=cname, trace=trace,
                             baseline_s=base.e2e_latency,
                             apex_s=full.best.e2e_latency, speedup=sp,
                             plan=full.best.plan_label))
            csv_row(f"table3/{name}/{cname}/{trace}", t.seconds * 1e6,
                    f"apex={sp:.2f}x plan={full.best.plan_label}")
    return rows


if __name__ == "__main__":
    run()
