"""Fig. 6 reproduction — simulation fidelity: predicted vs ACTUAL speedups.

The paper compares APEX-predicted speedups against vLLM/SGLang runs on
GPUs (mean relative error 10.7%).  Our hardware is this host's CPU, so the
loop closes the same way at reduced scale: the simulator (profiling tables
MEASURED on this CPU, core/profiles.MeasuredBackend) predicts serving
outcomes for configuration variants, and the REAL JAX engine
(serving/engine.py) runs them.  Variants exercised: max-batch-size caps —
the serving-dynamics knob (paper §4.6) measurable on one device.

Reported: per-variant predicted vs actual slowdown relative to the best
variant + mean relative error.
"""

from __future__ import annotations

import jax
import numpy as np

from repro import configs as C
from repro.core import (ApexSearch, BatchingPolicy, cpu_local,
                        MeasuredBackend, Request)
from repro.core.planner import heuristic_scheme
from repro.data.requests import make_serving_requests
from repro.models import transformer as T
from repro.serving.engine import ServingEngine

from .common import Timer, csv_row

CAPS = (1, 2, 4)


def run(arch: str = "qwen2_0_5b", n_requests: int = 6, gen_len: int = 8,
        ctx: int = 12, quick: bool = False):
    cfg = C.get_reduced(arch)
    model = cfg.to_ir()
    cluster = cpu_local()
    caps = CAPS[:2] if quick else CAPS

    # --- real engine runs ---
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = make_serving_requests("chat", 1000.0, n_requests,
                                 cfg.vocab_size, max_len=ctx)
    for r in reqs:
        r["gen_len"] = gen_len
        r["prompt"] = r["prompt"][:ctx]
    actual = {}
    for cap in caps:
        eng = ServingEngine(cfg, params, max_batch=cap, max_len=64)
        rep = eng.run([dict(r) for r in reqs], time_scale=0.0)
        actual[cap] = rep.total_time

    # --- simulator predictions with CPU-measured op tables ---
    backend = MeasuredBackend(cluster)
    search = ApexSearch(model, cluster, backend=backend)
    search.store.x_max = 4096
    sim_reqs = [Request(rid=r["rid"], arrival=0.0,
                        context_len=len(r["prompt"]), gen_len=r["gen_len"])
                for r in reqs]
    scheme = heuristic_scheme(model, 1, cluster)
    predicted = {}
    for cap in caps:
        rep = search.evaluate(scheme, sim_reqs,
                              policy=BatchingPolicy(max_batch_size=cap,
                                                    fast_forward=False))
        predicted[cap] = rep.e2e_latency

    # --- compare normalized slowdowns (the paper's speedup-ratio fidelity) ---
    ref = max(caps)
    errs = []
    rows = []
    for cap in caps:
        act = actual[cap] / actual[ref]
        pred = predicted[cap] / predicted[ref]
        err = abs(pred - act) / act
        errs.append(err)
        rows.append(dict(cap=cap, actual_s=actual[cap],
                         predicted_s=predicted[cap],
                         actual_ratio=act, predicted_ratio=pred,
                         rel_err=err))
        csv_row(f"fig6/{arch}/cap{cap}", actual[cap] * 1e6,
                f"pred_ratio={pred:.2f} act_ratio={act:.2f} err={err:.1%}")
    mean_err = float(np.mean(errs))
    csv_row(f"fig6/{arch}/mean_rel_err", mean_err * 1e6,
            f"mean_relative_error={mean_err:.1%} (paper: 10.7%)")
    return rows, mean_err


if __name__ == "__main__":
    run()
