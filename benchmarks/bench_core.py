"""Plan-evaluation throughput benchmark for the simulation core.

Times how many candidate plans per second ``ApexSearch.search`` evaluates
(fixed seed, fixed trace slices) for a colocated-only search and a joint
colocated+disaggregated search, and writes ``BENCH_core.json`` next to the
repo root so successive PRs can track the perf trajectory of the engine
(step-cost memoization vs event-loop overhead).

    PYTHONPATH=src python benchmarks/bench_core.py [--smoke] [--out PATH]

``--smoke`` shrinks the workload to a few seconds for CI import-rot +
sanity checking; the default sizing is the comparable number to quote.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

from repro.core import ApexSearch, get_trace, h100_node, ir_from_hf_config

MODEL_CFG = dict(hidden_size=2048, num_hidden_layers=16,
                 num_attention_heads=16, num_key_value_heads=8,
                 intermediate_size=8192, vocab_size=32000)


def bench_search(search, reqs, **kw):
    t0 = time.perf_counter()
    res = search.search(reqs, **kw)
    dt = time.perf_counter() - t0
    hit_rate = (res.cache_hits / (res.cache_hits + res.cache_misses)
                if res.cache_hits + res.cache_misses else 0.0)
    return {
        "plans": res.num_schemes,
        "feasible": res.num_feasible,
        "seconds": round(dt, 3),
        "plans_per_sec": round(res.num_schemes / dt, 2),
        "best": res.best.plan_label,
        "cache_hits": res.cache_hits,
        "cache_misses": res.cache_misses,
        "cache_hit_rate": round(hit_rate, 4),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizing for CI (seconds, not minutes)")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args()

    n_req = 16 if args.smoke else 64
    max_disagg = 12 if args.smoke else 48
    model = ir_from_hf_config(MODEL_CFG, name="tiny-7b")
    cluster = h100_node(8)
    search = ApexSearch(model, cluster)
    reqs = get_trace("chat", arrival_rate=4.0, seed=0, num_requests=n_req)

    results = {
        "colocated": bench_search(search, reqs, feasible_only=True),
        "joint_disagg": bench_search(
            search, reqs, feasible_only=True, disaggregated=True,
            max_disagg_plans=max_disagg),
    }
    out = {
        "bench": "bench_core",
        "smoke": args.smoke,
        "n_requests": n_req,
        "python": platform.python_version(),
        "machine": platform.machine(),
        "results": results,
    }
    path = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_core.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    for name, r in results.items():
        print(f"{name}: {r['plans']} plans in {r['seconds']}s "
              f"-> {r['plans_per_sec']} plans/s (best {r['best']})")
        print(f"  step-cost cache: {r['cache_hits']} hits / "
              f"{r['cache_misses']} misses "
              f"({100 * r['cache_hit_rate']:.1f}% hit rate)")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
