"""Fig. 8 reproduction — simulation overhead stays flat from billion- to
trillion-scale models (the Transformer-IR block extrapolation claim)."""

from __future__ import annotations

from repro.core import ApexSearch, get_trace, h100_multinode

from .common import Timer, csv_row, model_ir, trillion_scale_ir


def run(quick: bool = False):
    names = ["qwen2.5-32b", "llama-3.1-70b", "mistral-large-123b",
             "llama-3.1-405b"]
    models = [(n, model_ir(n)) for n in (names[:2] if quick else names)]
    if not quick:
        models.append(("llama-1.1T", trillion_scale_ir()))
    cluster = h100_multinode(4)           # 32 GPUs so the 1T model fits
    reqs = get_trace("chat", arrival_rate=8.0, num_requests=48)
    rows = []
    for name, model in models:
        search = ApexSearch(model, cluster)
        with Timer() as t:
            res = search.search(reqs, max_model_dp=4)
        rows.append(dict(model=name,
                         params_b=model.total_params() / 1e9,
                         sim_seconds=t.seconds,
                         schemes=res.num_schemes))
        csv_row(f"fig8/{name}", t.seconds * 1e6,
                f"params={model.total_params() / 1e9:.0f}B "
                f"schemes={res.num_schemes} sim={t.seconds:.2f}s")
    if len(rows) >= 2:
        ratio = rows[-1]["sim_seconds"] / max(rows[0]["sim_seconds"], 1e-9)
        csv_row("fig8/overhead_ratio_1T_vs_32B", ratio * 1e6,
                f"{ratio:.2f}x sim-time for "
                f"{rows[-1]['params_b'] / rows[0]['params_b']:.0f}x params")
    return rows


if __name__ == "__main__":
    run()
