"""§Perf hillclimb driver: lower the three chosen cells' variants and
record the roofline-term deltas (hypothesis -> change -> before -> after).

Cells (chosen per the hillclimb policy from the baseline roofline table):
  1. qwen1.5-32b x prefill_32k  — worst roofline fraction (useful=0.07:
     40 heads don't divide the 16-wide TP axis, attention replicated).
     Change: zero-padded heads 40 -> 48 (parallel/padding).
  2. mixtral-8x7b x train_4k    — most collective-bound (6.3 TB
     all-reduce/step).  Change: chunk-major MoE dispatch (one TP reduce
     per token chunk instead of per expert) — layers/moe.py.
  3. mixtral-8x7b x decode_32k  — most representative of the paper's
     technique (MoE serving, the paper's EP-vs-TP study).  Change:
     flash-decoding sharding hints keep the seq-sharded KV local
     (layers/attention.py) instead of per-layer all-gathers.

MUST run in a fresh process (forces 512 host devices):
    PYTHONPATH=src python -m benchmarks.perf_iterations
Results -> results/perf_iterations.json.  The moe/decode baselines are the
recorded dry-run numbers (the code before iterations 2/3); re-lowering
with the current code gives the optimized numbers.
"""

import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses      # noqa: E402
import json             # noqa: E402

CELLS = [
    ("qwen1.5-32b", "prefill_32k", "head-pad 40->48"),
    ("mixtral-8x7b", "train_4k", "chunk-major MoE dispatch"),
    ("mixtral-8x7b", "decode_32k", "flash-decoding shard hints"),
]

PEAK, HBM, ICI = 197e12, 819e9, 50e9


def terms(rec) -> dict:
    coll = sum(rec["collective_bytes"].values())
    traffic = (rec["memory"]["argument_size_in_bytes"]
               + rec.get("workspace_model", 0))
    return dict(compute_s=rec["dot_flops"] / PEAK,
                memory_s=traffic / HBM,
                collective_s=coll / ICI,
                coll_gb=coll / 1e9,
                per_dev_gb=rec["per_device_bytes"] / 1e9)


def main():
    from repro import configs as C
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh
    from repro.parallel.padding import padded_config

    mesh = make_production_mesh()
    baselines = {(r["arch"], r["shape"]): r
                 for r in json.load(open("results/dryrun.json"))
                 if r.get("status") == "ok" and r["mesh"] == "16x16"}

    out = []
    for arch, shape, change in CELLS:
        norm = C.ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
        base = baselines[(norm, shape)]
        kwargs = {}
        if "head-pad" in change:
            kwargs["cfg_override"] = padded_config(C.get_config(arch))
        print(f"=== {arch} x {shape}: {change} ===", flush=True)
        rec = lower_cell(arch, shape, mesh, **kwargs)
        b, a = terms(base), terms(rec)
        row = dict(arch=arch, shape=shape, change=change,
                   before=b, after=a)
        for k in ("compute_s", "collective_s", "per_dev_gb"):
            d = a[k] / b[k] if b[k] else float("nan")
            print(f"  {k}: {b[k]:.4g} -> {a[k]:.4g}  ({d:.2f}x)")
        out.append(row)

    os.makedirs("results", exist_ok=True)
    with open("results/perf_iterations.json", "w") as f:
        json.dump(out, f, indent=1)
    print("-> results/perf_iterations.json")


if __name__ == "__main__":
    main()
