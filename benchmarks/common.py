"""Shared fixtures for the per-table benchmarks: the paper's evaluation
models (§4.1) as Transformer-IR configs, and CSV output helpers."""

from __future__ import annotations

import time

from repro.core import ir_from_hf_config

# The paper's four evaluation models (§4.1) + Fig. 8's scaling set.
PAPER_MODELS = {
    "llama-3.1-70b": dict(hidden_size=8192, num_hidden_layers=80,
                          num_attention_heads=64, num_key_value_heads=8,
                          intermediate_size=28672, vocab_size=128256),
    "llama-3.1-405b": dict(hidden_size=16384, num_hidden_layers=126,
                           num_attention_heads=128, num_key_value_heads=8,
                           intermediate_size=53248, vocab_size=128256),
    "mistral-large-123b": dict(hidden_size=12288, num_hidden_layers=88,
                               num_attention_heads=96,
                               num_key_value_heads=8,
                               intermediate_size=28672,
                               vocab_size=32768),
    "mixtral-8x22b": dict(hidden_size=6144, num_hidden_layers=56,
                          num_attention_heads=48, num_key_value_heads=8,
                          intermediate_size=16384, num_local_experts=8,
                          num_experts_per_tok=2,
                          moe_intermediate_size=16384, vocab_size=32000),
    "qwen2.5-32b": dict(hidden_size=5120, num_hidden_layers=64,
                        num_attention_heads=40, num_key_value_heads=8,
                        intermediate_size=27648, vocab_size=152064),
}


def model_ir(name: str):
    return ir_from_hf_config(PAPER_MODELS[name], name=name)


def trillion_scale_ir():
    """The paper's Fig. 8 synthetic trillion-parameter model: Llama-70B
    scaled 16x via its config file."""
    cfg = dict(PAPER_MODELS["llama-3.1-70b"])
    cfg["hidden_size"] *= 4            # 16x params ~ 4x width
    cfg["intermediate_size"] *= 4
    cfg["num_attention_heads"] *= 4
    return ir_from_hf_config(cfg, name="llama-1.1T")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row, flush=True)
    return row
