"""Colocated vs disaggregated TTFT/TPOT frontier (+ heterogeneous pools).

Sweeps the three paper traces (summarization / creation / chat, §4.1
Table 1) for a dense and a MoE model, runs the joint plan search
(``ApexSearch.search(..., disaggregated=True)``), and reports each
family's latency frontier: for every (model, trace) point, the best
TTFT-p95 each family achieves, and — the disaggregation claim — whether a
disaggregated plan strictly beats the best colocated plan's TTFT p95 *at
comparable TPOT p95* (colocated candidates within ``TPOT_TOL`` of the
disaggregated plan's TPOT are admitted to the comparison).

``--hetero`` adds the heterogeneous-pool sweep: the same search with a
pool MENU (H100+H200, H100+TPUv5e) so mixed-device plans — prefill on one
part, decode on another — compete against every homogeneous plan, and the
table reports where a mixed pool wins TTFT p95, TPOT p95, or energy.

Run:  PYTHONPATH=src python benchmarks/disagg_frontier.py [--requests N]
      PYTHONPATH=src python benchmarks/disagg_frontier.py --hetero
      PYTHONPATH=src python benchmarks/disagg_frontier.py --smoke
or:   PYTHONPATH=src python -m benchmarks.run --only disagg
"""

from __future__ import annotations

import argparse

from repro.core import (ApexSearch, BatchingPolicy, get_trace, h100_node,
                        h100_multinode, h200_node, ir_from_hf_config,
                        tpu_v5e_pod)
from repro.disagg import is_mixed_label

try:
    from .common import PAPER_MODELS, Timer, csv_row
except ImportError:                      # direct script execution
    from common import PAPER_MODELS, Timer, csv_row

MODELS = {
    "qwen2.5-32b": "dense",
    "mixtral-8x22b": "moe",
}
TRACES = ["summarization", "creation", "chat"]
# Arrival rates chosen so each trace loads a 16-GPU cluster into the
# contention regime where batching policy matters (idle clusters make
# every plan look alike).
RATES = {"summarization": 1.0, "creation": 1.0, "chat": 2.0}
TPOT_TOL = 1.10      # "comparable TPOT": within 10% of the disagg plan's

# Heterogeneous pool menus: each entry is the per-pool cluster choices the
# search may pair (prefill from one, decode from the other — or same).
HETERO_MENUS = {
    "h100+h200": lambda: [h100_node(8), h200_node(8)],
    "h100+tpuv5e": lambda: [h100_node(8),
                            tpu_v5e_pod(chips=16, ring_group=16)],
}


def pareto(reports):
    """Non-dominated subset under (ttft_p95, tpot_p95), sorted by TTFT."""
    pts = sorted(reports, key=lambda r: (r.ttft_p95, r.tpot_p95))
    front, best_tpot = [], float("inf")
    for r in pts:
        if r.tpot_p95 < best_tpot:
            front.append(r)
            best_tpot = r.tpot_p95
    return front


def frontier_row(model_name, trace, requests, cluster):
    model = ir_from_hf_config(PAPER_MODELS[model_name], name=model_name)
    reqs = get_trace(trace, arrival_rate=RATES[trace],
                     num_requests=requests, seed=0)
    search = ApexSearch(model, cluster)
    res = search.search(reqs, objective="ttft", feasible_only=True,
                        disaggregated=True,
                        policy=BatchingPolicy(chunked_prefill=512))
    feas = [r for r in res.all_reports if r.feasible]
    coloc = [r for r in feas if not r.plan_label.startswith("disagg[")]
    disagg = [r for r in feas if r.plan_label.startswith("disagg[")]
    return res, coloc, disagg


def run(quick: bool = False, requests: int = 96, nodes: int = 2,
        gpus_per_node: int = 8) -> int:
    """Registry entry (benchmarks/run.py): emits the frontier table plus
    one CSV row; returns the number of disagg TTFT wins."""
    if quick:
        requests = 48
    cluster = h100_multinode(nodes, gpus_per_node)
    with Timer() as t:
        wins = _frontier(cluster, requests)
    csv_row("disagg_frontier", t.seconds * 1e6,
            f"ttft_wins={wins}/{len(MODELS) * len(TRACES)}")
    return wins


def _frontier(cluster, requests: int) -> int:
    print(f"# disagg frontier on {cluster.name}, "
          f"{requests} requests/trace")
    print(f"{'model':<14} {'trace':<14} {'family':<10} "
          f"{'ttft_p95_ms':>11} {'tpot_p95_ms':>11} {'e2e_s':>8}  plan")

    wins = 0
    for model_name in MODELS:
        for trace in TRACES:
            res, coloc, disagg = frontier_row(model_name, trace,
                                              requests, cluster)
            for fam, reps in (("colocated", coloc), ("disagg", disagg)):
                for r in pareto(reps)[:3]:
                    print(f"{model_name:<14} {trace:<14} {fam:<10} "
                          f"{r.ttft_p95 * 1e3:>11.1f} "
                          f"{r.tpot_p95 * 1e3:>11.2f} "
                          f"{r.e2e_latency:>8.1f}  {r.plan_label[:72]}")
            # the disaggregation claim: strictly better TTFT p95 than the
            # best colocated plan at comparable TPOT p95
            claim = None
            for d in pareto(disagg):
                comparable = [c for c in coloc
                              if c.tpot_p95 <= d.tpot_p95 * TPOT_TOL]
                if not comparable:
                    continue
                best_c = min(comparable, key=lambda c: c.ttft_p95)
                if d.ttft_p95 < best_c.ttft_p95:
                    claim = (d, best_c)
                    break
            if claim:
                d, c = claim
                wins += 1
                print(f"{'':<14} {'':<14} >> disagg wins TTFT: "
                      f"{d.ttft_p95 * 1e3:.1f}ms vs {c.ttft_p95 * 1e3:.1f}ms "
                      f"at TPOT {d.tpot_p95 * 1e3:.2f} vs "
                      f"{c.tpot_p95 * 1e3:.2f}ms")
            else:
                print(f"{'':<14} {'':<14} >> no disagg TTFT win at "
                      f"comparable TPOT")
    print(f"# disagg TTFT wins at comparable TPOT: {wins}/"
          f"{len(MODELS) * len(TRACES)} (model, trace) points")
    return wins


def _hetero(requests: int, menus=None, models=None, traces=None,
            max_disagg_plans: int = 96) -> int:
    """Mixed-device pools vs the best homogeneous plan (colocated OR
    same-device disagg) on TTFT p95 / TPOT p95 / energy.  Returns the
    number of (menu, model, trace) points where a mixed pool wins at
    least one metric."""
    menus = menus or {k: mk() for k, mk in HETERO_MENUS.items()}
    models = models or list(MODELS)
    traces = traces or TRACES
    print(f"# hetero pools, {requests} requests/trace")
    print(f"{'menu':<13} {'model':<14} {'trace':<14} {'family':<6} "
          f"{'ttft_p95_ms':>11} {'tpot_p95_ms':>11} {'energy_kJ':>9}  plan")
    wins = 0
    for menu_name, menu in menus.items():
        budget = sum(c.num_devices for c in menu)
        cluster = h100_multinode(2, budget // 2) if budget % 2 == 0 \
            else h100_multinode(1, budget)
        for model_name in models:
            model = ir_from_hf_config(PAPER_MODELS[model_name],
                                      name=model_name)
            for trace in traces:
                reqs = get_trace(trace, arrival_rate=RATES[trace],
                                 num_requests=requests, seed=0)
                search = ApexSearch(model, cluster)
                res = search.search(
                    reqs, objective="ttft", feasible_only=True,
                    disaggregated=True, pool_menu=menu,
                    max_total_devices=budget,
                    max_disagg_plans=max_disagg_plans,
                    policy=BatchingPolicy(chunked_prefill=512))
                feas = [r for r in res.all_reports if r.feasible]
                mixed = [r for r in feas if is_mixed_label(r.plan_label)]
                homog = [r for r in feas
                         if not is_mixed_label(r.plan_label)]
                if not mixed or not homog:
                    print(f"{menu_name:<13} {model_name:<14} {trace:<14} "
                          f">> no {'mixed' if not mixed else 'homog'} "
                          f"plan feasible")
                    continue
                point_wins = []
                for metric, key in (("ttft", lambda r: r.ttft_p95),
                                    ("tpot", lambda r: r.tpot_p95),
                                    ("energy",
                                     lambda r: r.total_energy)):
                    bm, bh = min(mixed, key=key), min(homog, key=key)
                    if key(bm) < key(bh):
                        point_wins.append(metric)
                for fam, best in (("homog", min(homog,
                                                key=lambda r: r.ttft_p95)),
                                  ("mixed", min(mixed,
                                                key=lambda r: r.ttft_p95))):
                    print(f"{menu_name:<13} {model_name:<14} {trace:<14} "
                          f"{fam:<6} {best.ttft_p95 * 1e3:>11.1f} "
                          f"{best.tpot_p95 * 1e3:>11.2f} "
                          f"{best.total_energy / 1e3:>9.2f}  "
                          f"{best.plan_label[:60]}")
                if point_wins:
                    wins += 1
                    print(f"{'':<13} {'':<14} {'':<14} >> mixed pools win: "
                          f"{', '.join(point_wins)}")
                else:
                    print(f"{'':<13} {'':<14} {'':<14} >> homogeneous "
                          f"wins every metric")
    print(f"# mixed-pool wins on >=1 metric: {wins} points")
    return wins


def run_hetero(quick: bool = False, requests: int = 64) -> int:
    if quick:
        requests = 32
    with Timer() as t:
        wins = _hetero(requests)
    csv_row("disagg_hetero", t.seconds * 1e6, f"mixed_wins={wins}")
    return wins


def smoke() -> int:
    """CI smoke: a tiny model through BOTH sweeps in seconds, so the
    benchmark entry points can't silently rot."""
    global MODELS, RATES
    tiny = dict(hidden_size=256, num_hidden_layers=4,
                num_attention_heads=8, num_key_value_heads=4,
                intermediate_size=1024, vocab_size=1024)
    PAPER_MODELS["tiny"] = tiny
    MODELS = {"tiny": "dense"}
    RATES = dict(RATES, chat=4.0)
    wins = _frontier(h100_node(4), requests=16)
    hwins = _hetero(16, menus={"h100+h200": [h100_node(2), h200_node(2)]},
                    models=["tiny"], traces=["chat"], max_disagg_plans=32)
    print(f"# smoke complete (ttft_wins={wins}, mixed_wins={hwins})")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--gpus-per-node", type=int, default=8)
    ap.add_argument("--hetero", action="store_true",
                    help="run the heterogeneous-pool sweep instead")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-model CI smoke of both sweeps")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke())
    if args.hetero:
        raise SystemExit(0 if run_hetero(requests=args.requests) > 0 else 1)
    raise SystemExit(0 if run(requests=args.requests, nodes=args.nodes,
                              gpus_per_node=args.gpus_per_node) > 0 else 1)
