"""Colocated vs disaggregated TTFT/TPOT frontier.

Sweeps the three paper traces (summarization / creation / chat, §4.1
Table 1) for a dense and a MoE model, runs the joint plan search
(``ApexSearch.search(..., disaggregated=True)``), and reports each
family's latency frontier: for every (model, trace) point, the best
TTFT-p95 each family achieves, and — the disaggregation claim — whether a
disaggregated plan strictly beats the best colocated plan's TTFT p95 *at
comparable TPOT p95* (colocated candidates within ``TPOT_TOL`` of the
disaggregated plan's TPOT are admitted to the comparison).

Run:  PYTHONPATH=src python benchmarks/disagg_frontier.py [--requests N]
or:   PYTHONPATH=src python -m benchmarks.run --only disagg
"""

from __future__ import annotations

import argparse

from repro.core import (ApexSearch, BatchingPolicy, get_trace,
                        h100_multinode, ir_from_hf_config)

try:
    from .common import PAPER_MODELS, Timer, csv_row
except ImportError:                      # direct script execution
    from common import PAPER_MODELS, Timer, csv_row

MODELS = {
    "qwen2.5-32b": "dense",
    "mixtral-8x22b": "moe",
}
TRACES = ["summarization", "creation", "chat"]
# Arrival rates chosen so each trace loads a 16-GPU cluster into the
# contention regime where batching policy matters (idle clusters make
# every plan look alike).
RATES = {"summarization": 1.0, "creation": 1.0, "chat": 2.0}
TPOT_TOL = 1.10      # "comparable TPOT": within 10% of the disagg plan's


def pareto(reports):
    """Non-dominated subset under (ttft_p95, tpot_p95), sorted by TTFT."""
    pts = sorted(reports, key=lambda r: (r.ttft_p95, r.tpot_p95))
    front, best_tpot = [], float("inf")
    for r in pts:
        if r.tpot_p95 < best_tpot:
            front.append(r)
            best_tpot = r.tpot_p95
    return front


def frontier_row(model_name, trace, requests, cluster):
    model = ir_from_hf_config(PAPER_MODELS[model_name], name=model_name)
    reqs = get_trace(trace, arrival_rate=RATES[trace],
                     num_requests=requests, seed=0)
    search = ApexSearch(model, cluster)
    res = search.search(reqs, objective="ttft", feasible_only=True,
                        disaggregated=True,
                        policy=BatchingPolicy(chunked_prefill=512))
    feas = [r for r in res.all_reports if r.feasible]
    coloc = [r for r in feas if not r.plan_label.startswith("disagg[")]
    disagg = [r for r in feas if r.plan_label.startswith("disagg[")]
    return res, coloc, disagg


def run(quick: bool = False, requests: int = 96, nodes: int = 2,
        gpus_per_node: int = 8) -> int:
    """Registry entry (benchmarks/run.py): emits the frontier table plus
    one CSV row; returns the number of disagg TTFT wins."""
    if quick:
        requests = 48
    cluster = h100_multinode(nodes, gpus_per_node)
    with Timer() as t:
        wins = _frontier(cluster, requests)
    csv_row("disagg_frontier", t.seconds * 1e6,
            f"ttft_wins={wins}/{len(MODELS) * len(TRACES)}")
    return wins


def _frontier(cluster, requests: int) -> int:
    print(f"# disagg frontier on {cluster.name}, "
          f"{requests} requests/trace")
    print(f"{'model':<14} {'trace':<14} {'family':<10} "
          f"{'ttft_p95_ms':>11} {'tpot_p95_ms':>11} {'e2e_s':>8}  plan")

    wins = 0
    for model_name in MODELS:
        for trace in TRACES:
            res, coloc, disagg = frontier_row(model_name, trace,
                                              requests, cluster)
            for fam, reps in (("colocated", coloc), ("disagg", disagg)):
                for r in pareto(reps)[:3]:
                    print(f"{model_name:<14} {trace:<14} {fam:<10} "
                          f"{r.ttft_p95 * 1e3:>11.1f} "
                          f"{r.tpot_p95 * 1e3:>11.2f} "
                          f"{r.e2e_latency:>8.1f}  {r.plan_label[:72]}")
            # the disaggregation claim: strictly better TTFT p95 than the
            # best colocated plan at comparable TPOT p95
            claim = None
            for d in pareto(disagg):
                comparable = [c for c in coloc
                              if c.tpot_p95 <= d.tpot_p95 * TPOT_TOL]
                if not comparable:
                    continue
                best_c = min(comparable, key=lambda c: c.ttft_p95)
                if d.ttft_p95 < best_c.ttft_p95:
                    claim = (d, best_c)
                    break
            if claim:
                d, c = claim
                wins += 1
                print(f"{'':<14} {'':<14} >> disagg wins TTFT: "
                      f"{d.ttft_p95 * 1e3:.1f}ms vs {c.ttft_p95 * 1e3:.1f}ms "
                      f"at TPOT {d.tpot_p95 * 1e3:.2f} vs "
                      f"{c.tpot_p95 * 1e3:.2f}ms")
            else:
                print(f"{'':<14} {'':<14} >> no disagg TTFT win at "
                      f"comparable TPOT")
    print(f"# disagg TTFT wins at comparable TPOT: {wins}/"
          f"{len(MODELS) * len(TRACES)} (model, trace) points")
    return wins


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--gpus-per-node", type=int, default=8)
    args = ap.parse_args()
    raise SystemExit(0 if run(requests=args.requests, nodes=args.nodes,
                              gpus_per_node=args.gpus_per_node) > 0 else 1)
