"""Resilience benchmark: does fault-aware search pick a different plan?

The nominal ``latency`` objective loves wide tensor parallelism — one
big DP-1 replica is the fastest healthy deployment.  But a single
machine failure takes ALL of a DP-1 plan's capacity with it, while a
DP-2 plan keeps serving at half rate.  This benchmark runs the exact
search twice on one (model, trace) point — once ranking by nominal
``latency``, once by ``degraded_goodput`` under a seeded single-machine
fault ensemble — and reports the headline divergence: the resilient
winner is a plan the nominal search rejects.

Also times the multi-fidelity degraded-goodput search (fluid screen and
halving rungs stay fault-free; only the confirmed finalists pay for the
faulted re-simulations) and records what the ensemble costs relative to
the nominal sweep.

Writes ``BENCH_faults.json`` next to the repo root:

    PYTHONPATH=src python benchmarks/bench_faults.py [--smoke] [--jobs N]
                                                     [--out PATH]

``--smoke`` shrinks the model for CI and additionally ASSERTS the
subsystem's load-bearing properties: the faulted report diverges from
the no-fault report, the seeded ensemble replays bit-identically (even
across ``--jobs 2`` forked evaluation), and the two objectives disagree
on the winner.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform
import time

from repro.core import (ApexSearch, MultiFidelitySearch, fault_ensemble,
                        get_trace, h100_node, ir_from_hf_config)

SMOKE_CFG = dict(hidden_size=256, num_hidden_layers=4,
                 num_attention_heads=8, num_key_value_heads=4,
                 intermediate_size=1024, vocab_size=1024)
FULL_CFG = dict(hidden_size=2048, num_hidden_layers=16,
                num_attention_heads=16, num_key_value_heads=8,
                intermediate_size=8192, vocab_size=32000)


def build(smoke: bool):
    """(search, requests, ensemble): a trace long enough to straddle the
    fault windows, and a 3-member seeded ensemble in which only replica
    index 0 fails — the single-machine-outage scenario that separates
    one big replica from several smaller ones."""
    if smoke:
        model = ir_from_hf_config(SMOKE_CFG, name="tiny")
        n_req, rate = 24, 16.0
    else:
        model = ir_from_hf_config(FULL_CFG, name="tiny-7b")
        n_req, rate = 48, 32.0
    cluster = h100_node(8)
    reqs = get_trace("summarization", arrival_rate=rate, seed=3,
                     num_requests=n_req)
    horizon = n_req / rate
    ens = fault_ensemble(11, 3, horizon_s=horizon, n_replicas=1,
                         pool="serve", replica_mtbf_s=horizon / 2,
                         replica_mttr_s=horizon)
    return ApexSearch(model, cluster), reqs, ens


def report_row(rep):
    row = {
        "plan": rep.plan_label,
        "nominal_goodput_rps": round(rep.goodput_rps, 3),
        "ttft_p95_ms": round(rep.ttft_p95 * 1e3, 2),
        "e2e_s": round(rep.e2e_latency, 3),
    }
    if rep.resilience is not None:
        r = rep.resilience
        row["faulted"] = {
            "availability": round(r.availability, 3),
            "goodput_rps": round(r.goodput_rps, 3),
            "degraded_window_goodput_rps":
                round(r.degraded_window_goodput_rps, 3),
            "requeued": r.requests_requeued,
            "dropped": r.requests_dropped,
            "ttft_p95_degraded_ms": round(r.ttft_p95_degraded * 1e3, 2),
        }
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizing for CI, plus correctness asserts")
    ap.add_argument("--jobs", type=int, default=1,
                    help="forked workers for the exact sweeps")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args()

    search, reqs, ens = build(args.smoke)

    t0 = time.perf_counter()
    lat = search.search(reqs, objective="latency", max_model_dp=2,
                        jobs=args.jobs)
    lat_s = round(time.perf_counter() - t0, 3)
    t0 = time.perf_counter()
    dg = search.search(reqs, objective="degraded_goodput", faults=ens,
                       max_model_dp=2, jobs=args.jobs)
    dg_s = round(time.perf_counter() - t0, 3)

    # the nominal winner's own faulted report, for the side-by-side
    lat_under_faults = next(r for r in dg.all_reports
                            if r.plan_label == lat.best.plan_label)

    t0 = time.perf_counter()
    mres = MultiFidelitySearch(search).search(
        reqs, objective="degraded_goodput", faults=ens, max_model_dp=2,
        jobs=args.jobs)
    mf_s = round(time.perf_counter() - t0, 3)

    diverged = dg.best.plan_label != lat.best.plan_label
    out = {
        "bench": "bench_faults",
        "smoke": args.smoke,
        "jobs": args.jobs,
        "n_requests": len(reqs),
        "ensemble_size": len(ens),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "num_candidates": dg.num_schemes,
        "latency_optimal": report_row(lat_under_faults),
        "degraded_goodput_optimal": report_row(dg.best),
        "winners_diverge": diverged,
        "resilience_gain_rps": round(
            dg.best.resilience.goodput_rps
            - lat_under_faults.resilience.goodput_rps, 3),
        "exact_seconds": {"latency": lat_s, "degraded_goodput": dg_s},
        "multifid": {
            "total_seconds": mf_s,
            "screen_seconds": round(mres.screen_seconds, 3),
            "confirm_seconds": round(mres.confirm_seconds, 3),
            "num_survivors": mres.num_survivors,
            "best": mres.best.plan_label,
            "agrees_with_exact":
                mres.best.plan_label == dg.best.plan_label,
        },
    }

    if args.smoke:
        # no-fault vs fault divergence: the faulted re-simulation must
        # actually change the winner's measured service
        res = lat_under_faults.resilience
        assert res is not None and res.availability < 1.0
        assert res.goodput_rps < lat_under_faults.goodput_rps
        # seeded-ensemble determinism (fresh context, same jobs setting)
        s2, reqs2, ens2 = build(args.smoke)
        dg2 = s2.search(reqs2, objective="degraded_goodput", faults=ens2,
                        max_model_dp=2, jobs=args.jobs)
        assert [dataclasses.asdict(r) for r in dg.all_reports] == \
            [dataclasses.asdict(r) for r in dg2.all_reports], \
            "seeded fault ensemble must replay bit-identically"
        # the headline: resilience-aware search picks a different plan
        assert diverged, (lat.best.plan_label, dg.best.plan_label)
        print("smoke asserts passed: fault divergence, seeded "
              f"determinism (jobs={args.jobs}), winner divergence")

    path = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_faults.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)

    print(f"latency optimal:   {out['latency_optimal']['plan']}")
    print(f"  under faults: {lat_under_faults.resilience.summary()}")
    print(f"resilient optimal: {out['degraded_goodput_optimal']['plan']}")
    print(f"  under faults: {dg.best.resilience.summary()}")
    print(f"winners diverge: {diverged}, resilience gain "
          f"{out['resilience_gain_rps']} req/s")
    m = out["multifid"]
    print(f"multifid[degraded_goodput]: {out['num_candidates']} -> "
          f"{m['num_survivors']} survivors in {m['total_seconds']}s, "
          f"agrees with exact={m['agrees_with_exact']}")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
