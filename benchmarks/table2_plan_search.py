"""Table 2 reproduction: end-to-end latency of Baseline vs Feasible
Optimal vs APEX Optimal — 3 traces x 3 models x 2 arrival rates on the
single-node H100 cluster.

Calibration note (EXPERIMENTS.md §Plan-search): our profiling tables are
analytic (no GPU-hours profiling run), so arrival rates are scaled to the
same utilization regime the paper operates in (the cluster near
saturation, where plan choice governs throughput).  Speedup STRUCTURE is
the reproduced quantity; the paper's absolute seconds depend on its
measured tables.
"""

from __future__ import annotations

from repro.core import ApexSearch, get_trace, h100_node

from .common import Timer, csv_row, model_ir

# (trace, arrival rates scaled to saturate the analytic H100 model)
SETTINGS = [
    ("summarization", (3.0, 6.0)),
    ("creation", (6.0, 12.0)),
    ("chat", (16.0, 32.0)),
]
MODELS = ["llama-3.1-70b", "mistral-large-123b", "mixtral-8x22b"]


def run(num_requests: int = 96, quant=None, quick: bool = False):
    cluster = h100_node(8)
    rows = []
    models = MODELS[:1] if quick else MODELS
    for name in models:
        model = model_ir(name)
        q = quant or ("w8a8" if name == "mistral-large-123b" else "fp16")
        search = ApexSearch(model, cluster)
        for trace, rates in (SETTINGS[:1] if quick else SETTINGS):
            for rate in (rates[:1] if quick else rates):
                reqs = get_trace(trace, arrival_rate=rate,
                                 num_requests=num_requests)
                with Timer() as t:
                    base = search.evaluate_baseline(reqs, quant=q)
                    feas = search.search(reqs, quant=q, feasible_only=True)
                    full = search.search(reqs, quant=q, feasible_only=False)
                fs = base.e2e_latency / feas.best.e2e_latency
                xs = base.e2e_latency / full.best.e2e_latency
                rows.append(dict(
                    model=name, trace=trace, rate=rate, quant=q,
                    baseline_s=base.e2e_latency,
                    feasible_s=feas.best.e2e_latency,
                    apex_s=full.best.e2e_latency,
                    feasible_speedup=fs, apex_speedup=xs,
                    best_plan=full.best.plan_label,
                    schemes=full.num_schemes,
                    search_s=t.seconds))
                csv_row(f"table2/{name}/{trace}/r{rate}",
                        t.seconds * 1e6,
                        f"feas={fs:.2f}x apex={xs:.2f}x "
                        f"plan={full.best.plan_label}")
    return rows


if __name__ == "__main__":
    run()
