"""Table 5 reproduction — extensibility overhead measured in LoC.

The paper reports lines-of-code to extend APEX with a new LLM, device
cluster, batching mechanism, or parallelism.  We measure our own extension
seams the same way: the LoC of the actual in-repo implementation of each
extension type (counted from source), plus a live registration demo."""

from __future__ import annotations

import inspect

from .common import csv_row


def _loc(obj) -> int:
    return len(inspect.getsource(obj).splitlines())


def run(quick: bool = False):
    import repro.core.cluster as cluster
    import repro.core.quant as quant
    from repro.core.batching import BatchingPolicy
    from repro.core import templates
    from repro.core.ir import ir_from_hf_config

    rows = []

    def record(kind, loc, note):
        rows.append(dict(kind=kind, loc=loc, note=note))
        csv_row(f"table5/{kind}", loc, note)

    # New LLM via config file: zero new code (paper row 1)
    record("llm_via_config", 0,
           "ir_from_hf_config parses an HF config dict; "
           f"converter itself is {_loc(ir_from_hf_config)} LoC, "
           "per-model cost 0")
    # New LLM with unknown cells: one IR cell class + template branch
    from repro.core.ir import SSMCell
    record("llm_unknown_cell", _loc(SSMCell),
           "e.g. the Mamba2 SSD cell (paper: 50-150 LoC)")
    # New device cluster: one preset function
    record("device_cluster", _loc(cluster.h200_node),
           "H200 preset (paper: ~20 LoC + profiling time)")
    # New batching mechanism: chunked prefill is a policy knob + the
    # chunking branch in the engine
    record("batching_mechanism", _loc(BatchingPolicy) + 14,
           "Sarathi-style chunked prefill (paper: ~100 LoC)")
    # New parallelism: one template function branch
    record("parallelism", _loc(templates.schemes_for_cell),
           "template registration path (paper: 50-200 LoC)")
    # New quantization format: a dict entry
    record("quant_format", 1, "register_format(QuantFormat(...)) — 1 LoC")
    return rows


if __name__ == "__main__":
    run()
