"""Collective-communication cost models (paper §3.5, profiled offline there).

The paper's Offline Profiler measures AllReduce/ReduceScatter/... across data
sizes, device counts and node counts.  We model the same operations with
standard ring/tree algorithm cost formulas parameterized by the cluster's
per-level bandwidth/latency (core/cluster.py).  These analytic curves *are*
the profiling tables' generator (core/profiles.py wraps them in the paper's
grid-plus-linear-interpolation mechanism), and they are cross-checked against
the collective bytes parsed out of real compiled XLA HLO in
tests/test_hlo_analysis.py.

All functions return seconds for ONE collective over ``nbytes`` of payload
(payload = the logical tensor size; algorithm-induced traffic expansion is
applied inside).
"""

from __future__ import annotations

import math

from .cluster import Cluster, NetworkLevel


def _level(cluster: Cluster, group_size: int) -> NetworkLevel:
    return cluster.level_for_group(group_size)


def all_reduce_time(nbytes: float, group_size: int, cluster: Cluster) -> float:
    """Ring all-reduce: 2*(n-1)/n * bytes per device over the bottleneck level."""
    if group_size <= 1 or nbytes <= 0:
        return 0.0
    lvl = _level(cluster, group_size)
    traffic = 2.0 * (group_size - 1) / group_size * nbytes
    return (traffic / lvl.bw_per_device + lvl.launch_s
            + 2 * (group_size - 1) * lvl.latency_s)


def all_gather_time(nbytes: float, group_size: int, cluster: Cluster) -> float:
    """Ring all-gather of a total of ``nbytes`` (gathered output size)."""
    if group_size <= 1 or nbytes <= 0:
        return 0.0
    lvl = _level(cluster, group_size)
    traffic = (group_size - 1) / group_size * nbytes
    return (traffic / lvl.bw_per_device + lvl.launch_s
            + (group_size - 1) * lvl.latency_s)


def reduce_scatter_time(nbytes: float, group_size: int, cluster: Cluster) -> float:
    """Ring reduce-scatter of a ``nbytes`` input per device."""
    if group_size <= 1 or nbytes <= 0:
        return 0.0
    lvl = _level(cluster, group_size)
    traffic = (group_size - 1) / group_size * nbytes
    return (traffic / lvl.bw_per_device + lvl.launch_s
            + (group_size - 1) * lvl.latency_s)


def all_to_all_time(nbytes: float, group_size: int, cluster: Cluster) -> float:
    """All-to-all where each device exchanges ``nbytes`` total payload.

    Each device sends (n-1)/n of its payload; on a ring/tree this is the
    cheapest of the big collectives — the reason the paper's simulator
    predicts EP (all-to-all) beating TP (all-reduce) for MoE (Fig. 6
    discussion).
    """
    if group_size <= 1 or nbytes <= 0:
        return 0.0
    lvl = _level(cluster, group_size)
    traffic = (group_size - 1) / group_size * nbytes
    return (traffic / lvl.bw_per_device + lvl.launch_s
            + (group_size - 1) * lvl.latency_s)


def p2p_time(nbytes: float, src_group: int, cluster: Cluster) -> float:
    """Point-to-point send (pipeline-stage boundary).

    ``src_group`` is the span (in devices) of the two communicating stages —
    the Device Mapper places adjacent stages as close as possible, and the
    level is determined by that span.
    """
    if nbytes <= 0:
        return 0.0
    lvl = _level(cluster, max(2, src_group))
    return nbytes / lvl.bw_per_device + lvl.launch_s + lvl.latency_s


def broadcast_time(nbytes: float, group_size: int, cluster: Cluster) -> float:
    """Binomial-tree broadcast."""
    if group_size <= 1 or nbytes <= 0:
        return 0.0
    lvl = _level(cluster, group_size)
    hops = math.ceil(math.log2(group_size))
    return (hops * (nbytes / lvl.bw_per_device) + lvl.launch_s
            + hops * lvl.latency_s)


COLLECTIVE_FNS = {
    "all_reduce": all_reduce_time,
    "all_gather": all_gather_time,
    "reduce_scatter": reduce_scatter_time,
    "all_to_all": all_to_all_time,
    "broadcast": broadcast_time,
}


def collective_time(kind: str, nbytes: float, group_size: int,
                    cluster: Cluster) -> float:
    """Dispatch by collective kind (extensibility hook: register new kinds
    by adding to COLLECTIVE_FNS — 'new parallelism' row of paper Table 5)."""
    try:
        fn = COLLECTIVE_FNS[kind]
    except KeyError:
        raise KeyError(
            f"unknown collective {kind!r}; known: {sorted(COLLECTIVE_FNS)}"
        ) from None
    return fn(nbytes, group_size, cluster)
