"""Transformer IR — APEX's canonical model representation (paper §3.2.1).

An LLM is represented as identical *blocks*; a block is a chain of *cells*
(attention, MLP, MoE, SSM, ...); a cell contains parallel *tasks* (heads,
experts).  The IR deliberately abstracts away tokenization / position
embeddings ("less relevant for model parallelization") and exposes exactly
what the Parallel Templates and the Serving Simulator need:

  * per-cell weight bytes (quantization-aware),
  * per-cell KV-cache / recurrent-state bytes,
  * per-cell compute decomposed into profile-able operations (GEMM,
    attention prefill/decode, SSD scan), mirroring the paper's
    operation-level profiling (§3.5),
  * the number of shardable tasks per cell.

Blocks let the simulator evaluate ONE block and extrapolate to the full
model (paper Fig. 8's trillion-scale scalability).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

from .quant import QuantFormat


# ---------------------------------------------------------------------------
# Operation calls — the unit the profiling store is queried with
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OpCall:
    """One profile-able operation instance.

    ``op``    : profile family ("gemm", "attn_prefill", "attn_decode",
                "ssd_scan", ...)
    ``axes``  : the profile grid's fixed configuration axes (e.g. n,k of a
                GEMM; heads/head_dim of attention) — the paper profiles
                "across various context lengths, attention heads, hidden
                dimensions".
    ``x``     : the interpolation variable (e.g. GEMM m-dim = token count).
    ``flops`` / ``bytes``: ground-truth work estimates for the WHOLE call
                (all ``count`` repetitions); used by analytic profile
                backends and by MFU/MBU metric computation.
    ``count`` : how many times this exact operation runs back-to-back
                (e.g. one GEMM per activated MoE expert); the simulator
                multiplies the per-op profiled time by ``count``.
    """

    op: str
    axes: tuple
    x: float
    flops: float
    bytes: float
    count: float = 1.0

    def scaled(self, factor: float) -> "OpCall":
        return dataclasses.replace(
            self, flops=self.flops * factor, bytes=self.bytes * factor
        )


def _window_area(q_len: int, kv_end: int, window: Optional[int]) -> float:
    """Sum over the chunk's query positions of their attended KV length.

    Queries are positions kv_end-q_len .. kv_end-1 (0-based); query at
    position p attends min(p+1, window) keys.  Closed form of
    sum_{p=a..b} min(p, W) with a=kv_end-q_len+1, b=kv_end.
    """
    a, b = kv_end - q_len + 1, kv_end
    if a > b:
        return 0.0
    if window is None or b <= window:
        return (a + b) * (b - a + 1) / 2.0
    w = window
    if a > w:
        return float(w) * (b - a + 1)
    # split: a..w triangular, w+1..b flat
    tri = (a + w) * (w - a + 1) / 2.0
    flat = float(w) * (b - w)
    return tri + flat


@dataclasses.dataclass(frozen=True)
class Workload:
    """What one serving iteration asks of one cell chain (per replica).

    The Batching Module aggregates the active batch into window-resolved
    attention work so each cell reads its own sliding-window variant
    exactly.  ``windows`` maps window size -> (prefill_qk, decode_kv):
      * prefill_qk : sum over prefill chunks of the window-clamped
                     attention area (see ``_window_area``).
      * decode_kv  : sum over decode requests of min(kv_len, window).
    The key ``None`` holds the unwindowed (full-attention) aggregates.

    Encoder-decoder extras: ``encoder_tokens`` = source tokens entering the
    encoder this iteration; ``cross_prefill_qk`` / ``cross_decode_kv`` =
    query-x-source attention work against the (fixed-length) encoder memory.
    """

    prefill_tokens: int = 0
    decode_tokens: int = 0
    batch_sequences: int = 0
    windows: dict = dataclasses.field(default_factory=dict)
    encoder_tokens: int = 0
    cross_prefill_qk: float = 0.0
    cross_decode_kv: float = 0.0

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    def prefill_qk(self, window: Optional[int]) -> float:
        entry = self.windows.get(window)
        if entry is None:
            entry = self.windows.get(None, (0.0, 0.0))
        return entry[0]

    def decode_kv(self, window: Optional[int]) -> float:
        entry = self.windows.get(window)
        if entry is None:
            entry = self.windows.get(None, (0.0, 0.0))
        return entry[1]

    def is_empty(self) -> bool:
        return self.total_tokens == 0 and self.encoder_tokens == 0

    def signature(self) -> tuple:
        """Hashable identity for step-cost memoization: two workloads with
        equal signatures cost identically under any deterministic model."""
        return (self.prefill_tokens, self.decode_tokens,
                self.batch_sequences, self.encoder_tokens,
                self.cross_prefill_qk, self.cross_decode_kv,
                tuple(sorted(self.windows.items(),
                             key=lambda kv: (kv[0] is None, kv[0] or 0))))

    @staticmethod
    def from_batch(prefill_chunks: Sequence, decode_kv_lens: Sequence,
                   model_windows: Sequence, batch_sequences: int = 0,
                   encoder_tokens: int = 0,
                   prefill_source: Sequence = (),
                   decode_source: Sequence = ()) -> "Workload":
        """Build a Workload from raw batch state.

        ``prefill_chunks``: iterable of (q_len, kv_end) pairs.
        ``decode_kv_lens``: iterable of current KV lengths.
        ``model_windows`` : the distinct window sizes the model's cells use
                            (None for full attention).
        ``prefill_source``/``decode_source``: per-request encoder-memory
        lengths for cross-attention models.
        """
        pre_tok = sum(q for q, _ in prefill_chunks)
        windows = {}
        for wnd in set(list(model_windows) + [None]):
            qk = sum(_window_area(q, kv, wnd) for q, kv in prefill_chunks)
            if wnd is None:
                dkv = float(sum(decode_kv_lens))
            else:
                dkv = float(sum(min(k, wnd) for k in decode_kv_lens))
            windows[wnd] = (qk, dkv)
        cross_pre = sum(q * s for (q, _), s in zip(prefill_chunks,
                                                   prefill_source))
        cross_dec = float(sum(decode_source))
        return Workload(prefill_tokens=int(pre_tok),
                        decode_tokens=len(decode_kv_lens),
                        batch_sequences=batch_sequences,
                        windows=windows,
                        encoder_tokens=int(encoder_tokens),
                        cross_prefill_qk=float(cross_pre),
                        cross_decode_kv=cross_dec)

    def divided(self, dp: int) -> "Workload":
        """Per-replica slice under cell-level DP (even token split)."""
        if dp == 1:
            return self
        windows = {k: (qk / dp, dkv / dp)
                   for k, (qk, dkv) in self.windows.items()}
        return Workload(
            prefill_tokens=-(-self.prefill_tokens // dp),
            decode_tokens=-(-self.decode_tokens // dp),
            batch_sequences=-(-self.batch_sequences // dp),
            windows=windows,
            encoder_tokens=-(-self.encoder_tokens // dp),
            cross_prefill_qk=self.cross_prefill_qk / dp,
            cross_decode_kv=self.cross_decode_kv / dp,
        )


# ---------------------------------------------------------------------------
# Cells
# ---------------------------------------------------------------------------

class Cell:
    """Base class for IR cells.

    A cell exposes:
      name, kind, num_tasks (shardable units), weight_params (scalar count),
      kv_bytes_per_token(q), state_bytes_per_seq(q),
      compute(workload, q)  -> list[OpCall]
      activation_bytes_per_token(q) -> resharding payload between cells

    Subclasses are frozen dataclasses declaring ``name`` and ``kind`` fields
    (deliberately not declared here — a base-class default would leak into
    subclass dataclass field ordering).
    """

    @property
    def num_tasks(self) -> int:
        raise NotImplementedError

    def weight_params(self) -> float:
        raise NotImplementedError

    def weight_bytes(self, q: QuantFormat) -> float:
        return self.weight_params() * q.weight_bytes

    def kv_bytes_per_token(self, q: QuantFormat) -> float:
        return 0.0

    def state_bytes_per_seq(self, q: QuantFormat) -> float:
        return 0.0

    def activation_bytes_per_token(self, q: QuantFormat) -> float:
        raise NotImplementedError

    def compute(self, w: Workload, q: QuantFormat) -> List[OpCall]:
        raise NotImplementedError

    # -- shared helpers ------------------------------------------------------

    @staticmethod
    def _gemm(m: float, n: int, k: int, q: QuantFormat,
              weight_shards: int = 1) -> OpCall:
        """A (m x k) @ (k x n) GEMM; ``weight_shards`` divides n (or k) when a
        template has already split the weight — callers pass post-sharding
        dims, this helper is for unsharded cell math."""
        flops = 2.0 * m * n * k
        mem = (m * k + m * n) * q.act_bytes + n * k * q.weight_bytes
        return OpCall("gemm", axes=(n, k, q.compute_dtype), x=float(m),
                      flops=flops, bytes=mem)


@dataclasses.dataclass(frozen=True)
class AttentionCell(Cell):
    """MHA / GQA / sliding-window attention (optionally with QKV bias).

    Task = query head (the paper's Fig. 5 distributes heads across devices).
    """

    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    window: Optional[int] = None        # sliding-window size (Mixtral, Gemma3)
    rope: str = "rope"                  # "rope" | "mrope" | "none"
    kind: str = "attn"

    @property
    def num_tasks(self) -> int:
        return self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def weight_params(self) -> float:
        p = self.d_model * self.q_dim          # W_q
        p += 2 * self.d_model * self.kv_dim    # W_k, W_v
        p += self.q_dim * self.d_model         # W_o
        if self.qkv_bias:
            p += self.q_dim + 2 * self.kv_dim
        return float(p)

    def kv_bytes_per_token(self, q: QuantFormat) -> float:
        return 2.0 * self.kv_dim * q.kv_bytes

    def activation_bytes_per_token(self, q: QuantFormat) -> float:
        return self.d_model * q.act_bytes

    def compute(self, w: Workload, q: QuantFormat) -> List[OpCall]:
        ops: List[OpCall] = []
        t = w.total_tokens
        if t == 0:
            return ops
        # Projections: fused QKV GEMM + output GEMM over all tokens.
        ops.append(self._gemm(t, self.q_dim + 2 * self.kv_dim, self.d_model, q))
        ops.append(self._gemm(t, self.d_model, self.q_dim, q))
        # Prefill attention: score+value matmuls, 4 * qk * heads * head_dim
        # FLOPs total (2 matmuls x 2 flops each), window-exact.
        qk = w.prefill_qk(self.window)
        if qk > 0:
            flops = 4.0 * qk * self.n_heads * self.head_dim
            mem = (2 * w.prefill_tokens * self.q_dim * q.act_bytes
                   + 2 * w.prefill_tokens * self.kv_dim * q.kv_bytes)
            ops.append(OpCall("attn_prefill",
                              axes=(self.n_heads, self.head_dim,
                                    q.compute_dtype),
                              x=float(qk), flops=flops, bytes=mem))
        # Decode attention: memory-bound read of every active request's
        # (window-clamped) KV cache.
        if w.decode_tokens > 0:
            kv_tok = w.decode_kv(self.window)
            flops = 4.0 * kv_tok * self.n_heads * self.head_dim
            mem = kv_tok * self.kv_bytes_per_token(q)
            ops.append(OpCall("attn_decode",
                              axes=(self.n_kv_heads, self.head_dim,
                                    q.compute_dtype),
                              x=float(kv_tok), flops=flops, bytes=mem))
        return ops


@dataclasses.dataclass(frozen=True)
class MLACell(Cell):
    """Multi-head Latent Attention (DeepSeek-V2).

    KV is compressed into a rank-``kv_lora_rank`` latent (+ a shared RoPE
    key); the cache stores the latent, not per-head K/V — the decisive
    memory advantage the simulator must model.  The latent is NOT
    head-sharded: TP shards query heads and the up-projections, while each
    device holds the full latent cache (see templates.py).
    """

    name: str
    d_model: int
    n_heads: int
    kv_lora_rank: int
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    kind: str = "mla"

    @property
    def num_tasks(self) -> int:
        return self.n_heads

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim

    def weight_params(self) -> float:
        p = self.d_model * self.n_heads * self.qk_head_dim            # W_q
        p += self.d_model * (self.kv_lora_rank + self.qk_rope_head_dim)  # W_dkv
        p += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim
                                                 + self.v_head_dim)   # W_ukv
        p += self.n_heads * self.v_head_dim * self.d_model            # W_o
        return float(p)

    def kv_bytes_per_token(self, q: QuantFormat) -> float:
        return (self.kv_lora_rank + self.qk_rope_head_dim) * q.kv_bytes

    def activation_bytes_per_token(self, q: QuantFormat) -> float:
        return self.d_model * q.act_bytes

    def compute(self, w: Workload, q: QuantFormat) -> List[OpCall]:
        ops: List[OpCall] = []
        t = w.total_tokens
        if t == 0:
            return ops
        ops.append(self._gemm(t, self.n_heads * self.qk_head_dim,
                              self.d_model, q))                      # W_q
        ops.append(self._gemm(t, self.kv_lora_rank + self.qk_rope_head_dim,
                              self.d_model, q))                      # W_dkv
        ops.append(self._gemm(t, self.n_heads * (self.qk_nope_head_dim
                                                 + self.v_head_dim),
                              self.kv_lora_rank, q))                 # W_ukv
        ops.append(self._gemm(t, self.d_model,
                              self.n_heads * self.v_head_dim, q))    # W_o
        qk = w.prefill_qk(None)
        if qk > 0:
            flops = 2.0 * qk * self.n_heads * (
                self.qk_head_dim + self.v_head_dim)
            mem = 2 * w.prefill_tokens * self.n_heads * self.qk_head_dim \
                * q.act_bytes
            ops.append(OpCall("attn_prefill",
                              axes=(self.n_heads, self.qk_head_dim,
                                    q.compute_dtype),
                              x=float(qk), flops=flops, bytes=mem))
        if w.decode_tokens > 0:
            kv_tok = w.decode_kv(None)
            # Absorbed-matmul decode: score against the latent directly.
            flops = 2.0 * kv_tok * self.n_heads * (
                self.kv_lora_rank + self.qk_rope_head_dim + self.v_head_dim)
            mem = kv_tok * self.kv_bytes_per_token(q)
            ops.append(OpCall("attn_decode",
                              axes=(self.n_heads, self.kv_lora_rank,
                                    q.compute_dtype),
                              x=float(kv_tok), flops=flops, bytes=mem))
        return ops


@dataclasses.dataclass(frozen=True)
class CrossAttentionCell(Cell):
    """Encoder-decoder cross-attention (Seamless-M4T decoder).

    K/V come from the encoder memory and are computed ONCE per request
    (at prefill); decode steps only read them.
    """

    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    source_len: int                  # encoder memory length (trace-provided)
    kind: str = "cross_attn"

    @property
    def num_tasks(self) -> int:
        return self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def weight_params(self) -> float:
        return float(self.d_model * self.q_dim + 2 * self.d_model * self.kv_dim
                     + self.q_dim * self.d_model)

    def kv_bytes_per_token(self, q: QuantFormat) -> float:
        # Cross-attn KV is per-SOURCE-token; accounted via state_bytes.
        return 0.0

    def state_bytes_per_seq(self, q: QuantFormat) -> float:
        return 2.0 * self.kv_dim * q.kv_bytes * self.source_len

    def activation_bytes_per_token(self, q: QuantFormat) -> float:
        return self.d_model * q.act_bytes

    def compute(self, w: Workload, q: QuantFormat) -> List[OpCall]:
        ops: List[OpCall] = []
        t = w.total_tokens
        if t == 0:
            return ops
        ops.append(self._gemm(t, self.q_dim, self.d_model, q))    # W_q
        ops.append(self._gemm(t, self.d_model, self.q_dim, q))    # W_o
        if w.encoder_tokens > 0:
            # K/V projection of new encoder memory, once per request.
            ops.append(self._gemm(w.encoder_tokens, 2 * self.kv_dim,
                                  self.d_model, q))
        if w.cross_prefill_qk > 0:
            flops = 4.0 * w.cross_prefill_qk * self.n_heads * self.head_dim
            mem = 2 * w.prefill_tokens * self.q_dim * q.act_bytes
            ops.append(OpCall("attn_prefill",
                              axes=(self.n_heads, self.head_dim,
                                    q.compute_dtype),
                              x=float(w.cross_prefill_qk), flops=flops,
                              bytes=mem))
        if w.cross_decode_kv > 0:
            flops = 4.0 * w.cross_decode_kv * self.n_heads * self.head_dim
            mem = w.cross_decode_kv * 2 * self.kv_dim * q.kv_bytes
            ops.append(OpCall("attn_decode",
                              axes=(self.n_kv_heads, self.head_dim,
                                    q.compute_dtype),
                              x=float(w.cross_decode_kv), flops=flops,
                              bytes=mem))
        return ops


@dataclasses.dataclass(frozen=True)
class MLPCell(Cell):
    """Dense feed-forward: 2-matrix (GELU) or 3-matrix gated (SwiGLU)."""

    name: str
    d_model: int
    d_ff: int
    gated: bool = True
    kind: str = "mlp"

    @property
    def num_tasks(self) -> int:
        # Task = a d_ff column group; templates shard d_ff.
        return self.d_ff

    @property
    def num_mats(self) -> int:
        return 3 if self.gated else 2

    def weight_params(self) -> float:
        return float(self.num_mats * self.d_model * self.d_ff)

    def activation_bytes_per_token(self, q: QuantFormat) -> float:
        return self.d_model * q.act_bytes

    def compute(self, w: Workload, q: QuantFormat) -> List[OpCall]:
        t = w.total_tokens
        if t == 0:
            return []
        up_n = (2 if self.gated else 1) * self.d_ff
        return [
            self._gemm(t, up_n, self.d_model, q),
            self._gemm(t, self.d_model, self.d_ff, q),
        ]


@dataclasses.dataclass(frozen=True)
class MoECell(Cell):
    """Mixture-of-Experts FFN with top-k routing (+ optional shared experts).

    Task = expert (the paper's EP distributes experts across devices).
    """

    name: str
    d_model: int
    d_ff_expert: int
    n_routed: int
    top_k: int
    n_shared: int = 0
    gated: bool = True
    kind: str = "moe"

    @property
    def num_tasks(self) -> int:
        return self.n_routed

    @property
    def num_mats(self) -> int:
        return 3 if self.gated else 2

    def expert_params(self) -> float:
        return float(self.num_mats * self.d_model * self.d_ff_expert)

    def weight_params(self) -> float:
        router = self.d_model * self.n_routed
        return (self.n_routed + self.n_shared) * self.expert_params() + router

    @property
    def active_experts_per_token(self) -> int:
        return self.top_k + self.n_shared

    def activation_bytes_per_token(self, q: QuantFormat) -> float:
        return self.d_model * q.act_bytes

    def compute(self, w: Workload, q: QuantFormat) -> List[OpCall]:
        t = w.total_tokens
        if t == 0:
            return []
        # Single-device case; templates.moe_expert_gemms handles sharding
        # (import deferred: templates depends on ir).
        from .templates import moe_expert_gemms
        ops = [self._gemm(t, self.n_routed, self.d_model, q)]   # router
        ops += moe_expert_gemms(self, float(t * self.top_k), self.n_routed,
                                1, q)
        if self.n_shared:
            ops += moe_expert_gemms(self, float(t * self.n_shared),
                                    self.n_shared, 1, q, all_activated=True)
        return ops


@dataclasses.dataclass(frozen=True)
class SSMCell(Cell):
    """Mamba2 SSD (state-space duality) mixer — attention-free.

    Task = SSD head.  Per-sequence recurrent state is O(1) in context
    length: heads * head_dim * d_state scalars (+ conv window) — the
    memory model that lets the simulator admit far more concurrent
    sequences than an attention arch (the point of long_500k).
    """

    name: str
    d_model: int
    d_inner: int
    d_state: int
    n_ssd_heads: int
    d_conv: int = 4
    n_groups: int = 1
    kind: str = "ssm"

    @property
    def num_tasks(self) -> int:
        return self.n_ssd_heads

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_ssd_heads

    def weight_params(self) -> float:
        in_n = (2 * self.d_inner + 2 * self.n_groups * self.d_state
                + self.n_ssd_heads)
        p = self.d_model * in_n                         # in_proj (x,z,B,C,dt)
        p += self.d_conv * (self.d_inner
                            + 2 * self.n_groups * self.d_state)  # conv1d
        p += self.d_inner * self.d_model                # out_proj
        p += 2 * self.n_ssd_heads + self.d_inner        # A, dt_bias, D
        return float(p)

    def state_bytes_per_seq(self, q: QuantFormat) -> float:
        ssm = self.n_ssd_heads * self.head_dim * self.d_state
        conv = self.d_conv * (self.d_inner + 2 * self.n_groups * self.d_state)
        # Recurrent state is kept in fp32 for stability (matches kernels/).
        return float(ssm * 4 + conv * q.act_bytes)

    def activation_bytes_per_token(self, q: QuantFormat) -> float:
        return self.d_model * q.act_bytes

    def compute(self, w: Workload, q: QuantFormat) -> List[OpCall]:
        t = w.total_tokens
        if t == 0:
            return []
        in_n = (2 * self.d_inner + 2 * self.n_groups * self.d_state
                + self.n_ssd_heads)
        ops = [
            self._gemm(t, in_n, self.d_model, q),
            self._gemm(t, self.d_model, self.d_inner, q),
        ]
        # SSD scan: state update + readout, 6 * t * d_inner * d_state FLOPs
        # (B-weighted outer-product update, C readout, decay).
        flops = 6.0 * t * self.d_inner * self.d_state
        mem = t * self.d_inner * q.act_bytes * 2
        if w.decode_tokens > 0:
            # decode reads+writes the full state per sequence
            mem += w.batch_sequences * self.state_bytes_per_seq(q)
        ops.append(OpCall("ssd_scan",
                          axes=(self.d_inner, self.d_state, q.compute_dtype),
                          x=float(t), flops=flops, bytes=mem))
        return ops


# ---------------------------------------------------------------------------
# Blocks and models
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Block:
    """The smallest non-repetitive chain of adjacent cells (paper §3.2.1)."""

    cells: tuple          # tuple[Cell, ...]
    repeat: int           # how many times the block tiles the model

    def __post_init__(self):
        if self.repeat < 1:
            raise ValueError("block repeat must be >= 1")
        if not self.cells:
            raise ValueError("block needs at least one cell")

    def weight_bytes(self, q: QuantFormat) -> float:
        return sum(c.weight_bytes(q) for c in self.cells)

    def weight_bytes_scalars(self) -> float:
        """Total parameter count across all repeats of this block."""
        return sum(c.weight_params() for c in self.cells) * self.repeat

    def kv_bytes_per_token(self, q: QuantFormat) -> float:
        return sum(c.kv_bytes_per_token(q) for c in self.cells)

    def state_bytes_per_seq(self, q: QuantFormat) -> float:
        return sum(c.state_bytes_per_seq(q) for c in self.cells)

    def cell_types(self) -> list:
        """Distinct (kind, signature) groups — planner assigns one scheme
        per group to avoid exponential per-cell enumeration."""
        seen, out = {}, []
        for c in self.cells:
            key = (c.kind, c.name.rsplit(".", 1)[-1])
            if key not in seen:
                seen[key] = True
                out.append(key)
        return out


@dataclasses.dataclass(frozen=True)
class ModelIR:
    """A full model: embedding/head bytes + repeated blocks.

    ``encoder`` (optional) models encoder-decoder architectures: the encoder
    is its own block chain executed once per request at prefill.
    """

    name: str
    d_model: int
    vocab_size: int
    block: Block
    tie_embeddings: bool = False
    encoder: Optional[Block] = None

    # -- aggregates ----------------------------------------------------------

    def embed_params(self) -> float:
        mult = 1 if self.tie_embeddings else 2
        return float(mult * self.vocab_size * self.d_model)

    def total_params(self) -> float:
        p = self.embed_params()
        p += self.block.weight_bytes_scalars()
        if self.encoder is not None:
            p += self.encoder.weight_bytes_scalars()
        return p

    def weight_bytes(self, q: QuantFormat) -> float:
        b = self.embed_params() * q.weight_bytes
        b += self.block.weight_bytes(q) * self.block.repeat
        if self.encoder is not None:
            b += self.encoder.weight_bytes(q) * self.encoder.repeat
        return b

    def kv_bytes_per_token(self, q: QuantFormat) -> float:
        return self.block.kv_bytes_per_token(q) * self.block.repeat

    def state_bytes_per_seq(self, q: QuantFormat) -> float:
        return self.block.state_bytes_per_seq(q) * self.block.repeat

    def lm_head_opcall(self, tokens: int, q: QuantFormat) -> OpCall:
        return Cell._gemm(tokens, self.vocab_size, self.d_model, q)

    @property
    def num_layers(self) -> int:
        return self.block.repeat * len(
            [c for c in self.block.cells if c.kind in
             ("attn", "mla", "ssm", "cross_attn")]
        ) or self.block.repeat

    def describe(self) -> str:
        cells = " -> ".join(f"{c.name}[{c.kind}]" for c in self.block.cells)
        return (f"{self.name}: d_model={self.d_model} vocab={self.vocab_size} "
                f"block=({cells}) x{self.block.repeat}, "
                f"params={self.total_params() / 1e9:.2f}B")


# ---------------------------------------------------------------------------
# IR converter (paper §3.2.1: "parses an LLM's configuration file")
# ---------------------------------------------------------------------------

def ir_from_hf_config(cfg: dict, name: str = "model") -> ModelIR:
    """Build IR from a HuggingFace-style config dict.

    This is the paper's zero-LoC extension path (Table 5 first row): a new
    dense/GQA/MoE LLM needs only its config file.  Architectures with
    unknown cells (SSM, MLA, ...) use the explicit constructors in
    repro/configs/ instead (Table 5 second row).
    """
    d_model = cfg.get("hidden_size") or cfg["d_model"]
    n_layers = cfg.get("num_hidden_layers") or cfg["n_layers"]
    n_heads = cfg.get("num_attention_heads") or cfg["n_heads"]
    n_kv = cfg.get("num_key_value_heads", n_heads)
    head_dim = cfg.get("head_dim", d_model // n_heads)
    d_ff = cfg.get("intermediate_size") or cfg["d_ff"]
    vocab = cfg.get("vocab_size", 32000)
    window = cfg.get("sliding_window", None)
    bias = bool(cfg.get("attention_bias", cfg.get("qkv_bias", False)))

    attn = AttentionCell(name="attn", d_model=d_model, n_heads=n_heads,
                         n_kv_heads=n_kv, head_dim=head_dim, qkv_bias=bias,
                         window=window)
    n_experts = cfg.get("num_local_experts", cfg.get("n_routed_experts", 0))
    if n_experts:
        ffn: Cell = MoECell(name="moe", d_model=d_model,
                            d_ff_expert=cfg.get("moe_intermediate_size", d_ff),
                            n_routed=n_experts,
                            top_k=cfg.get("num_experts_per_tok", 2),
                            n_shared=cfg.get("n_shared_experts", 0))
    else:
        ffn = MLPCell(name="mlp", d_model=d_model, d_ff=d_ff, gated=True)
    block = Block(cells=(attn, ffn), repeat=n_layers)
    return ModelIR(name=name, d_model=d_model, vocab_size=vocab, block=block,
                   tie_embeddings=bool(cfg.get("tie_word_embeddings", False)))
