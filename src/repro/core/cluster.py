"""Device-cluster abstraction for APEX (paper §2.2, §3.2.3).

A cluster is a tree: devices at the leaves, interconnect levels above them.
Bandwidth and latency are uniform within a level (paper Fig. 1).  Level 1 is
the fastest/lowest (e.g. NVLink within a node, an ICI ring group on a TPU
pod); higher levels span more devices at lower bandwidth (InfiniBand across
nodes, DCN across pods).

The paper models GPU clusters; §2.2 notes ASIC clusters (TPU, Gaudi) use
tree-based topologies as well and "can be abstracted similarly" — we ship a
TPU v5e preset built on the hardware constants used by the roofline analysis
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """A single accelerator's capabilities.

    ``peak_flops`` maps dtype name -> peak dense FLOP/s.  ``hbm_bytes`` is
    usable memory capacity; ``hbm_bw`` is peak HBM bandwidth in bytes/s.
    ``idle_power_w`` / ``peak_power_w`` feed the energy model (core/energy.py).
    ``base_freq_ghz`` is the frequency the peak numbers are quoted at; the
    energy model scales rates linearly and power super-linearly with
    frequency (paper Table 4 explores 0.8 GHz vs 2.0 GHz).
    """

    name: str
    peak_flops: dict
    hbm_bytes: float
    hbm_bw: float
    idle_power_w: float
    peak_power_w: float
    base_freq_ghz: float = 2.0

    def flops(self, dtype: str) -> float:
        if dtype not in self.peak_flops:
            raise KeyError(
                f"{self.name} has no peak-FLOPs entry for dtype {dtype!r}; "
                f"known: {sorted(self.peak_flops)}"
            )
        return self.peak_flops[dtype]


@dataclasses.dataclass(frozen=True)
class NetworkLevel:
    """One level of the interconnect tree.

    ``group_size``: number of *devices* spanned by one group at this level
    (cumulative — level 2's group_size counts all devices under one level-2
    switch, not the number of level-1 groups).
    ``bw_per_device``: per-device injection bandwidth in bytes/s at this
    level (the number ring-collective models divide by).
    ``latency_s``: per-hop software+wire latency.
    """

    name: str
    group_size: int
    bw_per_device: float
    latency_s: float
    # Per-collective software launch overhead (NCCL kernel launch, group
    # sync). GPUs pay ~10 us per op; TPU collectives are compiled into the
    # XLA program and pay far less. This term is what makes high-degree TP
    # lose to DP-heavy hybrids on decode (paper §4.2.1's "incorporating DP
    # often yields performance benefits").
    launch_s: float = 8e-6


@dataclasses.dataclass(frozen=True)
class Cluster:
    """A tree-topology device cluster."""

    name: str
    device: DeviceSpec
    levels: tuple  # tuple[NetworkLevel, ...], innermost first
    num_devices: int

    def __post_init__(self):
        if not self.levels:
            raise ValueError("cluster needs at least one network level")
        sizes = [l.group_size for l in self.levels]
        if sizes != sorted(sizes):
            raise ValueError(f"levels must be ordered innermost-first: {sizes}")
        if self.levels[-1].group_size < self.num_devices:
            raise ValueError(
                f"outermost level spans {self.levels[-1].group_size} devices "
                f"< cluster size {self.num_devices}"
            )

    # -- topology queries ---------------------------------------------------

    def level_for_group(self, group_size: int) -> NetworkLevel:
        """Smallest level whose group covers ``group_size`` devices.

        The Device Mapper (core/mapper.py) packs communicating groups
        bottom-up, so a group of size g lands on the first level with
        group_size >= g.
        """
        if group_size <= 1:
            return self.levels[0]
        for lvl in self.levels:
            if lvl.group_size >= group_size:
                return lvl
        raise ValueError(
            f"group of {group_size} devices exceeds cluster {self.name} "
            f"({self.num_devices} devices)"
        )

    def level_index_for_group(self, group_size: int) -> int:
        lvl = self.level_for_group(group_size)
        return self.levels.index(lvl)

    @property
    def total_hbm_bytes(self) -> float:
        return self.device.hbm_bytes * self.num_devices

    @property
    def total_flops(self) -> dict:
        return {k: v * self.num_devices for k, v in self.device.peak_flops.items()}

    def describe(self) -> str:
        lines = [f"cluster {self.name}: {self.num_devices} x {self.device.name}"]
        for i, lvl in enumerate(self.levels):
            lines.append(
                f"  L{i + 1} {lvl.name}: groups of {lvl.group_size}, "
                f"{lvl.bw_per_device / 1e9:.0f} GB/s/dev, "
                f"{lvl.latency_s * 1e6:.1f} us"
            )
        return "\n".join(lines)


def cross_pool_link(prefill: "Cluster", decode: "Cluster",
                    name: str = "cross-pool") -> NetworkLevel:
    """The network level joining two heterogeneous device pools.

    Each pool injects onto the shared fabric through its own outermost
    level; the joint link can move bytes no faster than the slower side, so
    its per-device bandwidth is the MIN of the two pools' outermost
    injection bandwidths, and latency/launch take the worse of the two.
    Pass an explicit ``NetworkLevel`` to ``map_disagg_scheme`` instead when
    the deployment's inter-pool wire is known (e.g. a dedicated RDMA
    fabric slower than either pool's scale-out network).
    """
    a, b = prefill.levels[-1], decode.levels[-1]
    return NetworkLevel(
        name=name,
        group_size=prefill.num_devices + decode.num_devices,
        bw_per_device=min(a.bw_per_device, b.bw_per_device),
        latency_s=max(a.latency_s, b.latency_s),
        launch_s=max(a.launch_s, b.launch_s),
    )


def host_link(name: str = "host-pcie",
              bw_bytes_s: float = 64e9,
              latency_s: float = 2e-6,
              launch_s: float = 1e-5) -> NetworkLevel:
    """The device<->host-DRAM link one device swaps KV over.

    Defaults model a PCIe Gen5 x16 endpoint (~64 GB/s per direction).
    This is the link the ``swap`` preemption mechanism prices its KV
    round trips on (engine ``SwapPolicy``); group_size=1 because a swap
    is a single device-local DMA, not a collective.
    """
    return NetworkLevel(name=name, group_size=1, bw_per_device=bw_bytes_s,
                        latency_s=latency_s, launch_s=launch_s)


# ---------------------------------------------------------------------------
# Device presets
# ---------------------------------------------------------------------------

H100 = DeviceSpec(
    name="H100-SXM",
    peak_flops={"fp16": 989e12, "bf16": 989e12, "fp8": 1979e12, "fp32": 67e12},
    hbm_bytes=80e9,
    hbm_bw=3.35e12,
    idle_power_w=90.0,
    peak_power_w=700.0,
    base_freq_ghz=2.0,
)

H200 = DeviceSpec(
    name="H200-SXM",
    peak_flops={"fp16": 989e12, "bf16": 989e12, "fp8": 1979e12, "fp32": 67e12},
    hbm_bytes=141e9,
    hbm_bw=4.8e12,
    idle_power_w=95.0,
    peak_power_w=700.0,
    base_freq_ghz=2.0,
)

# TPU v5e — the production dry-run / roofline target. Constants match the
# roofline analysis: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s per ICI link.
TPU_V5E = DeviceSpec(
    name="TPU-v5e",
    peak_flops={"bf16": 197e12, "fp16": 197e12, "int8": 394e12, "fp8": 394e12,
                "fp32": 49e12},
    hbm_bytes=16e9,
    hbm_bw=819e9,
    idle_power_w=60.0,
    peak_power_w=220.0,
    base_freq_ghz=1.7,
)


# ---------------------------------------------------------------------------
# Cluster presets (the paper's three evaluation clusters + our TPU target)
# ---------------------------------------------------------------------------

def h100_node(num_gpus: int = 8) -> Cluster:
    """Single-node H100 cluster (paper §4.2.1): NVLink all-to-all."""
    return Cluster(
        name=f"h100x{num_gpus}",
        device=H100,
        levels=(
            NetworkLevel("nvlink", num_gpus, 450e9, 2e-6, launch_s=10e-6),
        ),
        num_devices=num_gpus,
    )


def h100_multinode(num_nodes: int = 2, gpus_per_node: int = 8) -> Cluster:
    """Multi-node H100 cluster (paper §4.2.2): NVLink in-node, IB across."""
    n = num_nodes * gpus_per_node
    return Cluster(
        name=f"h100x{gpus_per_node}x{num_nodes}nodes",
        device=H100,
        levels=(
            NetworkLevel("nvlink", gpus_per_node, 450e9, 2e-6, launch_s=10e-6),
            NetworkLevel("infiniband", n, 50e9, 10e-6, launch_s=25e-6),
        ),
        num_devices=n,
    )


def h200_node(num_gpus: int = 8) -> Cluster:
    """Single-node H200 cluster (paper §4.2.3): more HBM, same compute."""
    return Cluster(
        name=f"h200x{num_gpus}",
        device=H200,
        levels=(
            NetworkLevel("nvlink", num_gpus, 450e9, 2e-6, launch_s=10e-6),
        ),
        num_devices=num_gpus,
    )


def tpu_v5e_pod(chips: int = 256, ring_group: int = 16) -> Cluster:
    """TPU v5e pod slice, modeled as a 2-level tree over ICI ring groups.

    A v5e pod is a 2D torus; collectives run ring algorithms along torus
    axes, so a 16-chip ring group is the level-1 "fast" domain (one torus
    row) and the full slice is level 2 (both axes). Paper §2.2 sanctions the
    tree abstraction for TPU clusters.
    """
    return Cluster(
        name=f"tpu-v5e-{chips}",
        device=TPU_V5E,
        levels=(
            NetworkLevel("ici-ring", ring_group, 50e9, 1e-6, launch_s=2e-6),
            NetworkLevel("ici-2d", chips, 50e9, 2e-6, launch_s=3e-6),
        ),
        num_devices=chips,
    )


def tpu_v5e_multipod(pods: int = 2, chips_per_pod: int = 256) -> Cluster:
    """Multi-pod v5e: pods joined over DCN (25 GB/s/device effective)."""
    n = pods * chips_per_pod
    return Cluster(
        name=f"tpu-v5e-{chips_per_pod}x{pods}pods",
        device=TPU_V5E,
        levels=(
            NetworkLevel("ici-ring", 16, 50e9, 1e-6, launch_s=2e-6),
            NetworkLevel("ici-2d", chips_per_pod, 50e9, 2e-6, launch_s=3e-6),
            NetworkLevel("dcn", n, 25e9, 20e-6, launch_s=30e-6),
        ),
        num_devices=n,
    )


# This container's CPU — used by the fidelity experiments where the
# simulator (with MEASURED op tables) predicts the real JAX engine running
# on the same silicon.  Peak numbers are rough (they only feed MFU/energy
# bookkeeping; timing comes from measured tables).
CPU_LOCAL = DeviceSpec(
    name="cpu-local",
    peak_flops={"fp32": 5e10, "bf16": 5e10, "fp16": 5e10, "fp8": 5e10},
    hbm_bytes=8e9,
    hbm_bw=20e9,
    idle_power_w=20.0,
    peak_power_w=65.0,
    base_freq_ghz=2.5,
)


def cpu_local() -> Cluster:
    return Cluster(
        name="cpu-local",
        device=CPU_LOCAL,
        levels=(NetworkLevel("shm", 1, 10e9, 1e-6, launch_s=1e-6),),
        num_devices=1,
    )


CLUSTER_PRESETS = {
    "cpu-local": cpu_local,
    "h100x8": h100_node,
    "h100x16-2node": h100_multinode,
    "h200x8": h200_node,
    "tpu-v5e-256": tpu_v5e_pod,
    "tpu-v5e-512-2pod": tpu_v5e_multipod,
}


def get_cluster(name: str) -> Cluster:
    """Resolve a preset cluster by name (extensibility hook, paper Table 5)."""
    if name not in CLUSTER_PRESETS:
        raise KeyError(f"unknown cluster {name!r}; known: {sorted(CLUSTER_PRESETS)}")
    return CLUSTER_PRESETS[name]()
