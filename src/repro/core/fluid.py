"""Fluid-limit surrogate of the batching dynamics — plans in microseconds.

The exact event engine (core/engine.py) prices every iteration of every
replica; at ~tens of plans per second it is the scaling bottleneck of
plan search (BENCH_core.json).  This module scores a plan by integrating
the *fluid limit* of the same dynamics instead: the discrete request
population is replaced by coupled ordinary differential equations for

    Q(t)  — requests waiting for admission,
    P(t)  — admitted requests still prefilling,
    N(t)  — requests decoding (the running batch),
    M(t)  — KV-token occupancy, carried implicitly as N x (mean ctx +
            half the mean generation): the admission cap ``B_cap`` is the
            KV capacity divided by that per-request footprint, so memory
            gates admission exactly as the engine's greedy rule does in
            expectation,

driven by the SAME per-step cost model the engine uses: a handful of
``PlanSimulator.iteration_cost`` probes (one mean-prompt prefill, two
decode batches) anchor the service rates, so the surrogate and the
engine disagree only on stochastic fine structure (bursts, preemption,
discreteness), never on the cost of an iteration.  Three probes plus a
~hundred-step Euler integration come to a few hundred microseconds per
plan — two to three orders of magnitude faster than exact simulation.

Disaggregated plans integrate BOTH pools and the cross-pool KV wire in
one coupled system: the prefill pool's completion flux feeds a link
stage with service rate 1/wire_s (the ``SharedLink`` FIFO's fluid
limit), whose output is the decode pool's arrival process — the transfer
rate is the coupling term joining the two pools' ODEs.

The surrogate returns a ``SimulationReport`` so every search objective
(latency, energy, ttft, tpot, throughput) ranks fluid and exact reports
through one code path.  Fidelity caveats (all second-order for ranking):
percentiles are dispersion-scaled means, preemption/re-fetch churn is
not modeled (admission respects the same KV cap instead), and chunked
prefill is treated as contiguous.  ``MultiFidelitySearch``
(core/multifid.py) uses these scores only to pick a survivor frontier;
the exact engine confirms the winners.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

from .batching import BatchingPolicy
from .engine import SharedCostStore, StepCostCache
from .ir import Workload
from .mapper import ExecutionPlan
from .metrics import ClassReport, SimulationReport, percentile
from .profiles import CollectiveModel, ProfileStore
from .simulator import PlanSimulator
from .trace import DEFAULT_SLO, Request, SLOClass, prefix_trace, retag_slo

# engine Pool default — the surrogate's sequence-slot cap must match
_MAX_SEQUENCES = 512


@dataclasses.dataclass(frozen=True)
class ClassSummary:
    """One SLO class's slice of a trace summary: its population and its
    own length moments, so multi-tenant screening does not collapse the
    mix into one aggregate distribution."""

    slo: SLOClass
    n: int
    ctx_mean: float
    gen_mean: float
    ctx_p95: float
    gen_p95: float


@dataclasses.dataclass(frozen=True)
class TraceSummary:
    """First/second-moment summary of a request trace — the fluid model's
    entire view of the workload (computed once per search, shared by
    every candidate's surrogate evaluation)."""

    n: int
    span_s: float             # last arrival time
    arrival_rate: float       # req/s over the arrival window
    ctx_mean: float
    gen_mean: float
    ctx_p95: float
    gen_p95: float
    source_mean: float = 0.0  # encoder-side tokens (enc-dec models)
    # per-SLO-class populations (highest priority first); empty means
    # treat the whole trace as one DEFAULT_SLO class
    classes: tuple = ()
    # stationarity diagnostics over 4 equal arrival windows: the max
    # per-window deviation from the uniform share in Poisson standard
    # errors (z ~ <2 for a stationary trace; diurnal/burst traces score
    # far higher), and the busiest window's arrival rate.  The fluid
    # model assumes ONE arrival rate, so a high score means the
    # surrogate is screening a workload it cannot represent —
    # ``MultiFidelitySearch`` refuses or falls back to ``peak_rate``.
    nonstationarity: float = 0.0
    peak_rate: float = 0.0

    @classmethod
    def of(cls, requests: Sequence[Request]) -> "TraceSummary":
        n = len(requests)
        if n == 0:
            return cls(0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0, 0.0)
        span = max(r.arrival for r in requests)
        ctxs = [r.context_len for r in requests]
        gens = [r.gen_len for r in requests]
        groups: dict = {}
        for r in requests:
            groups.setdefault(r.slo_class, []).append(r)
        classes = []
        for slo in sorted(groups, key=lambda s: (-s.priority, s.name)):
            rs = groups[slo]
            k = len(rs)
            classes.append(ClassSummary(
                slo=slo, n=k,
                ctx_mean=sum(r.context_len for r in rs) / k,
                gen_mean=sum(r.gen_len for r in rs) / k,
                ctx_p95=float(percentile(
                    [float(r.context_len) for r in rs], 0.95)),
                gen_p95=float(percentile(
                    [float(r.gen_len) for r in rs], 0.95))))
        z = 0.0
        peak = n / span if span > 0 else float("inf")
        if span > 0 and n >= 8:
            win = span / 4.0
            counts = [0] * 4
            for r in requests:
                counts[min(int(r.arrival / win), 3)] += 1
            m = n / 4.0
            z = max(abs(c - m) for c in counts) / math.sqrt(m)
            peak = max(counts) / win
        return cls(
            n=n, span_s=span,
            arrival_rate=n / span if span > 0 else float("inf"),
            ctx_mean=sum(ctxs) / n, gen_mean=sum(gens) / n,
            ctx_p95=float(percentile([float(c) for c in ctxs], 0.95)),
            gen_p95=float(percentile([float(g) for g in gens], 0.95)),
            source_mean=sum(r.source_len for r in requests) / n,
            classes=tuple(classes),
            nonstationarity=z, peak_rate=peak)

    @classmethod
    def of_prefixes(cls, requests: Sequence[Request],
                    fractions: Sequence[float]) -> dict:
        """Summaries of count-prefixes of ``requests``: maps each fraction
        in ``fractions`` (plus 1.0, the full trace) to the summary of the
        first ``ceil(f * n)`` requests by arrival, sharing one sort.

        The first k arrivals of a Poisson process are themselves a Poisson
        sample over a shorter window (arrival times kept absolute — see
        ``trace.prefix_trace``), so prefix summaries preserve the
        arrival-rate and length statistics the fluid model and the
        halving rungs consume.
        """
        ordered = sorted(requests, key=lambda r: (r.arrival, r.rid))
        out = {}
        for f in set(fractions) | {1.0}:
            out[f] = cls.of(prefix_trace(ordered, f, presorted=True))
        return out


@dataclasses.dataclass
class _PoolRates:
    """One pool's fluid-rate anchors, probed from its cost model."""

    t_pre: float              # seconds to prefill one mean prompt
    e_pre: float              # energy of that prefill iteration
    td0: float                # decode iteration time ~ td0 + td1 * B
    td1: float
    ed0: float                # decode iteration energy ~ ed0 + ed1 * B
    ed1: float
    b_cap: float              # per-replica running cap (KV/slots/batch)
    dp: int                   # replicas

    def t_dec(self, b: float) -> float:
        return max(1e-12, self.td0 + self.td1 * b)

    def e_dec(self, b: float) -> float:
        return max(0.0, self.ed0 + self.ed1 * b)


def _probe_rates(sim: PlanSimulator, cache: StepCostCache,
                 ts: TraceSummary, capacity: int, dp: int,
                 policy: BatchingPolicy,
                 decode_only: bool = False) -> _PoolRates:
    """Anchor one pool's fluid rates with three cost-model probes: one
    mean-prompt prefill and two decode batches (B=1 and B=cap) whose
    linear fit prices any fractional fluid batch."""
    windows = sim.windows
    is_encdec = sim.scheme.model.encoder is not None
    src = int(round(ts.source_mean)) if is_encdec else 0
    c = max(1, int(round(ts.ctx_mean)))
    g = max(1.0, ts.gen_mean)
    kv = max(1, int(round(ts.ctx_mean + ts.gen_mean / 2.0)))

    # per-replica running cap: KV footprint, sequence slots, batch knob,
    # and the trace's own max concurrency; >= 1 (engine liveness: an
    # idle replica always admits its head request)
    b_kv = capacity / float(kv)
    b_cap = min(b_kv, float(_MAX_SEQUENCES),
                float(policy.max_batch_size or _MAX_SEQUENCES),
                max(1.0, ts.n / float(dp)))
    b_cap = max(1.0, b_cap)

    w_pre = Workload.from_batch(
        [(c, c)], [], windows, batch_sequences=1,
        encoder_tokens=src if not decode_only else 0,
        prefill_source=[src] if is_encdec else ())
    t_pre, e_pre, _ = cache.cost(w_pre)

    b_hi = max(2, int(min(b_cap, 4096.0)))
    dec_src = ([src] if is_encdec else [])

    def dec_probe(b: int) -> Tuple[float, float]:
        w = Workload.from_batch([], [kv] * b, windows, batch_sequences=b,
                                decode_source=dec_src * b)
        t, e, _ = cache.cost(w)
        return t, e

    t1, e1 = dec_probe(1)
    t2, e2 = dec_probe(b_hi)
    td1 = (t2 - t1) / (b_hi - 1)
    ed1 = (e2 - e1) / (b_hi - 1)
    return _PoolRates(t_pre=max(t_pre, 1e-12), e_pre=e_pre,
                      td0=t1 - td1, td1=td1, ed0=e1 - ed1, ed1=ed1,
                      b_cap=b_cap, dp=dp)


def _attained_fraction(mean: float, p95v: float,
                       target: Optional[float]) -> float:
    """Fraction of requests under ``target`` given the surrogate's
    (mean, p95) dispersion pair: 0.5 of the mass sits at or below the
    mean, 0.95 at or below p95, linear between — a two-point CDF sketch,
    enough to rank plans by goodput, not a tail model."""
    if target is None:
        return 1.0
    if target <= 0.0:
        return 0.0
    if mean <= 0.0 or (target >= p95v and target >= mean):
        return 1.0
    if target <= mean:
        return min(1.0, 0.5 * target / mean)
    return min(1.0, 0.5 + 0.45 * (target - mean) / max(p95v - mean, 1e-12))


def _class_goodput(ts: TraceSummary, wait: float, t_pre: float,
                   tpot: float, drain_s: float) -> tuple:
    """(goodput_rps, class_reports) from the fluid means, split per SLO
    class: every class shares the queueing wait and decode pacing, but
    pays prefill service proportional to its OWN mean prompt, and its
    TTFT dispersion comes from its own length spread — so a latency-tight
    chat class is not judged by a batchy summarization class's tails."""
    classes = ts.classes or (ClassSummary(
        DEFAULT_SLO, ts.n, ts.ctx_mean, ts.gen_mean,
        ts.ctx_p95, ts.gen_p95),)
    met_total = 0.0
    reports = []
    for c in classes:
        scale = c.ctx_mean / ts.ctx_mean if ts.ctx_mean > 0 else 1.0
        ttft_c = wait + t_pre * scale
        disp_c = c.ctx_p95 / c.ctx_mean if c.ctx_mean > 0 else 1.0
        ttft_p95_c = ttft_c * disp_c
        frac = (_attained_fraction(ttft_c, ttft_p95_c,
                                   c.slo.ttft_target_s)
                * _attained_fraction(tpot, tpot, c.slo.tpot_target_s))
        met = c.n * frac
        met_total += met
        reports.append(ClassReport(
            name=c.slo.name, priority=c.slo.priority, num_requests=c.n,
            ttft_mean=ttft_c, ttft_p50=ttft_c, ttft_p95=ttft_p95_c,
            ttft_p99=ttft_p95_c,
            tpot_mean=tpot, tpot_p50=tpot, tpot_p95=tpot, tpot_p99=tpot,
            slo_met=int(met + 0.5),
            goodput_rps=met / drain_s if drain_s > 0 else 0.0))
    goodput = met_total / drain_s if drain_s > 0 else 0.0
    return goodput, reports


def _dispersed_report(label: str, ts: TraceSummary, ttft: float,
                      tpot: float, drain_s: float, energy: float,
                      tokens: float, peak_n: float, kv_per_req: float,
                      capacity: int, iterations: float,
                      t_pre: float = 0.0) -> SimulationReport:
    """Fold fluid means into a SimulationReport; percentile fields are
    means scaled by the trace's own length dispersion (enough to rank,
    not a tail model).  ``t_pre`` is the prefill-service floor inside
    ``ttft`` (the rest is queueing wait shared by every class) — the
    split the per-class goodput estimate needs."""
    ctx_disp = ts.ctx_p95 / ts.ctx_mean if ts.ctx_mean > 0 else 1.0
    gen = max(1.0, ts.gen_mean)
    ttft = max(0.0, ttft)
    tpot = max(0.0, tpot)
    t_pre = min(max(0.0, t_pre), ttft)
    e2e_mean = ttft + tpot * max(0.0, gen - 1.0)
    e2e_p95 = ttft * ctx_disp + tpot * max(0.0, ts.gen_p95 - 1.0)
    goodput, class_reports = _class_goodput(ts, ttft - t_pre, t_pre,
                                            tpot, drain_s)
    return SimulationReport(
        plan_label=label,
        e2e_latency=drain_s,
        total_energy=energy,
        ttft_mean=ttft, ttft_p95=ttft * ctx_disp,
        tpot_mean=tpot, tpot_p95=tpot,
        latency_p95=max(e2e_mean, e2e_p95),
        throughput_tok_s=tokens / drain_s if drain_s > 0 else 0.0,
        mfu=0.0, mbu=0.0,
        iterations=int(iterations),
        preemptions=0,
        peak_kv_tokens=int(min(capacity, peak_n * kv_per_req)),
        peak_batch=int(peak_n + 0.5),
        feasible=True,
        ttft_p50=ttft, ttft_p99=ttft * ctx_disp,
        tpot_p50=tpot, tpot_p99=tpot,
        goodput_rps=goodput, class_reports=class_reports)


class FluidSimulator:
    """Fluid-limit surrogate of one colocated plan's trace simulation.

    Mirrors ``PlanSimulator``'s constructor so search code can build
    either fidelity from the same (plan, store, coll) triple; the cost
    probes go through a ``StepCostCache`` so ``cache_stats`` reports the
    surrogate's cost reuse just like the exact simulators do.
    """

    steps: int = 48           # Euler steps over the arrival window

    def __init__(self, plan: ExecutionPlan, store: ProfileStore,
                 coll: CollectiveModel,
                 cost_store: Optional[SharedCostStore] = None):
        self.plan = plan
        self.scheme = plan.scheme
        self.sim = PlanSimulator(plan, store, coll, cost_store=cost_store)
        self.cache = self.sim.cost_cache()
        self.cache_stats = {"hits": 0, "misses": 0}

    def simulate(self, requests: Sequence[Request],
                 policy: Optional[BatchingPolicy] = None,
                 keep_records: bool = False,
                 summary: Optional[TraceSummary] = None,
                 preemption=None,
                 slo_classes=None) -> SimulationReport:
        # ``preemption`` is accepted for signature parity with the exact
        # simulator and ignored: the fluid limit admits within the same
        # KV cap instead of modeling eviction churn.
        policy = policy or BatchingPolicy()
        scheme = self.scheme
        cap = scheme.kv_token_capacity(self.plan.cluster.device.hbm_bytes)
        if cap <= 0:
            return SimulationReport.infeasible(scheme.label())
        if summary is None:
            requests = retag_slo(requests, slo_classes)
        ts = summary or TraceSummary.of(requests)
        if ts.n == 0:
            return SimulationReport.infeasible(scheme.label())
        rates = _probe_rates(self.sim, self.cache, ts, cap,
                             scheme.model_dp, policy)
        out = _integrate_colocated(rates, ts, self.steps)
        self.cache_stats = self.cache.stats()
        kv_per_req = ts.ctx_mean + ts.gen_mean / 2.0
        return _dispersed_report(scheme.label(), ts, out["ttft"],
                                 out["tpot"], out["t"], out["energy"],
                                 out["tokens"], out["peak_n"] / rates.dp,
                                 kv_per_req, cap, out["iters"],
                                 t_pre=rates.t_pre)


def _integrate_colocated(r: _PoolRates, ts: TraceSummary,
                         steps: int) -> dict:
    """Forward-Euler integration of the colocated fluid system.

    Aggregate (all-replica) state; the engine-time split between prefill
    and decode is the fluid analogue of contiguous batching: admitted
    prompts claim a share ``u`` of each replica-second and decode runs in
    the remaining ``1-u``, so a prefill backlog slows token emission
    exactly as prefill-priority iterations do in the engine.
    """
    lam = ts.arrival_rate * 1.0            # aggregate arrivals/s
    n = float(ts.n)
    gbar = max(1.0, ts.gen_mean)
    cap_total = r.b_cap * r.dp
    q = p = nd = done = tok = energy = 0.0
    aw = tpw = 0.0            # ∫(Q+P)dt, token-weighted decode intervals
    peak_n = 0.0
    iters = 0.0
    t = 0.0
    span = ts.span_s
    dt = span / steps if span > 0 else 0.0
    if dt <= 0:                            # burst trace: all arrive at 0
        q = n
        dt = _drain_dt_estimate(r, n, gbar, cap_total, steps)
    budget = 40 * steps                    # hard bound on the Euler loop
    remaining_arrivals = n

    for _ in range(budget):
        if done >= n - 1e-6:
            break
        if t >= span and q + p + nd <= 1e-9:
            break
        # arrivals (exact count over the window, fluid within it)
        if remaining_arrivals > 0 and span > 0:
            a = min(remaining_arrivals, lam * dt)
            if t + dt >= span:
                a = remaining_arrivals
            q += a
            remaining_arrivals -= a
        # admission: memory/slot-gated, instantaneous in the fluid limit
        slots = cap_total - nd - p
        if slots > 0 and q > 0:
            x = min(q, slots)
            q -= x
            p += x
        # prefill claims engine time first (prefill-priority batching)
        u = 0.0
        if p > 0:
            pref = min(p, r.dp * dt / r.t_pre)
            u = pref * r.t_pre / (r.dp * dt)
            p -= pref
            nd += pref
            energy += pref * r.e_pre
            iters += pref
        peak_n = max(peak_n, nd)
        # decode in the remaining share
        if nd > 1e-9 and u < 1.0:
            b = max(1.0, nd / r.dp)
            tdb = r.t_dec(b)
            emitted = (1.0 - u) * nd / tdb * dt
            comp = min(nd, emitted / gbar)
            tok += emitted
            # token-weighted inter-token interval (exact even when a
            # request decodes end-to-end inside one Euler step, where the
            # ∫N dt / tokens estimate collapses to zero)
            tpw += emitted * tdb / (1.0 - u)
            nd -= comp
            done += comp
            energy += (1.0 - u) * dt * r.dp * r.e_dec(b) / tdb
            iters += (1.0 - u) * dt * r.dp / tdb
        aw += (q + p) * dt
        t += dt
        if t >= span and q + p + nd > 1e-9:
            # drain phase: re-scale dt to the remaining work
            dt = max(dt, _drain_dt_estimate(r, q + p + nd, gbar,
                                            cap_total, steps))
    else:
        # budget exhausted (deep overload): extrapolate the linear tail
        left = n - done
        b = max(1.0, min(cap_total, nd) / r.dp) if nd > 0 else 1.0
        mu = nd / r.t_dec(b) / gbar if nd > 0 else r.dp / r.t_pre
        tail = left / max(mu, 1e-9)
        aw += (q + p) * tail / 2.0
        tpw += left * gbar * r.t_dec(b)
        tok += left * gbar
        t += tail
        done = n

    tok = min(tok, n * gbar)
    # queueing integral plus the service-time floor: a request that never
    # waits still pays its own prefill (without the floor, sub-dt prefill
    # clears within one Euler step and every plan's TTFT collapses to 0)
    ttft = aw / n + r.t_pre
    tpot = tpw / tok if tok > 0 else 0.0
    return {"ttft": ttft, "tpot": tpot, "t": t, "energy": energy,
            "tokens": tok, "peak_n": peak_n, "iters": iters}


def _drain_dt_estimate(r: _PoolRates, backlog: float, gbar: float,
                       cap_total: float, steps: int) -> float:
    """Step size that resolves draining ``backlog`` requests in ~steps."""
    b = max(1.0, min(backlog, cap_total) / r.dp)
    mu = min(cap_total, backlog) / r.t_dec(b) / gbar  # completions/s
    mu = min(mu, r.dp / r.t_pre) if backlog > cap_total else mu
    est = backlog / max(mu, 1e-9) + backlog * r.t_pre / r.dp
    return max(est / steps, 1e-9)


class FluidDisaggSimulator:
    """Fluid-limit surrogate of a disaggregated plan: both pools and the
    shared KV wire integrated as one coupled system.

    Mirrors ``DisaggSimulator``'s constructor; the underlying exact
    simulator is built only for its per-pool cost hooks and transfer
    estimator — no events run.
    """

    steps: int = 48

    def __init__(self, plan, store: ProfileStore, coll: CollectiveModel,
                 kv_model=None, decode_store: Optional[ProfileStore] = None,
                 decode_coll: Optional[CollectiveModel] = None,
                 cost_store: Optional[SharedCostStore] = None):
        from ..disagg.simulate import DisaggSimulator
        self.exact = DisaggSimulator(plan, store, coll, kv_model,
                                     decode_store=decode_store,
                                     decode_coll=decode_coll,
                                     cost_store=cost_store)
        self.plan = plan
        self.scheme = plan.scheme
        self.pre_cache = self.exact.pre_sim.cost_cache()
        self.dec_cache = self.exact.dec_sim.cost_cache()
        self.cache_stats = {"hits": 0, "misses": 0}

    def simulate(self, requests: Sequence[Request],
                 policy: Optional[BatchingPolicy] = None,
                 keep_records: bool = False,
                 prefill_policy: Optional[BatchingPolicy] = None,
                 decode_policy: Optional[BatchingPolicy] = None,
                 summary: Optional[TraceSummary] = None,
                 preemption=None,
                 slo_classes=None) -> SimulationReport:
        # ``preemption`` accepted for parity with DisaggSimulator and
        # ignored (no eviction churn in the fluid limit)
        plan = self.plan
        pre_pol = (prefill_policy or plan.prefill_policy or policy
                   or BatchingPolicy())
        dec_pol = (decode_policy or plan.decode_policy or policy
                   or BatchingPolicy())
        if pre_pol.mode == "static" or dec_pol.mode == "static":
            # mirror the exact simulator: static batching has no
            # meaningful decode-only pool
            return SimulationReport.infeasible(plan.label())
        pre_s, dec_s = self.scheme.prefill, self.scheme.decode
        pre_cap = pre_s.kv_token_capacity(
            plan.prefill_cluster.device.hbm_bytes)
        dec_cap = dec_s.kv_token_capacity(
            plan.decode_cluster.device.hbm_bytes)
        if pre_cap <= 0 or dec_cap <= 0:
            return SimulationReport.infeasible(plan.label())
        if summary is None:
            requests = retag_slo(requests, slo_classes)
        ts = summary or TraceSummary.of(requests)
        if ts.n == 0:
            return SimulationReport.infeasible(plan.label())

        pre = _probe_rates(self.exact.pre_sim, self.pre_cache, ts,
                           pre_cap, pre_s.model_dp, pre_pol)
        dec = _probe_rates(self.exact.dec_sim, self.dec_cache, ts,
                           dec_cap, dec_s.model_dp, dec_pol,
                           decode_only=True)
        lanes = min(pre_s.devices_per_replica, dec_s.devices_per_replica)
        est = self.exact.kv.estimate(
            self.scheme.model, max(1, int(round(ts.ctx_mean))),
            pre_s.quant, plan.transfer_span, lanes=lanes)

        out = _integrate_disagg(pre, dec, est, ts, self.steps)
        self.cache_stats = {
            k: self.pre_cache.stats()[k] + self.dec_cache.stats()[k]
            for k in ("hits", "misses", "entries", "evictions")}
        kv_per_req = ts.ctx_mean + ts.gen_mean / 2.0
        return _dispersed_report(plan.label(), ts, out["ttft"],
                                 out["tpot"], out["t"], out["energy"],
                                 out["tokens"], out["peak_n"] / dec.dp,
                                 kv_per_req, dec_cap, out["iters"],
                                 t_pre=pre.t_pre)


def _integrate_disagg(pre: _PoolRates, dec: _PoolRates, est,
                      ts: TraceSummary, steps: int) -> dict:
    """Coupled fluid system: prefill pool -> shared KV wire -> decode
    pool.  The wire's service rate (1/wire_s, the SharedLink FIFO's
    fluid limit) is the coupling term: the decode pool's arrival flux is
    the transfer completion rate, never more than the wire admits."""
    lam = ts.arrival_rate
    n = float(ts.n)
    gbar = max(1.0, ts.gen_mean)
    dec_tokens_per_req = max(0.0, gbar - 1.0)   # first token at prefill
    wire = max(est.wire_s, 0.0)
    dcap_total = dec.b_cap * dec.dp

    qp = pp = 0.0             # prefill pool: waiting / in prefill
    ql = 0.0                  # transfers queued on the shared wire
    qd = nd = 0.0             # decode pool: awaiting slot / decoding
    done = tok = energy = 0.0
    awp = al = awd = tpw = 0.0
    peak_n = 0.0
    iters = 0.0
    t = 0.0
    span = ts.span_s
    dt = span / steps if span > 0 else 0.0
    if dt <= 0:
        qp = n
        dt = _drain_dt_estimate(dec, n, gbar, dcap_total, steps) \
            + n * pre.t_pre / pre.dp / steps
    budget = 40 * steps
    remaining_arrivals = n
    first_tokens = 0.0

    for _ in range(budget):
        if done >= n - 1e-6:
            break
        if t >= span and qp + pp + ql + qd + nd <= 1e-9:
            break
        if remaining_arrivals > 0 and span > 0:
            a = min(remaining_arrivals, lam * dt)
            if t + dt >= span:
                a = remaining_arrivals
            qp += a
            remaining_arrivals -= a
        # ---- prefill pool (prefill-only iterations) ----
        if qp > 0:
            pp += qp            # admission gated only by prefill service
            qp = 0.0
        fin = 0.0
        if pp > 0:
            fin = min(pp, pre.dp * dt / pre.t_pre)
            pp -= fin
            energy += fin * pre.e_pre
            iters += fin
            first_tokens += fin
        # ---- shared wire: the cross-pool coupling term ----
        ql += fin
        if ql > 0:
            moved = min(ql, dt / wire) if wire > 0 else ql
            ql -= moved
            qd += moved
        # ---- decode pool (decode-only continuous batching) ----
        slots = dcap_total - nd
        if slots > 0 and qd > 0:
            x = min(qd, slots)
            qd -= x
            nd += x
        peak_n = max(peak_n, nd)
        if nd > 1e-9 and dec_tokens_per_req > 0:
            b = max(1.0, nd / dec.dp)
            tdb = dec.t_dec(b)
            emitted = nd / tdb * dt
            comp = min(nd, emitted / dec_tokens_per_req)
            tok += emitted
            tpw += emitted * tdb   # token-weighted inter-token interval
            nd -= comp
            done += comp
            energy += dt * dec.dp * dec.e_dec(b) / tdb
            iters += dt * dec.dp / tdb
        elif dec_tokens_per_req <= 0:
            done += nd + qd
            nd = qd = 0.0
        awp += (qp + pp) * dt
        al += ql * dt
        awd += qd * dt
        t += dt
        if t >= span and qp + pp + ql + qd + nd > 1e-9:
            backlog = qp + pp + ql + qd + nd
            dt = max(dt, _drain_dt_estimate(dec, backlog, gbar,
                                            dcap_total, steps))
    else:
        left = n - done
        b = max(1.0, min(dcap_total, nd) / dec.dp) if nd > 0 else 1.0
        mu = (nd / dec.t_dec(b) / max(dec_tokens_per_req, 1.0)
              if nd > 0 else pre.dp / pre.t_pre)
        tail = left / max(mu, 1e-9)
        awp += (qp + pp) * tail / 2.0
        tpw += left * dec_tokens_per_req * dec.t_dec(b)
        tok += left * dec_tokens_per_req
        t += tail
        done = n

    tok = min(tok, n * dec_tokens_per_req)
    total_tok = tok + min(first_tokens, n)       # first tokens count too
    energy += n * est.energy_j                   # every shipped cache
    ttft = awp / n + pre.t_pre     # queueing + own-prefill service floor
    # time between token 1 and 2: transfer (uncontended tail + queueing
    # on the wire) plus decode-slot wait; then decode pacing
    xfer = est.delay_s + al / n
    slot_wait = awd / n
    per_tok = tpw / tok if tok > 0 else 0.0
    if dec_tokens_per_req > 0:
        tpot = (xfer + slot_wait + per_tok * dec_tokens_per_req) \
            / dec_tokens_per_req
    else:
        tpot = 0.0
    return {"ttft": ttft, "tpot": tpot, "t": t, "energy": energy,
            "tokens": total_tok, "peak_n": peak_n, "iters": iters}
