"""Batching Module — dynamism-aware iteration-level batching (paper §3.3).

Simulates the request lifecycle of a continuous-batching serving system:

  * greedy admission whenever KV memory permits (no pre-allocation for
    future generated tokens — exactly the paper's greedy semantics),
  * per-iteration batch construction: prefill-priority contiguous batching
    (vLLM-style, the paper's default) or Sarathi-style chunked prefill
    (the paper's §4.5 batching extension: a chunk-size knob + per-request
    chunk counters),
  * KV growth of one token per active decode request per iteration,
  * preemption of the MOST-RECENTLY-added requests when KV overflows
    (paper: "the most recently added requests and their tokens are
    temporarily removed to free memory for earlier requests to complete"),
  * static batching (the paper's §2.3 strawman) and a max-batch-size cap
    (the paper's §4.6 SLO knob).

The module is cost-model-agnostic: it asks a ``step_cost(Workload)``
callback (the LLM Serving Simulator) for each iteration's duration/energy
and advances virtual time.  A fast-forward optimization batches runs of
uneventful decode iterations (no arrival/completion/overflow possible
within the run) into one cost evaluation at the midpoint KV state; this is
exact to first order (decode cost is ~linear in KV length) and is validated
against exact stepping in tests/test_batching.py.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .ir import Workload
from .trace import Request

RefetchDelay = Callable[[Request], float]


@dataclasses.dataclass
class BatchingPolicy:
    mode: str = "continuous"             # "continuous" | "static"
    chunked_prefill: Optional[int] = None  # Sarathi chunk size (tokens)
    max_batch_size: Optional[int] = None   # §4.6 SLO knob
    max_prefill_tokens: int = 16384        # per-iteration prefill budget
    fast_forward: bool = True
    fast_forward_cap: int = 64


@dataclasses.dataclass
class _Active:
    req: Request
    admitted_at: float
    order: int                    # admission order (for preemption LIFO)
    prefill_done: int = 0         # prompt tokens already processed
    generated: int = 0            # output tokens produced
    first_token_time: Optional[float] = None

    @property
    def kv_tokens(self) -> int:
        return self.prefill_done + self.generated

    @property
    def kv_reserved(self) -> int:
        """Admission-time reservation: an admitted request's prompt KV is
        committed even before its prefill runs (prevents admission storms
        that thrash prefill/evict cycles and starve decodes)."""
        return max(self.req.context_len, self.kv_tokens)

    @property
    def prefill_remaining(self) -> int:
        return self.req.context_len - self.prefill_done

    @property
    def done(self) -> bool:
        return self.generated >= self.req.gen_len

    def reset(self) -> None:
        self.prefill_done = 0
        self.generated = 0
        self.first_token_time = None


@dataclasses.dataclass
class RequestRecord:
    rid: int
    arrival: float
    context_len: int
    gen_len: int
    first_token_time: float = 0.0
    finish_time: float = 0.0
    preemptions: int = 0
    refetch_s: float = 0.0        # KV re-fetch delay charged on re-admissions

    @property
    def ttft(self) -> float:
        return self.first_token_time - self.arrival

    @property
    def tpot(self) -> float:
        if self.gen_len <= 1:
            return 0.0
        return (self.finish_time - self.first_token_time) / (self.gen_len - 1)

    @property
    def e2e(self) -> float:
        return self.finish_time - self.arrival


@dataclasses.dataclass
class BatchingResult:
    records: List[RequestRecord]
    iterations: int
    total_time: float
    total_energy: float
    preemptions: int
    peak_kv_tokens: int
    peak_batch: int
    kv_refetch_s: float = 0.0     # total re-fetch delay across all victims


StepCost = Callable[[Workload], Tuple[float, float]]


class BatchingModule:
    """One replica's iteration-level batching simulation."""

    def __init__(self, kv_capacity_tokens: int, policy: BatchingPolicy,
                 model_windows: Sequence = (None,),
                 max_sequences: int = 512,
                 is_encdec: bool = False,
                 role: str = "both",
                 refetch_delay: Optional[RefetchDelay] = None):
        if kv_capacity_tokens <= 0:
            raise ValueError("plan has no KV capacity — infeasible")
        if role not in ("both", "decode"):
            raise ValueError(f"unknown batching role {role!r}")
        self.capacity = kv_capacity_tokens
        self.policy = policy
        self.windows = tuple(model_windows)
        self.max_sequences = max_sequences
        self.is_encdec = is_encdec
        # role="decode" models the decode pool of a disaggregated deployment
        # (disagg/simulate.py): an admitted request's prompt KV is already
        # materialized (shipped from the prefill pool), so admission starts
        # it mid-lifecycle — prefill done, first token produced — and only
        # decode iterations run here.  A preempted request loses its cache
        # and must RE-FETCH it before re-admission: ``refetch_delay(req)``
        # returns the seconds the victim waits before it becomes admissible
        # again.  The coupled simulation passes the KV-transfer model's
        # full-cache wire time (a re-fetch cannot stream behind a prefill
        # that already happened); standalone use defaults to a re-prefill
        # estimate priced through ``step_cost`` on the victim's prompt.
        self.role = role
        self.refetch_delay = refetch_delay
        self._refetch_cache: Dict[int, float] = {}

    # -- public entry ---------------------------------------------------------

    def run(self, requests: Sequence[Request], step_cost: StepCost
            ) -> BatchingResult:
        if self.policy.mode == "static":
            if self.role == "decode":
                raise ValueError("decode role requires continuous batching")
            return self._run_static(requests, step_cost)
        return self._run_continuous(requests, step_cost)

    # -- continuous (iteration-level) batching --------------------------------

    def _run_continuous(self, requests: Sequence[Request],
                        step_cost: StepCost) -> BatchingResult:
        self._refetch_cache.clear()
        pending: List[Request] = sorted(requests, key=lambda r: r.arrival)
        active: List[_Active] = []
        records: Dict[int, RequestRecord] = {
            r.rid: RequestRecord(r.rid, r.arrival, r.context_len, r.gen_len)
            for r in requests}
        now = 0.0
        order = 0
        iters = 0
        energy = 0.0
        preemptions = 0
        peak_kv = 0
        peak_batch = 0
        kv_refetch_s = 0.0
        new_admissions: List[_Active] = []

        def kv_used() -> int:
            return sum(a.kv_tokens for a in active)

        def kv_reserved() -> int:
            return sum(a.kv_reserved for a in active)

        while pending or active:
            # ---- admission (greedy, memory-gated; paper §3.3) ----
            # headroom of one decode token per active sequence prevents the
            # admit -> prefill -> immediately-evict livelock
            while pending and pending[0].arrival <= now:
                headroom = len(active) + 1
                cap_ok = (kv_reserved() + pending[0].context_len
                          + headroom <= self.capacity)
                # liveness: an idle engine always admits its head request,
                # even one whose prompt alone exceeds KV capacity (it runs
                # solo and may overshoot — dual of never-evict-last)
                if not active:
                    cap_ok = True
                seq_ok = len(active) < self.max_sequences
                bs_ok = (self.policy.max_batch_size is None
                         or len(active) < self.policy.max_batch_size)
                if not (cap_ok and seq_ok and bs_ok):
                    break
                req = pending.pop(0)
                a = _Active(req=req, admitted_at=now, order=order)
                order += 1
                if self.role == "decode":
                    # prompt KV arrived from the prefill pool; the first
                    # token was already emitted there.  Standalone records
                    # stamp first-token at FIRST admission only (a re-fetch
                    # after preemption does not re-emit the first token); a
                    # coupled simulation (disagg/simulate.py) overwrites it
                    # with the prefill pool's timestamp.
                    a.prefill_done = req.context_len
                    a.generated = 1
                    a.first_token_time = now
                    if records[req.rid].preemptions == 0:
                        records[req.rid].first_token_time = now
                    if a.done:          # gen_len <= 1: nothing to decode
                        records[req.rid].finish_time = now
                        continue
                active.append(a)
                new_admissions.append(a)

            if not active:
                if not pending:
                    break
                now = max(now, pending[0].arrival)
                continue

            # ---- build this iteration's batch ----
            prefills = [a for a in active if a.prefill_remaining > 0]
            decodes = [a for a in active if a.prefill_remaining == 0
                       and not a.done]
            chunk = self.policy.chunked_prefill
            iter_prefills: List[Tuple[_Active, int]] = []
            budget = self.policy.max_prefill_tokens
            for a in prefills:
                if budget <= 0:
                    break
                take = min(a.prefill_remaining, budget)
                if chunk is not None:
                    take = min(take, chunk)
                iter_prefills.append((a, take))
                budget -= take
                if chunk is None and budget <= 0:
                    break
            # contiguous batching: prefill iterations exclude decodes;
            # chunked prefill mixes them (Sarathi-style).
            iter_decodes = decodes if (chunk is not None or not iter_prefills) \
                else []

            w = self._workload(iter_prefills, iter_decodes, new_admissions)
            new_admissions = []
            dur, en = step_cost(w)
            now += dur
            energy += en
            iters += 1
            peak_batch = max(peak_batch, len(iter_prefills) + len(iter_decodes))

            # ---- apply iteration effects ----
            for a, take in iter_prefills:
                a.prefill_done += take
                if a.prefill_remaining == 0:
                    # prompt fully processed -> first token emitted
                    a.generated = 1
                    a.first_token_time = now
                    records[a.req.rid].first_token_time = now
                    if a.done:
                        records[a.req.rid].finish_time = now
            for a in iter_decodes:
                a.generated += 1
            # sample peak BEFORE completions release their KV: the true
            # peak includes each finishing request's final token
            peak_kv = max(peak_kv, kv_used())

            finished = [a for a in active if a.done]
            for a in finished:
                records[a.req.rid].finish_time = now
            active = [a for a in active if not a.done]

            # ---- fast-forward uneventful decode runs ----
            if (self.policy.fast_forward and not iter_prefills and active
                    and all(a.prefill_remaining == 0 for a in active)):
                steps = self._ff_steps(active, pending, now, dur)
                if steps > 1:
                    kv_lens = [a.kv_tokens for a in active]
                    mid = [k + steps // 2 for k in kv_lens]
                    w_mid = self._workload_decode(mid, len(active))
                    d_mid, e_mid = step_cost(w_mid)
                    for a in active:
                        a.generated += steps
                    # per-token times: uniform at d_mid
                    now += d_mid * steps
                    energy += e_mid * steps
                    iters += steps
                    # peak inside the run = KV total at the END of the run
                    # (no arrival/completion/overflow can occur within it),
                    # just before completions are removed
                    peak_kv = max(peak_kv,
                                  sum(kv_lens) + steps * len(active))
                    finished = [a for a in active if a.done]
                    for a in finished:
                        over = a.generated - a.req.gen_len
                        records[a.req.rid].finish_time = now - d_mid * over
                        a.generated = a.req.gen_len
                    active = [a for a in active if not a.done]

            # ---- KV overflow -> preempt most-recent (paper §3.3) ----
            # never evict the LAST active request: a single sequence whose
            # prompt+generation exceeds capacity must run to completion
            # (evicting it would requeue-loop forever); real engines
            # likewise always keep at least one sequence scheduled.
            while kv_used() > self.capacity and len(active) > 1:
                victim = max(active, key=lambda a: a.order)
                active.remove(victim)
                victim.reset()
                records[victim.req.rid].preemptions += 1
                preemptions += 1
                if self.role == "decode":
                    # the shipped prompt KV was dropped; the victim only
                    # becomes admissible again after re-fetching it
                    delay = self._refetch(victim.req, step_cost)
                    records[victim.req.rid].refetch_s += delay
                    kv_refetch_s += delay
                    ready = now + delay
                    re_req = dataclasses.replace(victim.req, arrival=ready)
                    idx = 0
                    while (idx < len(pending)
                           and pending[idx].arrival <= ready):
                        idx += 1
                    pending.insert(idx, re_req)
                else:
                    pending.insert(0, victim.req)
            peak_kv = max(peak_kv, kv_used())

        return BatchingResult(records=list(records.values()),
                              iterations=iters, total_time=now,
                              total_energy=energy, preemptions=preemptions,
                              peak_kv_tokens=peak_kv, peak_batch=peak_batch,
                              kv_refetch_s=kv_refetch_s)

    def _refetch(self, req: Request, step_cost: StepCost) -> float:
        """Seconds a preempted decode-role request waits for its prompt KV.

        With a ``refetch_delay`` callback (the coupled disagg simulation
        wires in the KV-transfer model), that is authoritative.  Standalone,
        the cache must be re-materialized by a re-prefill, priced through
        the same ``step_cost`` callback as every other iteration (time only
        — the recompute runs on the prefill pool, not this one).
        """
        if req.rid not in self._refetch_cache:
            if self.refetch_delay is not None:
                delay = max(0.0, self.refetch_delay(req))
            else:
                w = Workload.from_batch(
                    [(req.context_len, req.context_len)], [], self.windows,
                    batch_sequences=1)
                delay, _ = step_cost(w)
            self._refetch_cache[req.rid] = delay
        return self._refetch_cache[req.rid]

    def _ff_steps(self, active: List[_Active], pending: List[Request],
                  now: float, dur: float) -> int:
        """Max decode steps guaranteed uneventful (no completion, arrival,
        or overflow)."""
        to_finish = min(a.req.gen_len - a.generated for a in active)
        kv = sum(a.kv_tokens for a in active)
        to_overflow = max(0, (self.capacity - kv)) // max(1, len(active))
        cap = self.policy.fast_forward_cap
        steps = min(to_finish, to_overflow, cap)
        if pending and dur > 0:
            to_arrival = int((pending[0].arrival - now) / dur)
            steps = min(steps, max(0, to_arrival))
        return max(steps, 0)

    # -- static batching (paper §2.3 baseline) ---------------------------------

    def _run_static(self, requests: Sequence[Request],
                    step_cost: StepCost) -> BatchingResult:
        pending = sorted(requests, key=lambda r: r.arrival)
        records = {r.rid: RequestRecord(r.rid, r.arrival, r.context_len,
                                        r.gen_len) for r in requests}
        bs = self.policy.max_batch_size or 32
        now, iters, energy = 0.0, 0, 0.0
        peak_kv = peak_batch = 0
        i = 0
        while i < len(pending):
            batch: List[Request] = []
            kv = 0
            while (i < len(pending) and len(batch) < bs
                   and kv + pending[i].context_len <= self.capacity):
                batch.append(pending[i])
                kv += pending[i].context_len
                i += 1
            if not batch:
                # head prompt alone exceeds KV capacity: admit it solo and
                # let it overshoot (the continuous path's liveness rule —
                # refusing it would loop forever with no progress)
                batch.append(pending[i])
                i += 1
            now = max(now, max(r.arrival for r in batch))
            acts = [_Active(req=r, admitted_at=now, order=j)
                    for j, r in enumerate(batch)]
            # prefill all
            w = self._workload([(a, a.req.context_len) for a in acts], [],
                               acts)
            dur, en = step_cost(w)
            now += dur
            energy += en
            iters += 1
            for a in acts:
                a.prefill_done = a.req.context_len
                a.generated = 1
                records[a.req.rid].first_token_time = now
                if a.done:            # gen_len == 1: done at prefill end,
                    # not when the whole batch drains
                    records[a.req.rid].finish_time = now
            peak_kv = max(peak_kv, sum(a.kv_tokens for a in acts))
            # decode until ALL finish (the static-batching inefficiency)
            max_gen = max(r.gen_len for r in batch)
            for _ in range(1, max_gen):
                live = [a for a in acts if not a.done]
                if not live:
                    break
                w = self._workload_decode([a.kv_tokens for a in live],
                                          len(live))
                dur, en = step_cost(w)
                now += dur
                energy += en
                iters += 1
                for a in acts:
                    if not a.done:
                        a.generated += 1
                        if a.done:
                            records[a.req.rid].finish_time = now
                peak_kv = max(peak_kv, sum(a.kv_tokens for a in acts))
            for a in acts:
                if records[a.req.rid].finish_time == 0.0:
                    records[a.req.rid].finish_time = now
            peak_batch = max(peak_batch, len(batch))
        return BatchingResult(records=list(records.values()),
                              iterations=iters, total_time=now,
                              total_energy=energy, preemptions=0,
                              peak_kv_tokens=peak_kv, peak_batch=peak_batch)

    # -- workload builders -----------------------------------------------------

    def _workload(self, iter_prefills, iter_decodes,
                  newly_admitted) -> Workload:
        chunks = [(take, a.prefill_done + take) for a, take in iter_prefills]
        kv_lens = [a.kv_tokens for a in iter_decodes]
        # decode role: the encoder already ran in the prefill pool — its
        # memory ships with the KV; only cross-attention reads remain here
        enc_tokens = sum(a.req.source_len for a in newly_admitted) \
            if self.is_encdec and self.role != "decode" else 0
        pre_src = [a.req.source_len for a, _ in iter_prefills] \
            if self.is_encdec else ()
        dec_src = [a.req.source_len for a in iter_decodes] \
            if self.is_encdec else ()
        n_seq = len(iter_prefills) + len(iter_decodes)
        return Workload.from_batch(chunks, kv_lens, self.windows,
                                   batch_sequences=n_seq,
                                   encoder_tokens=enc_tokens,
                                   prefill_source=pre_src,
                                   decode_source=dec_src)

    def _workload_decode(self, kv_lens: List[int], n_seq: int) -> Workload:
        return Workload.from_batch([], kv_lens, self.windows,
                                   batch_sequences=n_seq)
