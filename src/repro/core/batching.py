"""Batching Module — dynamism-aware iteration-level batching (paper §3.3).

Simulates the request lifecycle of a continuous-batching serving system:

  * greedy admission whenever KV memory permits (no pre-allocation for
    future generated tokens — exactly the paper's greedy semantics),
  * per-iteration batch construction: prefill-priority contiguous batching
    (vLLM-style, the paper's default) or Sarathi-style chunked prefill
    (the paper's §4.5 batching extension: a chunk-size knob + per-request
    chunk counters),
  * KV growth of one token per active decode request per iteration,
  * preemption of the MOST-RECENTLY-added requests when KV overflows
    (paper: "the most recently added requests and their tokens are
    temporarily removed to free memory for earlier requests to complete"),
  * static batching (the paper's §2.3 strawman) and a max-batch-size cap
    (the paper's §4.6 SLO knob).

The module is cost-model-agnostic: it asks a ``step_cost(Workload)``
callback (the LLM Serving Simulator) for each iteration's duration/energy
and advances virtual time.  A fast-forward optimization batches runs of
uneventful decode iterations (no arrival/completion/overflow possible
within the run) into one cost evaluation at the midpoint KV state; this is
exact to first order (decode cost is ~linear in KV length) and is validated
against exact stepping in tests/test_batching.py.

Since the event-engine refactor this module is a one-replica front for
``core/engine.py``: the continuous/chunked/static/decode-role mechanics
live in the engine's ``SchedulerPolicy`` variants (``ContinuousScheduler``
/ ``StaticScheduler``), where every replica of every pool — colocated or
disaggregated — shares them.  ``BatchingModule.run`` simply drives a
single-replica, single-pool engine, which is numerically identical to the
per-replica loop it replaced (tests/test_engine_golden.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

from .ir import Workload
from .trace import DEFAULT_SLO, Request, SLOClass

RefetchDelay = Callable[[Request], float]
# (victim request, its live KV tokens) -> (round-trip delay_s, energy_j)
SwapCost = Callable[[Request, int], Tuple[float, float]]


@dataclasses.dataclass
class BatchingPolicy:
    mode: str = "continuous"             # "continuous" | "static"
    chunked_prefill: Optional[int] = None  # Sarathi chunk size (tokens)
    max_batch_size: Optional[int] = None   # §4.6 SLO knob
    max_prefill_tokens: int = 16384        # per-iteration prefill budget
    fast_forward: bool = True
    fast_forward_cap: int = 64
    # memory-threshold admission control (continuous mode only): when a
    # busy replica's projected KV occupancy (reserved + the head request's
    # demand) would exceed ``admission_watermark * capacity``, the head is
    # deferred (held in queue; the default) or rejected outright
    # (dropped + counted).  None disables the gate (legacy behaviour).
    admission_watermark: Optional[float] = None
    admission_mode: str = "defer"        # "defer" | "reject"


@dataclasses.dataclass
class RequestRecord:
    rid: int
    arrival: float
    context_len: int
    gen_len: int
    first_token_time: float = 0.0
    finish_time: float = 0.0
    preemptions: int = 0          # total evictions (sacrifices + swaps)
    refetch_s: float = 0.0        # KV re-fetch delay charged on re-admissions
    swaps: int = 0                # evictions served by KV swap (not recompute)
    swap_s: float = 0.0           # host-link round-trip delay charged on swaps
    slo_class: SLOClass = DEFAULT_SLO
    rejected: bool = False        # dropped by admission control (never served)

    @property
    def ttft(self) -> float:
        return self.first_token_time - self.arrival

    @property
    def tpot(self) -> float:
        if self.gen_len <= 1:
            return 0.0
        return (self.finish_time - self.first_token_time) / (self.gen_len - 1)

    @property
    def e2e(self) -> float:
        return self.finish_time - self.arrival


@dataclasses.dataclass
class BatchingResult:
    records: List[RequestRecord]
    iterations: int
    total_time: float
    total_energy: float
    preemptions: int              # total evictions (sacrifices + swaps)
    peak_kv_tokens: int
    peak_batch: int
    kv_refetch_s: float = 0.0     # total re-fetch delay across all victims
    swap_outs: int = 0            # victims whose KV moved to host
    swap_ins: int = 0             # swapped victims re-admitted from host
    kv_swap_s: float = 0.0        # total host-link delay across all swaps
    admission_rejected: int = 0   # requests dropped at the watermark
    admission_deferred: int = 0   # unique requests held at the watermark


StepCost = Callable[[Workload], Tuple[float, float]]


class BatchingModule:
    """One replica's iteration-level batching simulation."""

    def __init__(self, kv_capacity_tokens: int, policy: BatchingPolicy,
                 model_windows: Sequence = (None,),
                 max_sequences: int = 512,
                 is_encdec: bool = False,
                 role: str = "both",
                 refetch_delay: Optional[RefetchDelay] = None,
                 preemption=None,
                 swap_cost: Optional[SwapCost] = None):
        if kv_capacity_tokens <= 0:
            raise ValueError("plan has no KV capacity — infeasible")
        if role not in ("both", "decode"):
            raise ValueError(f"unknown batching role {role!r}")
        self.capacity = kv_capacity_tokens
        self.policy = policy
        self.windows = tuple(model_windows)
        self.max_sequences = max_sequences
        self.is_encdec = is_encdec
        # KV-overflow handling: a PreemptionPolicy object or a menu string
        # ("sacrifice", "swap", "swap/lowest-priority-first", ...); None is
        # today's default, sacrifice + recent-first.  ``swap_cost`` prices
        # one victim's host round trip for the swap mechanism.
        self.preemption = preemption
        self.swap_cost = swap_cost
        # role="decode" models the decode pool of a disaggregated
        # deployment: an admitted request's prompt KV is already
        # materialized (shipped from the prefill pool), so admission starts
        # it mid-lifecycle — prefill done, first token produced — and only
        # decode iterations run here.  A preempted request loses its cache
        # and must RE-FETCH it before re-admission: ``refetch_delay(req)``
        # returns the seconds the victim waits before it becomes admissible
        # again.  The coupled simulation routes the re-fetch through the
        # event engine as a real re-prefill + transfer; standalone use
        # defaults to a re-prefill estimate priced through ``step_cost``.
        self.role = role
        self.refetch_delay = refetch_delay

    def run(self, requests: Sequence[Request], step_cost: StepCost
            ) -> BatchingResult:
        from .engine import Engine   # deferred: engine imports our types
        if self.policy.mode == "static" and self.role == "decode":
            raise ValueError("decode role requires continuous batching")
        engine = Engine()
        pool = engine.add_pool(
            "solo", [list(requests)], self.capacity, self.policy,
            step_cost, windows=self.windows,
            max_sequences=self.max_sequences, is_encdec=self.is_encdec,
            role=self.role, refetch_delay=self.refetch_delay,
            preemption=self.preemption, swap_cost=self.swap_cost)
        engine.run()
        results = pool.results()
        if not results:
            return BatchingResult(records=[], iterations=0, total_time=0.0,
                                  total_energy=0.0, preemptions=0,
                                  peak_kv_tokens=0, peak_batch=0)
        return results[0]
