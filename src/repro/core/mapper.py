"""Device Mapper — logical -> physical device assignment (paper §3.2.3).

The Plan Generator works top-down on a *logical* cluster; the Device Mapper
works bottom-up on the *physical* tree: the most communication-hungry
groups (intra-cell TP/EP groups, which run AllReduce/All-to-All every
layer) are packed into the lowest, highest-bandwidth level first; pipeline
stages (p2p only) next; model replicas (no steady-state traffic in serving)
last.  The result is an ``ExecutionPlan``: the scheme plus concrete device
ids and, per collective group, the network level its traffic crosses.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from .cluster import Cluster
from .planner import ParallelScheme


@dataclasses.dataclass(frozen=True)
class GroupPlacement:
    """Physical placement of one communicating group."""

    kind: str                 # "cell" | "stage_p2p" | "replica"
    device_ids: tuple
    span: int                 # devices spanned -> picks the network level

    @property
    def size(self) -> int:
        return len(self.device_ids)


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A physically-mapped parallel execution plan — the Batching Module /
    Serving Simulator's unit of evaluation."""

    scheme: ParallelScheme
    cluster: Cluster
    cell_groups: tuple        # one GroupPlacement per cell scheme (stage 0,
                              # replica 0 — stages/replicas are isomorphic)
    stage_span: int           # span of adjacent-stage p2p pairs
    replica_span: int
    device_offset: int = 0    # first physical id (pool partitioning)

    def label(self) -> str:
        return self.scheme.label()

    def collective_span(self, cell_index: int) -> int:
        return self.cell_groups[cell_index].span

    def describe(self) -> str:
        s = self.scheme
        lines = [f"plan {self.label()} on {self.cluster.name}",
                 f"  replicas={s.model_dp} stages={s.pp_stages} "
                 f"stage_devices={s.stage_devices}"]
        for g, cs in zip(self.cell_groups, s.cell_schemes):
            lvl = self.cluster.level_for_group(g.span)
            lines.append(
                f"  {cs.cell.name}[{cs.cell.kind}] dp={cs.dp} "
                f"{cs.method or 'tp'}={cs.shard} -> devices {g.device_ids} "
                f"(level {lvl.name})")
        return "\n".join(lines)


def map_scheme(scheme: ParallelScheme, cluster: Cluster,
               device_offset: int = 0) -> ExecutionPlan:
    """Assign logical devices to physical devices, bottom-up.

    Physical ids are laid out so that consecutive ids are topologically
    close (id // L1.group_size = node index), the standard tree numbering.
    Packing a group into consecutive ids therefore minimizes its span, and
    the bottom-up priority order (cells -> stages -> replicas) matches the
    paper: finer-grained parallelism gets the better links.

    ``device_offset`` places the scheme on the physical id range
    [offset, offset + total_devices) — disaggregated pools partition one
    cluster into contiguous id ranges (disagg/pools.py).
    """
    n_needed = scheme.total_devices
    if device_offset < 0:
        raise ValueError(f"negative device_offset {device_offset}")
    if device_offset + n_needed > cluster.num_devices:
        raise ValueError(
            f"scheme needs {n_needed} devices at offset {device_offset}; "
            f"cluster {cluster.name} has {cluster.num_devices}")

    s_dev = scheme.stage_devices
    l1 = cluster.levels[0].group_size

    # Stage-0/replica-0 cell groups: pack each cell's shard groups into
    # consecutive ids starting at the pool offset.  A cell with dp replicas
    # of width `shard` forms dp groups; the widest communicating unit is
    # `shard`.
    cell_groups: List[GroupPlacement] = []
    for cs in scheme.cell_schemes:
        ids = tuple(range(device_offset, device_offset + cs.shard))
        # span: if the shard group fits in an L1 group it spans `shard`
        # devices at level 1; otherwise it genuinely crosses levels.  An
        # offset pool whose range straddles a group boundary is promoted to
        # the level that actually covers the range.
        span = cs.shard
        if cs.shard > 1:
            for lvl in cluster.levels:
                if ids[0] // lvl.group_size == ids[-1] // lvl.group_size:
                    if lvl is not cluster.levels[0]:
                        span = max(span, lvl.group_size)
                    break
        cell_groups.append(GroupPlacement("cell", ids, span))

    # Adjacent pipeline stages occupy consecutive s_dev-sized chunks; the
    # boundary p2p pair spans the distance between the last device of one
    # chunk and the first of the next.
    if scheme.pp_stages > 1:
        stage_span = s_dev + 1 if s_dev < l1 else 2 * s_dev
        if device_offset % l1:
            # A misaligned pool can put a stage boundary across an L1
            # group even when s_dev < l1; promote the p2p span to the
            # level that covers the worst adjacent-stage boundary pair.
            R = scheme.devices_per_replica
            for r in range(scheme.model_dp):
                for p in range(1, scheme.pp_stages):
                    b = device_offset + r * R + p * s_dev
                    lvl = next(l for l in cluster.levels
                               if (b - 1) // l.group_size
                               == b // l.group_size)
                    if lvl is not cluster.levels[0]:
                        stage_span = max(stage_span, lvl.group_size)
        stage_span = min(stage_span, cluster.num_devices)
    else:
        stage_span = 1

    replica_span = min(scheme.devices_per_replica, cluster.num_devices)

    return ExecutionPlan(scheme=scheme, cluster=cluster,
                         cell_groups=tuple(cell_groups),
                         stage_span=stage_span, replica_span=replica_span,
                         device_offset=device_offset)


def assign_physical_ids(scheme: ParallelScheme, cluster: Cluster
                        ) -> Dict[str, List[Tuple[int, ...]]]:
    """Full physical id assignment for inspection/visualization and the
    locality tests: returns every group's device-id tuple.

    Layout: replica r occupies ids [r*R, (r+1)*R); within a replica, stage
    p occupies the next s_dev ids; within a stage, cell-DP replica q of a
    cell occupies the next `shard` ids.  This is the bottom-up packing
    realized as an id arithmetic scheme.
    """
    R = scheme.devices_per_replica
    s_dev = scheme.stage_devices
    out: Dict[str, List[Tuple[int, ...]]] = {"cell": [], "stage_p2p": [],
                                             "replica": []}
    for r in range(scheme.model_dp):
        base_r = r * R
        out["replica"].append(tuple(range(base_r, base_r + R)))
        for p in range(scheme.pp_stages):
            base_p = base_r + p * s_dev
            for cs in scheme.cell_schemes:
                for q in range(cs.dp):
                    start = base_p + q * cs.shard
                    out["cell"].append(tuple(range(start, start + cs.shard)))
            if p + 1 < scheme.pp_stages:
                out["stage_p2p"].append((base_p + s_dev - 1, base_p + s_dev))
    return out
