"""Fault injection and resilience accounting for the event engine.

A ``FaultSchedule`` is a seeded, fully deterministic description of what
goes wrong during one simulated serving run:

  * ``ReplicaFault`` — a fail-stop: at ``start`` the replica's device
    group drops out, its KV cache and in-flight iteration are lost, and
    its active + pending requests re-queue to surviving replicas through
    the pool's sacrifice/recompute path (decode-pool victims re-fetch
    their prompt KV through the prefill pool, exactly like a preemption).
    At ``repair`` (may be ``inf`` = never) the replica returns to service
    with an empty cache.
  * ``LinkDegradation`` — the cross-pool KV wire's effective bandwidth
    drops by ``factor`` inside ``[start, end)`` (transfer/refetch times
    multiply by ``factor``).
  * ``Straggler`` — a replica runs ``slowdown``x slower inside
    ``[start, end)`` (iteration time and energy scale; the step-cost
    cache stays fault-free — the scale is applied after the lookup, so
    degraded runs never pollute healthy cost tables).

Schedules are frozen and hashable: ``cost_key()`` extends the plan's
cost fingerprint so ``SharedCostStore`` entries priced under a degraded
cluster state can never collide with healthy-state entries.

``FaultSchedule.sample`` draws a schedule from seeded MTBF/MTTR
exponentials — same seed, same schedule, bit-identical simulation —
and ``fault_ensemble`` draws N independent schedules for resilience-
aware plan search (``objective="degraded_goodput"``).
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from .metrics import ResilienceReport, p95, slo_met


@dataclasses.dataclass(frozen=True)
class ReplicaFault:
    """Fail-stop of one replica: down at ``start``, back (with an empty
    KV cache) at ``repair``.  ``pool`` names the target pool ("serve",
    "prefill", "decode", ...) or "*" for every pool with that index."""

    replica: int
    start: float
    repair: float = math.inf
    pool: str = "*"

    def __post_init__(self):
        if self.replica < 0:
            raise ValueError(f"replica index must be >= 0, "
                             f"got {self.replica}")
        if self.start < 0 or self.repair <= self.start:
            raise ValueError(f"need 0 <= start < repair, got "
                             f"[{self.start}, {self.repair})")


@dataclasses.dataclass(frozen=True)
class LinkDegradation:
    """Cross-pool wire bandwidth degradation: transfer times multiply by
    ``factor`` (>= 1) inside ``[start, end)``."""

    start: float
    end: float
    factor: float

    def __post_init__(self):
        if self.start < 0 or self.end <= self.start:
            raise ValueError(f"need 0 <= start < end, got "
                             f"[{self.start}, {self.end})")
        if self.factor < 1.0:
            raise ValueError(f"degradation factor must be >= 1, "
                             f"got {self.factor}")


@dataclasses.dataclass(frozen=True)
class Straggler:
    """One replica runs ``slowdown``x slower inside ``[start, end)``."""

    replica: int
    start: float
    end: float
    slowdown: float
    pool: str = "*"

    def __post_init__(self):
        if self.replica < 0:
            raise ValueError(f"replica index must be >= 0, "
                             f"got {self.replica}")
        if self.start < 0 or self.end <= self.start:
            raise ValueError(f"need 0 <= start < end, got "
                             f"[{self.start}, {self.end})")
        if self.slowdown < 1.0:
            raise ValueError(f"slowdown must be >= 1, got {self.slowdown}")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """One run's worth of injected faults (empty by default).

    ``throttle`` models graceful admission degradation: while any replica
    of a pool is down, the pool's effective ``max_sequences`` is scaled
    by ``throttle`` (1.0 = no throttling; 0.5 = survivors admit at half
    their normal concurrency so queued work doesn't thrash the remaining
    KV into preemption storms).
    """

    replica_faults: Tuple[ReplicaFault, ...] = ()
    link_faults: Tuple[LinkDegradation, ...] = ()
    stragglers: Tuple[Straggler, ...] = ()
    throttle: float = 1.0

    def __post_init__(self):
        # tolerate lists at construction; store tuples (hashable)
        object.__setattr__(self, "replica_faults",
                           tuple(self.replica_faults))
        object.__setattr__(self, "link_faults", tuple(self.link_faults))
        object.__setattr__(self, "stragglers", tuple(self.stragglers))
        if not 0.0 < self.throttle <= 1.0:
            raise ValueError(f"throttle must lie in (0, 1], "
                             f"got {self.throttle}")

    @property
    def empty(self) -> bool:
        return not (self.replica_faults or self.link_faults
                    or self.stragglers)

    def cost_key(self) -> tuple:
        """Hashable fingerprint extension: everything that can change a
        priced step cost or transfer time under this schedule.  Appended
        to ``cost_fingerprint`` so a degraded cluster state's cache
        entries live in their own ``SharedCostStore`` table, never
        shared with healthy-state entries."""
        if self.empty:
            return ()
        return (self.replica_faults, self.link_faults, self.stragglers,
                self.throttle)

    # -- queries the engine and the report builder use ---------------------

    def link_factor(self, t: float) -> float:
        """Wire-time multiplier at time ``t`` (product of overlapping
        degradation windows; 1.0 outside all of them)."""
        f = 1.0
        for d in self.link_faults:
            if d.start <= t < d.end:
                f *= d.factor
        return f

    def restrict(self, pool_sizes: Dict[str, int]) -> "FaultSchedule":
        """The subset of this schedule that can actually fire against a
        deployment with ``pool_sizes`` replicas per pool (a fault aimed
        at replica 3 of a dp=2 plan is inert and excluded from
        availability accounting)."""
        def applies(pool: str, replica: int) -> bool:
            if pool == "*":
                return any(replica < n for n in pool_sizes.values())
            return replica < pool_sizes.get(pool, 0)

        return FaultSchedule(
            replica_faults=tuple(f for f in self.replica_faults
                                 if applies(f.pool, f.replica)),
            link_faults=self.link_faults,
            stragglers=tuple(s for s in self.stragglers
                             if applies(s.pool, s.replica)),
            throttle=self.throttle)

    def windows(self, horizon: float) -> List[Tuple[float, float]]:
        """Merged degraded-time intervals (any fault active), clipped to
        ``[0, horizon]`` — the split used for degraded-vs-nominal
        latency/goodput accounting."""
        raw = [(f.start, f.repair) for f in self.replica_faults]
        raw += [(d.start, d.end) for d in self.link_faults]
        raw += [(s.start, s.end) for s in self.stragglers]
        clipped = [(max(0.0, a), min(horizon, b)) for a, b in raw
                   if a < horizon and b > 0.0]
        if not clipped:
            return []
        clipped.sort()
        merged = [clipped[0]]
        for a, b in clipped[1:]:
            la, lb = merged[-1]
            if a <= lb:
                merged[-1] = (la, max(lb, b))
            else:
                merged.append((a, b))
        return merged

    # -- seeded sampling ---------------------------------------------------

    @classmethod
    def sample(cls, seed: int, horizon_s: float, n_replicas: int,
               pool: str = "*",
               replica_mtbf_s: Optional[float] = None,
               replica_mttr_s: float = 30.0,
               link_mtbf_s: Optional[float] = None,
               link_mttr_s: float = 15.0,
               link_factor: float = 4.0,
               straggler_mtbf_s: Optional[float] = None,
               straggler_mttr_s: float = 15.0,
               straggler_slowdown: float = 2.0,
               throttle: float = 1.0) -> "FaultSchedule":
        """Draw one schedule over ``[0, horizon_s)``.

        Each fault family is an alternating-renewal process per replica
        (up-time ~ Exp(mtbf), down-time ~ Exp(mttr)); ``None`` mtbf
        disables the family.  Deterministic in ``seed`` — the same seed
        always yields the same schedule, so a simulation under it is
        bit-reproducible.
        """
        rng = random.Random(seed)
        replica_faults: List[ReplicaFault] = []
        stragglers: List[Straggler] = []
        link_faults: List[LinkDegradation] = []

        def renewal(mtbf: float, mttr: float):
            """Alternating (down_start, down_end) windows in horizon."""
            t = rng.expovariate(1.0 / mtbf)
            while t < horizon_s:
                down = rng.expovariate(1.0 / mttr)
                yield t, t + down
                t += down + rng.expovariate(1.0 / mtbf)

        for i in range(n_replicas):
            if replica_mtbf_s is not None:
                for a, b in renewal(replica_mtbf_s, replica_mttr_s):
                    replica_faults.append(
                        ReplicaFault(replica=i, start=a, repair=b,
                                     pool=pool))
            if straggler_mtbf_s is not None:
                for a, b in renewal(straggler_mtbf_s, straggler_mttr_s):
                    stragglers.append(
                        Straggler(replica=i, start=a, end=b,
                                  slowdown=straggler_slowdown, pool=pool))
        if link_mtbf_s is not None:
            for a, b in renewal(link_mtbf_s, link_mttr_s):
                link_faults.append(
                    LinkDegradation(start=a, end=b, factor=link_factor))
        return cls(replica_faults=tuple(replica_faults),
                   link_faults=tuple(link_faults),
                   stragglers=tuple(stragglers), throttle=throttle)


def fault_ensemble(seed: int, n: int, horizon_s: float, n_replicas: int,
                   **kw) -> List[FaultSchedule]:
    """``n`` independent seeded schedules (seeds ``seed .. seed+n-1``) —
    the small ensemble resilience-aware search confirms finalists
    against."""
    if n <= 0:
        raise ValueError(f"ensemble size must be > 0, got {n}")
    return [FaultSchedule.sample(seed + i, horizon_s, n_replicas, **kw)
            for i in range(n)]


def normalize_faults(spec) -> Tuple[FaultSchedule, ...]:
    """The ``faults=`` plumbing: None -> (), one schedule -> (it,), a
    sequence of schedules -> tuple.  Empty schedules are dropped."""
    if spec is None:
        return ()
    if isinstance(spec, FaultSchedule):
        spec = (spec,)
    out = []
    for s in spec:
        if not isinstance(s, FaultSchedule):
            raise TypeError(f"faults must be FaultSchedule(s), "
                            f"got {type(s).__name__}")
        if not s.empty:
            out.append(s)
    return tuple(out)


# ---------------------------------------------------------------------------
# resilience accounting
# ---------------------------------------------------------------------------

def build_resilience(schedule: FaultSchedule, records: Sequence,
                     total_time: float, pool_sizes: Dict[str, int],
                     requeued: int) -> ResilienceReport:
    """One faulted run's ``ResilienceReport``.

    ``records`` are ALL request records (dropped requests carry
    ``finish_time == 0``); ``pool_sizes`` maps pool name -> replica
    count (availability normalizes by total replica-seconds).
    """
    applied = schedule.restrict(pool_sizes)
    n_replicas = sum(pool_sizes.values())
    horizon = max(total_time, 0.0)

    # availability: 1 - (down replica-seconds / total replica-seconds),
    # counting each applied fail-stop's clipped outage once per pool it
    # hits ("*" wildcards hit every pool with that replica index)
    down_s = 0.0
    for f in applied.replica_faults:
        hits = sum(1 for name, n in pool_sizes.items()
                   if f.replica < n and f.pool in ("*", name))
        down_s += hits * max(0.0, min(f.repair, horizon) - min(f.start,
                                                               horizon))
    denom = n_replicas * horizon
    availability = 1.0 - down_s / denom if denom > 0 else 1.0

    windows = applied.windows(horizon)
    degraded_s = sum(b - a for a, b in windows)

    def in_window(t: float) -> bool:
        return any(a <= t < b for a, b in windows)

    finished = [r for r in records if r.finish_time > 0.0]
    met = sum(1 for r in finished if slo_met(r))
    degraded = [r for r in finished if in_window(r.finish_time)]
    nominal = [r for r in finished if not in_window(r.finish_time)]
    met_deg = sum(1 for r in degraded if slo_met(r))
    met_nom = met - met_deg
    healthy_s = max(0.0, horizon - degraded_s)

    return ResilienceReport(
        availability=availability,
        requests_total=len(records),
        requests_finished=len(finished),
        requests_dropped=len(records) - len(finished),
        requests_requeued=requeued,
        degraded_seconds=degraded_s,
        goodput_rps=met / horizon if horizon > 0 else 0.0,
        degraded_window_goodput_rps=(met_deg / degraded_s
                                     if degraded_s > 0 else 0.0),
        nominal_window_goodput_rps=(met_nom / healthy_s
                                    if healthy_s > 0 else 0.0),
        ttft_p95_degraded=p95([r.ttft for r in degraded]),
        ttft_p95_nominal=p95([r.ttft for r in nominal]),
        tpot_p95_degraded=p95([r.tpot for r in degraded
                               if r.gen_len > 1]),
        tpot_p95_nominal=p95([r.tpot for r in nominal
                              if r.gen_len > 1]),
        ensemble_size=1)


def aggregate_resilience(members: Sequence[ResilienceReport]
                         ) -> ResilienceReport:
    """Ensemble aggregate: counts SUM across members (total outcomes
    over the whole ensemble), rates/percentiles/availability are the
    MEAN (expected behaviour under one random fault draw)."""
    if not members:
        raise ValueError("cannot aggregate an empty ensemble")
    n = len(members)

    def mean(field: str) -> float:
        return sum(getattr(m, field) for m in members) / n

    def total(field: str) -> int:
        return sum(getattr(m, field) for m in members)

    return ResilienceReport(
        availability=mean("availability"),
        requests_total=total("requests_total"),
        requests_finished=total("requests_finished"),
        requests_dropped=total("requests_dropped"),
        requests_requeued=total("requests_requeued"),
        degraded_seconds=mean("degraded_seconds"),
        goodput_rps=mean("goodput_rps"),
        degraded_window_goodput_rps=mean("degraded_window_goodput_rps"),
        nominal_window_goodput_rps=mean("nominal_window_goodput_rps"),
        ttft_p95_degraded=mean("ttft_p95_degraded"),
        ttft_p95_nominal=mean("ttft_p95_nominal"),
        tpot_p95_degraded=mean("tpot_p95_degraded"),
        tpot_p95_nominal=mean("tpot_p95_nominal"),
        ensemble_size=sum(m.ensemble_size for m in members))


def attach_resilience(nominal, fault_reports):
    """A copy of the nominal ``SimulationReport`` carrying the ensemble-
    aggregated resilience of its faulted re-simulations — the report
    shape the ``degraded_goodput`` objective ranks (nominal fields for
    every other objective, faulted goodput for resilience)."""
    members = [r.resilience for r in fault_reports
               if r.resilience is not None]
    if not members:
        return nominal
    return dataclasses.replace(nominal,
                               resilience=aggregate_resilience(members))
