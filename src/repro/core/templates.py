"""Parallel Templates (paper §3.2.2, Fig. 5).

A template specifies, per IR cell *type*, how the cell's tasks are
distributed across devices and which collectives synchronize adjacent
cells.  Templates are parameterized over the device count (Fig. 5(c)) and
the cell-level data-parallel degree (Fig. 5(b)), so one template covers all
models expressing that cell type — the reason APEX extends to new LLMs with
zero template work (Table 5).

A ``CellScheme`` is a template *instance*: (cell, dp, shard, method).  With
``dp`` replicas of the cell, each replica parallelized ``shard``-ways via
``method`` ("tp" head/column sharding, "ep" expert distribution), the cell
occupies ``dp * shard`` logical devices.  The scheme knows its per-device
weight/KV memory and how to scale the cell's OpCalls and emit collectives
for a given per-replica workload — everything the Serving Simulator needs.

Resharding between adjacent cells with different partitionings (Fig. 5(b))
is computed by ``reshard_collectives``: All-to-All + AllGather, matching the
paper's example.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

from .ir import (AttentionCell, Cell, CrossAttentionCell, MLACell, MLPCell,
                 MoECell, OpCall, SSMCell, Workload)
from .quant import QuantFormat


def expected_activated(visible: int, total: int, assignments: float) -> float:
    """Expected number of distinct activated experts among ``visible``
    experts hosted locally, with ``assignments`` token-to-expert assignments
    spread uniformly over ``total`` experts.  Drives the weight-read traffic
    of MoE cells: only activated experts' matrices are touched."""
    if assignments <= 0 or visible <= 0:
        return 0.0
    p_hit = 1.0 - (1.0 - 1.0 / total) ** assignments
    return visible * p_hit


def moe_expert_gemms(c, assignments: float, visible: int, g: int,
                     q: QuantFormat, all_activated: bool = False) -> list:
    """Per-device expert GEMMs: ``assignments`` token-assignments spread over
    the expected activated subset of ``visible`` local experts, each expert's
    matrices sliced ``g``-ways (g=1 for EP, TP degree for TP)."""
    if assignments <= 0:
        return []
    if all_activated:
        e_act = float(visible)
    else:
        e_act = max(1.0, expected_activated(visible, c.n_routed, assignments))
    m = assignments / e_act
    up_n = (2 if c.gated else 1) * c.d_ff_expert // g
    down_k = c.d_ff_expert // g
    up = Cell._gemm(m, up_n, c.d_model, q)
    down = Cell._gemm(m, c.d_model, down_k, q)
    # e_act experts each run (m x up_n x d) + (m x d x down_k): the
    # simulator charges the per-GEMM profiled time ``count`` times.
    return [dataclasses.replace(up, flops=up.flops * e_act,
                                bytes=up.bytes * e_act, count=e_act),
            dataclasses.replace(down, flops=down.flops * e_act,
                                bytes=down.bytes * e_act, count=e_act)]


@dataclasses.dataclass(frozen=True)
class CollectiveCall:
    """One collective emitted by a scheme for one iteration."""

    kind: str          # all_reduce | all_gather | reduce_scatter | all_to_all | p2p
    nbytes: float      # logical payload bytes
    group_size: int    # communicating devices

    def scaled(self, f: float) -> "CollectiveCall":
        return dataclasses.replace(self, nbytes=self.nbytes * f)


@dataclasses.dataclass(frozen=True)
class CellScheme:
    """A parallel-template instance for one cell."""

    cell: Cell
    dp: int
    shard: int
    method: str               # "tp" | "ep" | "none"
    ep_imbalance: float = 1.15  # hot-expert skew multiplier (paper §2.4 notes
                                # EP workload imbalance; calibrate per trace)

    @property
    def devices(self) -> int:
        return self.dp * self.shard

    # -- memory ---------------------------------------------------------------

    def weight_bytes_per_device(self, q: QuantFormat) -> float:
        c = self.cell
        g = self.shard
        if isinstance(c, (AttentionCell, CrossAttentionCell)):
            kv_shard = min(g, c.n_kv_heads)
            per = (2 * c.d_model * c.q_dim) / g \
                + (2 * c.d_model * c.kv_dim) / kv_shard
            if getattr(c, "qkv_bias", False):
                per += c.q_dim / g + 2 * c.kv_dim / kv_shard
            return per * q.weight_bytes
        if isinstance(c, MLACell):
            sharded = (c.d_model * c.n_heads * c.qk_head_dim
                       + c.kv_lora_rank * c.n_heads * (c.qk_nope_head_dim
                                                       + c.v_head_dim)
                       + c.n_heads * c.v_head_dim * c.d_model) / g
            repl = c.d_model * (c.kv_lora_rank + c.qk_rope_head_dim)
            return (sharded + repl) * q.weight_bytes
        if isinstance(c, MoECell):
            if self.method == "ep":
                local_experts = c.n_routed / g
                per = (local_experts + c.n_shared) * c.expert_params() \
                    + c.d_model * c.n_routed        # router replicated
            else:  # tp: every expert sharded g ways
                per = (c.n_routed + c.n_shared) * c.expert_params() / g \
                    + c.d_model * c.n_routed
            return per * q.weight_bytes
        # MLP / SSM: fully column/row sharded
        return self.cell.weight_params() / g * q.weight_bytes

    def kv_bytes_per_token_per_device(self, q: QuantFormat) -> float:
        """KV-cache bytes per BATCH token landing on one device.

        cell-DP splits the batch across replicas (factor dp); TP shards KV
        heads (factor min(shard, kv_heads)); the MLA latent is replicated
        across the TP group (factor 1)."""
        c = self.cell
        if isinstance(c, (AttentionCell,)):
            kv_shard = min(self.shard, c.n_kv_heads)
            return c.kv_bytes_per_token(q) / (self.dp * kv_shard)
        if isinstance(c, MLACell):
            return c.kv_bytes_per_token(q) / self.dp
        return 0.0

    def state_bytes_per_seq_per_device(self, q: QuantFormat) -> float:
        c = self.cell
        s = c.state_bytes_per_seq(q)
        if s == 0.0:
            return 0.0
        if isinstance(c, SSMCell):
            return s / (self.dp * self.shard)
        if isinstance(c, CrossAttentionCell):
            kv_shard = min(self.shard, c.n_kv_heads)
            return s / (self.dp * kv_shard)
        return s / self.dp

    # -- compute + communication ------------------------------------------------

    def compute_ops(self, w: Workload, q: QuantFormat) -> List[OpCall]:
        """Per-DEVICE OpCalls for this iteration's workload.

        ``w`` is the full (replica-group) workload; cell-DP divides tokens
        across replicas, the shard dimension divides each op's dims.  Ops
        are constructed with the *actual post-sharding shapes* so the
        profile lookup reflects the per-device operation (a TP-sharded GEMM
        is a thinner GEMM, not a scaled copy of the full one) — this is
        exactly what the paper's operation-level profiling provides."""
        per_replica = w.divided(self.dp)
        if per_replica.total_tokens == 0 and per_replica.encoder_tokens == 0:
            return []
        g = self.shard
        if g == 1:
            return self.cell.compute(per_replica, q)
        c = self.cell
        if isinstance(c, AttentionCell):
            return self._attn_ops(c, per_replica, q, g)
        if isinstance(c, MLACell):
            return self._mla_ops(c, per_replica, q, g)
        if isinstance(c, CrossAttentionCell):
            return self._cross_ops(c, per_replica, q, g)
        if isinstance(c, MLPCell):
            return self._mlp_ops(c, per_replica, q, g)
        if isinstance(c, MoECell):
            return self._moe_ops(c, per_replica, q, g)
        if isinstance(c, SSMCell):
            return self._ssm_ops(c, per_replica, q, g)
        return [op.scaled(1.0 / g) for op in c.compute(per_replica, q)]

    # -- per-cell-type sharded op construction (the template bodies) -----------

    @staticmethod
    def _attn_ops(c: AttentionCell, w: Workload, q: QuantFormat,
                  g: int) -> List[OpCall]:
        t = w.total_tokens
        kvg = min(g, c.n_kv_heads)
        ops = [Cell._gemm(t, c.q_dim // g + 2 * c.kv_dim // kvg, c.d_model, q),
               Cell._gemm(t, c.d_model, c.q_dim // g, q)]
        qk = w.prefill_qk(c.window)
        heads = c.n_heads // g
        if qk > 0:
            flops = 4.0 * qk * heads * c.head_dim
            mem = 2 * w.prefill_tokens * (c.q_dim // g) * q.act_bytes \
                + 2 * w.prefill_tokens * (c.kv_dim // kvg) * q.kv_bytes
            ops.append(OpCall("attn_prefill",
                              axes=(heads, c.head_dim, q.compute_dtype),
                              x=float(qk), flops=flops, bytes=mem))
        if w.decode_tokens > 0:
            kv_tok = w.decode_kv(c.window)
            kv_heads = max(1, c.n_kv_heads // kvg)
            flops = 4.0 * kv_tok * heads * c.head_dim
            mem = kv_tok * 2 * kv_heads * c.head_dim * q.kv_bytes
            ops.append(OpCall("attn_decode",
                              axes=(kv_heads, c.head_dim, q.compute_dtype),
                              x=float(kv_tok), flops=flops, bytes=mem))
        return ops

    @staticmethod
    def _mla_ops(c: MLACell, w: Workload, q: QuantFormat,
                 g: int) -> List[OpCall]:
        t = w.total_tokens
        h = c.n_heads // g
        ops = [
            Cell._gemm(t, h * c.qk_head_dim, c.d_model, q),           # W_q
            Cell._gemm(t, c.kv_lora_rank + c.qk_rope_head_dim,
                       c.d_model, q),                                 # W_dkv
            Cell._gemm(t, h * (c.qk_nope_head_dim + c.v_head_dim),
                       c.kv_lora_rank, q),                            # W_ukv
            Cell._gemm(t, c.d_model, h * c.v_head_dim, q),            # W_o
        ]
        qk = w.prefill_qk(None)
        if qk > 0:
            flops = 2.0 * qk * h * (c.qk_head_dim + c.v_head_dim)
            mem = 2 * w.prefill_tokens * h * c.qk_head_dim * q.act_bytes
            ops.append(OpCall("attn_prefill",
                              axes=(h, c.qk_head_dim, q.compute_dtype),
                              x=float(qk), flops=flops, bytes=mem))
        if w.decode_tokens > 0:
            kv_tok = w.decode_kv(None)
            # latent cache is replicated: every device reads the full latent
            flops = 2.0 * kv_tok * h * (c.kv_lora_rank + c.qk_rope_head_dim
                                        + c.v_head_dim)
            mem = kv_tok * c.kv_bytes_per_token(q)
            ops.append(OpCall("attn_decode",
                              axes=(h, c.kv_lora_rank, q.compute_dtype),
                              x=float(kv_tok), flops=flops, bytes=mem))
        return ops

    @staticmethod
    def _cross_ops(c: CrossAttentionCell, w: Workload, q: QuantFormat,
                   g: int) -> List[OpCall]:
        t = w.total_tokens
        kvg = min(g, c.n_kv_heads)
        h = c.n_heads // g
        ops = [Cell._gemm(t, c.q_dim // g, c.d_model, q),
               Cell._gemm(t, c.d_model, c.q_dim // g, q)]
        if w.encoder_tokens > 0:
            ops.append(Cell._gemm(w.encoder_tokens, 2 * c.kv_dim // kvg,
                                  c.d_model, q))
        if w.cross_prefill_qk > 0:
            flops = 4.0 * w.cross_prefill_qk * h * c.head_dim
            mem = 2 * w.prefill_tokens * (c.q_dim // g) * q.act_bytes
            ops.append(OpCall("attn_prefill",
                              axes=(h, c.head_dim, q.compute_dtype),
                              x=float(w.cross_prefill_qk), flops=flops,
                              bytes=mem))
        if w.cross_decode_kv > 0:
            kv_heads = max(1, c.n_kv_heads // kvg)
            flops = 4.0 * w.cross_decode_kv * h * c.head_dim
            mem = w.cross_decode_kv * 2 * kv_heads * c.head_dim * q.kv_bytes
            ops.append(OpCall("attn_decode",
                              axes=(kv_heads, c.head_dim, q.compute_dtype),
                              x=float(w.cross_decode_kv), flops=flops,
                              bytes=mem))
        return ops

    @staticmethod
    def _mlp_ops(c: MLPCell, w: Workload, q: QuantFormat,
                 g: int) -> List[OpCall]:
        t = w.total_tokens
        up_n = (2 if c.gated else 1) * c.d_ff // g
        return [Cell._gemm(t, up_n, c.d_model, q),
                Cell._gemm(t, c.d_model, c.d_ff // g, q)]

    def _moe_ops(self, c: MoECell, w: Workload, q: QuantFormat,
                 g: int) -> List[OpCall]:
        t = w.total_tokens
        ops = [Cell._gemm(t, c.n_routed, c.d_model, q)]   # router (replicated)
        if self.method == "ep":
            # This device hosts n_routed/g experts, each with FULL matrices;
            # it receives ~ t*top_k/g token-assignments (hot-expert skew
            # inflates the straggler's share — paper §2.4).
            visible = c.n_routed // g
            assigns = t * c.top_k / g * self.ep_imbalance
            ops += moe_expert_gemms(c, assigns, visible, 1, q)
            if c.n_shared:
                ops += moe_expert_gemms(c, float(t * c.n_shared), c.n_shared,
                                        1, q, all_activated=True)
        else:
            # TP: this device holds a 1/g slice of EVERY expert; activated
            # experts each incur a sliced-weight read.
            assigns = t * c.top_k
            ops += moe_expert_gemms(c, assigns, c.n_routed, g, q)
            if c.n_shared:
                ops += moe_expert_gemms(c, float(t * c.n_shared), c.n_shared,
                                        g, q, all_activated=True)
        return ops

    @staticmethod
    def _ssm_ops(c: SSMCell, w: Workload, q: QuantFormat,
                 g: int) -> List[OpCall]:
        t = w.total_tokens
        in_n = (2 * c.d_inner + 2 * c.n_groups * c.d_state + c.n_ssd_heads)
        d_in = c.d_inner // g
        ops = [Cell._gemm(t, in_n // g, c.d_model, q),
               Cell._gemm(t, c.d_model, d_in, q)]
        flops = 6.0 * t * d_in * c.d_state
        mem = t * d_in * q.act_bytes * 2
        if w.decode_tokens > 0:
            mem += w.batch_sequences * c.state_bytes_per_seq(q) / g
        ops.append(OpCall("ssd_scan",
                          axes=(d_in, c.d_state, q.compute_dtype),
                          x=float(t), flops=flops, bytes=mem))
        return ops

    def collectives(self, w: Workload, q: QuantFormat) -> List[CollectiveCall]:
        """Intra-cell collectives for one iteration (per replica)."""
        per_replica = w.divided(self.dp)
        t = per_replica.total_tokens
        if t == 0 or self.shard == 1:
            return []
        c = self.cell
        act = t * c.activation_bytes_per_token(q)
        if isinstance(c, MoECell) and self.method == "ep":
            # Dispatch + combine all-to-all.  Each device starts with t/g of
            # the tokens and sends each token's activation to its top-k
            # experts' devices: per-device payload = (t/g) * d * top_k bytes
            # — the lower-traffic pattern that makes APEX predict EP over TP
            # (paper Fig. 6 discussion).
            payload = act * c.top_k / self.shard
            return [CollectiveCall("all_to_all", payload, self.shard),
                    CollectiveCall("all_to_all", payload, self.shard)]
        # Megatron-style TP: one all-reduce on the full cell output.
        return [CollectiveCall("all_reduce", act, self.shard)]

    # -- validity -----------------------------------------------------------------

    def valid(self) -> bool:
        c, g = self.cell, self.shard
        if isinstance(c, (AttentionCell, CrossAttentionCell, MLACell)):
            return g <= c.num_tasks and c.num_tasks % g == 0
        if isinstance(c, MoECell):
            if self.method == "ep":
                return g <= c.n_routed and c.n_routed % g == 0
            return c.d_ff_expert % g == 0 and g <= c.d_ff_expert
        if isinstance(c, MLPCell):
            return c.d_ff % g == 0 and g <= c.d_ff
        if isinstance(c, SSMCell):
            return g <= c.n_ssd_heads and c.n_ssd_heads % g == 0
        return g == 1


# ---------------------------------------------------------------------------
# Template registry: cell kind -> scheme options for (cell, s devices)
# ---------------------------------------------------------------------------

def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def schemes_for_cell(cell: Cell, s: int, cell_dp: int) -> List[CellScheme]:
    """All template instances putting ``cell`` on ``s`` devices with
    ``cell_dp`` replicas (Algorithm 1 inner loop body)."""
    if s % cell_dp != 0:
        return []
    shard = s // cell_dp
    out: List[CellScheme] = []
    methods = ["tp"]
    if isinstance(cell, MoECell):
        methods = ["tp", "ep"] if shard > 1 else ["tp"]
    for m in methods:
        scheme = CellScheme(cell=cell, dp=cell_dp, shard=shard,
                            method=m if shard > 1 else "none")
        if scheme.valid():
            out.append(scheme)
    return out


def reshard_collectives(a: CellScheme, b: CellScheme, w: Workload,
                        q: QuantFormat, stage_devices: int
                        ) -> List[CollectiveCall]:
    """Collectives to move activations from cell A's layout to cell B's
    (paper Fig. 5(b): differing cell-DP degrees need All-to-All +
    AllGather; identical layouts need nothing beyond A's own sync)."""
    if a.dp == b.dp:
        return []
    t = w.total_tokens
    act_per_tok = a.cell.activation_bytes_per_token(q)
    payload = t * act_per_tok
    calls = [CollectiveCall("all_to_all", payload, stage_devices)]
    if b.dp < a.dp:
        # fewer replicas downstream -> each gathers a larger token slice
        calls.append(CollectiveCall("all_gather", payload / b.dp,
                                    stage_devices))
    else:
        calls.append(CollectiveCall("all_gather", payload / a.dp,
                                    stage_devices))
    return calls
