"""Quantization formats (paper §2.5, §4.1).

A format specifies byte widths for the three quantizable components —
weights, activations, KV cache — plus which compute dtype the MXU/tensor
cores run at (W8A8 runs fp8 matmuls; weight-only formats dequantize to the
activation dtype, so compute stays fp16/bf16).

The simulator uses formats to scale (1) weight memory, (2) KV-cache memory,
(3) GEMM compute rate, (4) bytes moved.  Registering a new format is one
dict entry (extensibility, paper Table 5).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class QuantFormat:
    name: str
    weight_bytes: float       # bytes per weight scalar
    act_bytes: float          # bytes per activation scalar
    kv_bytes: float           # bytes per KV-cache scalar
    compute_dtype: str        # dtype whose peak-FLOPs entry GEMMs run at

    @property
    def weight_dtype_bits(self) -> int:
        return int(self.weight_bytes * 8)


# The paper's evaluated formats: FP16 default, FP8 KV cache, W8A8 (weights +
# activations in FP8); we add bf16 (TPU-native) and AWQ-style INT4 weights
# (paper §2.5 cites AWQ as a weight-only method).
FORMATS = {
    "fp16": QuantFormat("fp16", 2.0, 2.0, 2.0, "fp16"),
    "bf16": QuantFormat("bf16", 2.0, 2.0, 2.0, "bf16"),
    "kv8": QuantFormat("kv8", 2.0, 2.0, 1.0, "fp16"),          # FP8 KV cache
    "w8a8": QuantFormat("w8a8", 1.0, 1.0, 1.0, "fp8"),          # FP8 W+A (+KV)
    "w4a16": QuantFormat("w4a16", 0.5, 2.0, 2.0, "fp16"),       # AWQ-style
}


def get_format(name: str) -> QuantFormat:
    if name not in FORMATS:
        raise KeyError(f"unknown quant format {name!r}; known: {sorted(FORMATS)}")
    return FORMATS[name]


def register_format(fmt: QuantFormat) -> None:
    """Extensibility hook — new quantization method in one call."""
    FORMATS[fmt.name] = fmt
