"""Parallel Scheme Generator — the paper's Algorithm 1 (§3.2.2).

Hierarchical top-down enumeration:

  model-level DP (replicas)  ->  pipeline stages  ->  per-cell cell-level DP
  ->  intra-cell TP/EP via Parallel Templates

with even-partitioning (divisor) constraints at every level.  The output is
a list of logical ``ParallelScheme``s — no physical devices assigned yet;
the Device Mapper (core/mapper.py) does that next.

Scaling note (paper challenge 2, "exponentially-growing design space"):
Algorithm 1 as printed iterates over each cell in the block.  For blocks
with many cells (gemma3's 6-layer local:global block has 12) a free per-cell
choice would be |options|^12.  We assign one scheme per cell *type* (all GQA
cells share a scheme, all MLP cells share a scheme, ...), which is exactly
the symmetry the paper's own Transformer-IR argument exploits — cells of the
same type are interchangeable — and keeps enumeration polynomial.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterator, List, Optional, Tuple

from .ir import Block, Cell, ModelIR
from .quant import QuantFormat, get_format
from .templates import CellScheme, schemes_for_cell


def divisors(n: int) -> List[int]:
    out = [d for d in range(1, int(n ** 0.5) + 1) if n % d == 0]
    out += [n // d for d in reversed(out) if d * d != n]
    return out


@dataclasses.dataclass(frozen=True)
class ParallelScheme:
    """A logical parallel scheme: the model mapped onto a logical device
    cluster (paper's two-stage mapping, first half)."""

    model: ModelIR
    model_dp: int                       # model replicas
    pp_stages: int                      # pipeline stages per replica
    cell_schemes: tuple                 # tuple[CellScheme] per cell in block
    quant: str = "fp16"

    @property
    def stage_devices(self) -> int:
        return max(s.devices for s in self.cell_schemes)

    @property
    def devices_per_replica(self) -> int:
        return self.stage_devices * self.pp_stages

    @property
    def total_devices(self) -> int:
        return self.devices_per_replica * self.model_dp

    @property
    def blocks_per_stage(self) -> int:
        return self.model.block.repeat // self.pp_stages

    def label(self) -> str:
        cells = ",".join(
            f"{s.cell.kind}:dp{s.dp}x{s.method or 'tp'}{s.shard}"
            for s in self.cell_schemes
        )
        return (f"DP{self.model_dp}xPP{self.pp_stages}x[{cells}]"
                f"@{self.quant}")

    def is_feasible_for_current_systems(self) -> bool:
        """The paper's 'Feasible Optimal' restriction (§4.2): current
        serving systems support uniform DP/PP/TP/EP but NOT cell-level DP
        or per-cell-type heterogeneous sharding."""
        if any(s.dp != 1 for s in self.cell_schemes):
            return False
        shards = {s.shard for s in self.cell_schemes}
        return len(shards) == 1

    # -- memory model ---------------------------------------------------------

    def weight_bytes_per_device(self) -> float:
        q = get_format(self.quant)
        per_block = sum(s.weight_bytes_per_device(q) for s in self.cell_schemes)
        total = per_block * self.blocks_per_stage
        # Embedding on the first stage, LM head on the last, vocab-sharded
        # across the stage's devices.  With PP > 1 each boundary stage holds
        # one table; with PP = 1 the same devices hold both.
        emb = self.model.embed_params() * q.weight_bytes
        if self.pp_stages > 1 and not self.model.tie_embeddings:
            emb /= 2
        total += emb / self.stage_devices
        if self.model.encoder is not None:
            total += (self.model.encoder.weight_bytes(q)
                      * self.model.encoder.repeat) / self.devices_per_replica
        return total

    def kv_bytes_per_token_per_device(self) -> float:
        q = get_format(self.quant)
        per_block = sum(s.kv_bytes_per_token_per_device(q)
                        for s in self.cell_schemes)
        return per_block * self.blocks_per_stage

    def state_bytes_per_seq_per_device(self) -> float:
        q = get_format(self.quant)
        per_block = sum(s.state_bytes_per_seq_per_device(q)
                        for s in self.cell_schemes)
        return per_block * self.blocks_per_stage

    def kv_token_capacity(self, hbm_bytes: float,
                          mem_util: float = 0.90,
                          workspace_frac: float = 0.05,
                          max_sequences: int = 512) -> int:
        """How many KV tokens one replica can hold (drives the Batching
        Module's admission decisions)."""
        budget = hbm_bytes * mem_util
        budget -= self.weight_bytes_per_device()
        budget -= hbm_bytes * workspace_frac
        budget -= self.state_bytes_per_seq_per_device() * max_sequences
        per_tok = self.kv_bytes_per_token_per_device()
        if budget <= 0:
            return 0
        if per_tok <= 0:
            return 10 ** 12  # attention-free: KV is not the binding constraint
        return int(budget / per_tok)


def generate_schemes(model: ModelIR, num_devices: int,
                     quant: str = "fp16",
                     max_model_dp: Optional[int] = None,
                     allow_cell_dp: bool = True,
                     max_schemes: int = 100000) -> List[ParallelScheme]:
    """Algorithm 1: enumerate parallel schemes for ``model`` on a logical
    cluster of ``num_devices`` devices."""
    n = num_devices
    block = model.block
    schemes: List[ParallelScheme] = []

    # Group block cells by type; each group gets one scheme choice.
    type_of_cell: List[int] = []
    groups: List[Cell] = []
    seen: Dict[tuple, int] = {}
    for c in block.cells:
        key = (c.kind, c.name)
        if key not in seen:
            seen[key] = len(groups)
            groups.append(c)
        type_of_cell.append(seen[key])

    for model_dp in divisors(n):                      # model-level DP
        if max_model_dp and model_dp > max_model_dp:
            continue
        m = n // model_dp                             # devices per replica
        for stages in divisors(m):                    # inter-layer (PP)
            if block.repeat % stages != 0:
                continue                              # even layer partitioning
            s = m // stages                           # devices per stage
            # per-cell-type options: cell-DP r (divisor of s) x template
            per_group_options: List[List[CellScheme]] = []
            for gcell in groups:
                opts: List[CellScheme] = []
                dps = divisors(s) if allow_cell_dp else [1]
                for r in dps:
                    opts.extend(schemes_for_cell(gcell, s, r))
                per_group_options.append(opts)
            if any(not o for o in per_group_options):
                continue
            for combo in itertools.product(*per_group_options):
                cell_schemes = tuple(combo[t] for t in type_of_cell)
                schemes.append(ParallelScheme(
                    model=model, model_dp=model_dp, pp_stages=stages,
                    cell_schemes=cell_schemes, quant=quant))
                if len(schemes) >= max_schemes:
                    return schemes
    return schemes


def prefilter_schemes(schemes: List[ParallelScheme], hbm_bytes: float,
                      frac: float = 0.92) -> List[ParallelScheme]:
    """Static weight-memory pre-filter.

    A scheme whose per-device weight bytes alone overflow ``frac`` of the
    device HBM can never simulate feasibly, so it is dropped before the
    (expensive) mapping + trace simulation.  Shared by the colocated search
    path (core/search.py) and the disaggregated per-pool pruning
    (disagg/pools.py) so both reject infeasible plans identically.
    """
    cap = hbm_bytes * frac
    return [s for s in schemes if s.weight_bytes_per_device() < cap]


def heuristic_scheme(model: ModelIR, num_devices: int, cluster=None,
                     quant: str = "fp16") -> ParallelScheme:
    """The baseline plan (paper §4.2): TP within a node, PP across nodes."""
    if cluster is not None and len(cluster.levels) > 1:
        node = cluster.levels[0].group_size
        stages = max(1, num_devices // node)
        while model.block.repeat % stages != 0 and stages > 1:
            stages //= 2
        tp = num_devices // stages
    else:
        tp, stages = num_devices, 1
    cells = []
    for c in model.block.cells:
        opts = schemes_for_cell(c, tp, 1)
        if not opts:
            # fall back to the largest valid TP degree
            for g in sorted(divisors(tp), reverse=True):
                opts = schemes_for_cell(c, g, 1)
                if opts:
                    break
        cells.append(opts[0])
    return ParallelScheme(model=model, model_dp=1, pp_stages=stages,
                          cell_schemes=tuple(cells), quant=quant)
