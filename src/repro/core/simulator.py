"""LLM Serving Simulator (paper §3.4).

Estimates per-iteration execution time and energy for an ExecutionPlan by
querying the operation-level ProfileStore, then extrapolates block results
to the full model:

  * only ONE Transformer block is costed; per-stage time multiplies by
    blocks-per-stage (the paper's repetitive-structure trick, Fig. 8),
  * iteration latency = max over pipeline stages (+ inter-stage p2p), since
    continuous batching pipelines successive iterations and the slowest
    stage paces the system (paper: "taking the maximum across all pipeline
    stages"),
  * energy = SUM across all stages and replicas (all devices burn power),
  * cell-level collectives are costed at the network level chosen by the
    Device Mapper.

It reports the paper's serving metrics: TTFT, TPOT, P95 latency, end-to-end
latency, energy, MFU and MBU.

Full-trace simulation runs on the event engine (core/engine.py): each
model-DP replica is an engine actor, and the per-iteration cost callback
is wrapped in a ``StepCostCache`` so identical iterations recurring across
the event stream are costed once (utilization tallies are replayed in
replica order afterwards, keeping MFU/MBU bit-identical to the sequential
accounting of the legacy loop).
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from .batching import BatchingPolicy, SwapCost
from .cluster import NetworkLevel, host_link
from .engine import Engine, SharedCostStore, StepCostCache
from .ir import Workload
from .mapper import ExecutionPlan
from .metrics import SimulationReport, p95, request_metrics, \
    windowed_metrics
from .profiles import CollectiveModel, ProfileStore
from .quant import get_format
from .templates import reshard_collectives
from .trace import Request, retag_slo

# Backwards-compatible aliases: SimulationReport and the p95 estimator
# used to live here (core/metrics.py is their home now).
_p95 = p95


def default_swap_cost(scheme, link: Optional[NetworkLevel] = None,
                      power=None) -> SwapCost:
    """Price one victim's KV round trip over the device<->host link.

    Each device of the replica swaps its own KV shard concurrently, so
    the delay is the per-device shard's serialization time on ``link``
    (default: the PCIe host link) — out now, back in before resumption,
    hence the factor of two — while energy charges every device of the
    replica at DMA-level utilization for the trip.
    """
    link = link or host_link()
    per_tok = scheme.kv_bytes_per_token_per_device()
    per_seq = scheme.state_bytes_per_seq_per_device()
    n_dev = scheme.devices_per_replica

    def cost(req: Request, kv_tokens: int):
        nbytes = per_tok * kv_tokens + per_seq
        t = nbytes / link.bw_per_device + link.launch_s + link.latency_s
        roundtrip = 2.0 * t
        energy = (power.energy(roundtrip, utilization=0.15) * n_dev
                  if power is not None else 0.0)
        return roundtrip, energy

    return cost


def _cluster_key(cluster) -> tuple:
    """A ``Cluster`` as a hashable tuple (``DeviceSpec.peak_flops`` is a
    dict, so the dataclass itself cannot key a table).  Covers every
    field the profile and collective models read: device rates/power and
    all interconnect levels."""
    d = cluster.device
    return (cluster.name, cluster.num_devices, cluster.levels,
            d.name, tuple(sorted(d.peak_flops.items())), d.hbm_bytes,
            d.hbm_bw, d.idle_power_w, d.peak_power_w, d.base_freq_ghz)


def cost_fingerprint(plan: ExecutionPlan, store: ProfileStore,
                     coll: CollectiveModel, fault_key: tuple = ()) -> tuple:
    """Everything ``PlanSimulator.iteration_cost`` reads, as a hashable key.

    Two plans with equal fingerprints price every workload identically, so
    they may share one ``SharedCostStore`` table.  The fingerprint covers
    the per-stage scheme layout (cells, sharding, blocks-per-stage via
    ``pp_stages``), the quant format, the cluster (device + network specs
    feed both ``ProfileStore.query`` and ``CollectiveModel.query``), the
    pipeline span, and the profile-backend knobs.  It deliberately
    EXCLUDES ``model_dp``: replicas of the same layout run identical
    iterations, and sharing across DP widths is the big cross-plan win.
    All components are frozen dataclasses, so equality is structural.

    ``fault_key`` (``FaultSchedule.cost_key()``) segregates runs under a
    degraded cluster state: straggler-scaled or link-degraded dynamics
    must never reuse (or seed) a healthy state's table.
    """
    scheme = plan.scheme
    base = (scheme.model, scheme.pp_stages, scheme.cell_schemes,
            scheme.quant, plan.stage_span,
            tuple(g.span for g in plan.cell_groups),
            _cluster_key(plan.cluster),
            getattr(store.backend, "freq_ghz", None), store.grid_stride)
    if fault_key:
        base = base + (("faults",) + tuple(fault_key),)
    return base


class PlanSimulator:
    """Costs one ExecutionPlan's iterations and runs full-trace simulations."""

    def __init__(self, plan: ExecutionPlan, store: ProfileStore,
                 coll: CollectiveModel,
                 cost_store: Optional[SharedCostStore] = None):
        self.plan = plan
        self.store = store
        self.coll = coll
        self.cost_store = cost_store
        self._fingerprint: Optional[tuple] = None
        self.scheme = plan.scheme
        self.q = get_format(self.scheme.quant)
        self._flops_accum = 0.0
        self._bytes_accum = 0.0
        self._last_inc = (0.0, 0.0)   # per-call accumulator increment
        # last simulate()'s StepCostCache counters (cost-reuse telemetry)
        self.cache_stats = {"hits": 0, "misses": 0, "entries": 0,
                            "evictions": 0}
        # set by simulate(stop_at=...): unfinished work at the epoch stop
        self.carryover: Optional[dict] = None
        # distinct attention windows in the model (for Workload building)
        self.windows = sorted(
            {getattr(c, "window", None) for c in self.scheme.model.block.cells},
            key=lambda w: (w is None, w))

    def fingerprint(self) -> tuple:
        """This plan's cost-model fingerprint (computed once, cached —
        hashing the scheme's cell tree is not free on the hot path)."""
        if self._fingerprint is None:
            self._fingerprint = cost_fingerprint(self.plan, self.store,
                                                 self.coll)
        return self._fingerprint

    def cost_cache(self, fault_key: tuple = ()) -> StepCostCache:
        """A fresh ``StepCostCache`` for one run: a view onto the shared
        store's fingerprint table when one was provided, private
        otherwise (direct ``PlanSimulator`` use stays golden-identical).
        A non-empty ``fault_key`` selects the degraded-state bucket —
        healthy-state entries are never visible to a faulted run."""
        if self.cost_store is not None:
            fp = self.fingerprint()
            if fault_key:
                fp = fp + (("faults",) + tuple(fault_key),)
            return self.cost_store.cache(fp, self.iteration_cost,
                                         owner=self)
        return StepCostCache(self.iteration_cost, owner=self)

    # -- per-iteration cost (the engine's step_cost callback) -----------------

    def iteration_cost(self, w: Workload) -> Tuple[float, float]:
        """(time_s, energy_j) for one iteration of one replica.

        Pipeline model: the batch is split into ``pp`` microbatches (paper
        §2.4: "input requests are split into micro-batches to flow through
        the pipeline stages"); at steady state (continuous batching keeps
        the pipeline full) the slowest stage paces the system, so one full
        iteration of the whole batch takes  pp * (slowest stage's
        microbatch time).  This is the paper's "max across pipeline stages"
        extrapolation applied at microbatch granularity — and it correctly
        denies PP a latency win in the flat memory-bound decode regime
        (stage time ~ weight reads, independent of microbatch size).

        Side effect: folds the iteration's FLOP/byte tallies into
        ``_flops_accum``/``_bytes_accum`` as ONE increment per call and
        exposes it as ``_last_inc`` so the engine's ``StepCostCache`` can
        replay cached calls into the same accounting.
        """
        if w.is_empty():
            self._last_inc = (0.0, 0.0)
            return 0.0, 0.0
        scheme = self.scheme
        pp = scheme.pp_stages
        mb = w.divided(pp)                    # one microbatch's workload
        stage_time = 0.0                      # per stage-visit (microbatch)
        stage_energy = 0.0
        stage_flops = 0.0
        stage_bytes = 0.0
        enc_flops = 0.0
        # One block's cells on one microbatch, scaled by blocks-per-stage.
        for idx, cs in enumerate(scheme.cell_schemes):
            for op in cs.compute_ops(mb, self.q):
                t, e = self.store.query(op.op, op.axes, op.x)
                stage_time += t * op.count
                stage_energy += e * op.count * cs.devices
                stage_flops += op.flops * cs.devices
                stage_bytes += op.bytes * cs.devices
            for cc in cs.collectives(mb, self.q):
                t, e = self.coll.query(cc.kind, cc.nbytes, cc.group_size)
                stage_time += t
                stage_energy += e
            nxt = scheme.cell_schemes[(idx + 1) % len(scheme.cell_schemes)]
            for cc in reshard_collectives(cs, nxt, mb, self.q,
                                          scheme.stage_devices):
                t, e = self.coll.query(cc.kind, cc.nbytes, cc.group_size)
                stage_time += t
                stage_energy += e
        bps = scheme.blocks_per_stage
        stage_time *= bps
        stage_energy *= bps
        stage_flops *= bps
        stage_bytes *= bps

        # Boundary work on the pacing stage: encoder (first stage) and LM
        # head (last stage) — the slower of the two paces the pipeline.
        extra_time = 0.0
        if scheme.model.encoder is not None and mb.encoder_tokens > 0:
            enc_w = Workload(prefill_tokens=mb.encoder_tokens,
                             windows={None: (float(mb.encoder_tokens) ** 2
                                             / max(1, mb.batch_sequences),
                                             0.0)},
                             batch_sequences=mb.batch_sequences)
            enc_t, enc_e, enc_flops = self._encoder_cost(enc_w)
            extra_time = max(extra_time, enc_t)
            stage_energy += enc_e
        head_tokens = mb.decode_tokens + (1 if mb.prefill_tokens else 0)
        if head_tokens:
            op = scheme.model.lm_head_opcall(head_tokens, self.q)
            t, e = self.store.query(op.op,
                                    (op.axes[0] // scheme.stage_devices,
                                     op.axes[1], op.axes[2]), op.x)
            extra_time = max(extra_time, t)
            stage_energy += e * scheme.stage_devices
            stage_flops += op.flops / pp  # amortize over the pp accounting

        visit_time = stage_time + extra_time
        if pp > 1:
            act = mb.total_tokens * scheme.model.d_model * self.q.act_bytes
            t_p2p, e_p2p = self.coll.query("p2p", act, self.plan.stage_span)
            visit_time += t_p2p
            stage_energy += e_p2p

        # pp stage-visits per microbatch x pp microbatches per iteration:
        iter_time = pp * visit_time
        iter_energy = pp * pp * stage_energy
        inc_f = stage_flops * pp * pp + enc_flops
        inc_b = stage_bytes * pp * pp
        self._flops_accum += inc_f
        self._bytes_accum += inc_b
        self._last_inc = (inc_f, inc_b)
        return iter_time, iter_energy

    def _encoder_cost(self, enc_w: Workload) -> Tuple[float, float, float]:
        enc = self.scheme.model.encoder
        t_total = e_total = f_total = 0.0
        # Encoder cells reuse the FIRST cell scheme's sharding (encoder TP
        # tracks decoder TP — standard enc-dec deployment).
        ref = self.scheme.cell_schemes[0]
        for cell in enc.cells:
            for op in cell.compute(enc_w, self.q):
                t, e = self.store.query(op.op, op.axes, op.x / ref.shard)
                t_total += t
                e_total += e * ref.shard
                f_total += op.flops
        return t_total * enc.repeat, e_total * enc.repeat, f_total

    # -- full-trace simulation --------------------------------------------------

    @staticmethod
    def _collect_carryover(pool) -> dict:
        """Unfinished requests at an epoch stop, for the next segment.

        ``{rid: (request, snapshot, partial_record)}`` where ``snapshot``
        is ``(prefill_done, generated, first_token_time)`` for requests
        with live or swap-parked KV (None for queued, not-yet-started
        ones), and ``partial_record`` carries the progress stats accrued
        so far (preemptions, refetch/swap delays, a stamped first-token
        time) for the controller's record merge."""
        carry: dict = {}
        for rep in pool.replicas:
            for a in rep.active:
                rid = a.req.rid
                carry[rid] = (a.req,
                              (a.prefill_done, a.generated,
                               a.first_token_time),
                              rep.records.get(rid))
            for req in rep.pending:
                snap = rep.swapped.get(req.rid)
                carry[req.rid] = (req, snap, rep.records.get(req.rid))
        return carry

    def simulate(self, requests: Sequence[Request],
                 policy: Optional[BatchingPolicy] = None,
                 keep_records: bool = False,
                 preemption=None,
                 swap_cost: Optional[SwapCost] = None,
                 slo_classes=None,
                 faults=None,
                 window_s: Optional[float] = None,
                 stop_at: Optional[float] = None,
                 carry_in: Optional[dict] = None) -> SimulationReport:
        """``preemption`` selects the KV-overflow policy (menu string or
        ``PreemptionPolicy``; None = sacrifice + recent-first, the
        golden-pinned default); ``swap_cost`` overrides the PCIe host-link
        pricing the swap mechanism defaults to.  ``slo_classes`` re-tags
        the trace's SLO classes by name (``trace.retag_slo``).

        ``faults`` (a ``core.faults.FaultSchedule``) injects fail-stops/
        stragglers into the run; the report then carries a
        ``resilience`` block, and unfinished requests (stranded on a dead
        replica) are dropped from the latency stats.  An empty schedule
        is bit-identical to ``faults=None``.

        ``window_s`` attaches a per-window metric timeline
        (``metrics.windowed_metrics``) to the report — the lens for
        non-stationary traces, where whole-run aggregates hide the peak
        hour.  Admission-rejected requests (see
        ``BatchingPolicy.admission_watermark``) are excluded from the
        latency/goodput stats and counted in ``admission_rejected``.

        ``stop_at`` halts the run at an epoch boundary (core/dynamic.py):
        the engine stops at that instant, unfinished requests are dropped
        from the stats, and ``self.carryover`` maps each unfinished rid to
        ``(request, progress_snapshot_or_None, partial_record_or_None)``
        so the next plan segment can resume them.  ``carry_in`` is the
        inverse: ``{rid: (prefill_done, generated, first_token_time)}``
        snapshots pre-seeded as swap-parked progress, restored without
        recompute when the rid (which must be in ``requests``) is
        admitted."""
        policy = policy or BatchingPolicy()
        scheme = self.scheme
        requests = retag_slo(requests, slo_classes)
        faulted = faults is not None and not faults.empty
        self._flops_accum = 0.0
        self._bytes_accum = 0.0
        cap = scheme.kv_token_capacity(self.plan.cluster.device.hbm_bytes)
        if cap <= 0:
            return SimulationReport.infeasible(scheme.label())

        # model-level DP: round-robin request routing to independent replicas
        buckets: List[List[Request]] = [[] for _ in range(scheme.model_dp)]
        for i, r in enumerate(requests):
            buckets[i % scheme.model_dp].append(r)

        engine = Engine()
        cache = self.cost_cache(
            fault_key=faults.cost_key() if faulted else ())
        pool = engine.add_pool(
            "serve", buckets, cap, policy, cache,
            windows=self.windows,
            is_encdec=scheme.model.encoder is not None,
            preemption=preemption,
            swap_cost=swap_cost or default_swap_cost(
                scheme, power=self.coll.power))
        if carry_in:
            # migrated in-flight progress: park each snapshot on the
            # replica that owns the rid — admission restores it through
            # the swap-in path (no recompute, no first-token re-stamp)
            for rep in pool.replicas:
                for rid, snap in carry_in.items():
                    if rid in rep.records:
                        rep.swapped[rid] = tuple(snap)
        if faulted:
            engine.install_faults(faults)
        if stop_at is not None:
            engine.install_epoch(stop_at, lambda t: engine.stop())
        engine.run()
        self.carryover = (self._collect_carryover(pool)
                          if stop_at is not None else None)
        results = pool.results()
        self.cache_stats = cache.stats()

        # replay the memoized cost calls into the utilization accumulators
        # in replica order (the legacy sequential summation order)
        self._flops_accum = 0.0
        self._bytes_accum = 0.0
        pool.replay_accumulators(self)

        all_records = [rec for res in results for rec in res.records]
        served = [r for r in all_records if not r.rejected]
        if faulted or stop_at is not None:
            # a request stranded on a dead replica (or still in flight at
            # an epoch stop) never finished — excluded from the
            # latency/goodput stats; epoch stops hand it to the next
            # segment via ``self.carryover``
            records = [r for r in served if r.finish_time > 0.0]
        else:
            records = served
        total_time = max(res.total_time for res in results)
        total_energy = sum(res.total_energy for res in results)
        gen_tokens = sum(r.gen_len for r in records)

        n_dev = scheme.total_devices
        peak = self.plan.cluster.device.flops(self.q.compute_dtype)
        bw = self.plan.cluster.device.hbm_bw
        mfu = (self._flops_accum
               / (total_time * n_dev * peak)) if total_time > 0 else 0.0
        mbu = (self._bytes_accum
               / (total_time * n_dev * bw)) if total_time > 0 else 0.0

        resilience = None
        if faulted:
            from .faults import build_resilience
            # admission-rejected requests are accounted separately — they
            # are deliberate drops, not fault-induced ones
            resilience = build_resilience(
                faults, served, total_time,
                {"serve": scheme.model_dp}, engine.fault_requeues)

        return SimulationReport(
            plan_label=scheme.label(),
            e2e_latency=total_time,
            total_energy=total_energy,
            throughput_tok_s=gen_tokens / total_time if total_time else 0.0,
            mfu=min(mfu, 1.0), mbu=min(mbu, 1.0),
            iterations=sum(r.iterations for r in results),
            preemptions=sum(r.preemptions for r in results),
            peak_kv_tokens=max(r.peak_kv_tokens for r in results),
            peak_batch=max(r.peak_batch for r in results),
            feasible=True,
            records=records if keep_records else None,
            swap_outs=sum(r.swap_outs for r in results),
            swap_ins=sum(r.swap_ins for r in results),
            kv_swap_s=sum(r.kv_swap_s for r in results),
            kv_refetch_s=sum(r.kv_refetch_s for r in results),
            resilience=resilience,
            admission_rejected=sum(r.admission_rejected for r in results),
            admission_deferred=sum(r.admission_deferred for r in results),
            windows=(windowed_metrics(records, window_s=window_s,
                                      horizon=total_time)
                     if window_s is not None else None),
            **request_metrics(records, total_time))
