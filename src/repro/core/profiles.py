"""Operation-level profiling results + linear interpolation (paper §3.5).

The paper's Offline Profiler measures key transformer operations (GEMM,
attention, collectives) on the target hardware across a grid of sizes; the
Serving Simulator then looks up (and linearly interpolates) those tables.
Profiling is a one-time per-cluster cost amortized across simulations.

We reproduce the exact mechanism with swappable *backends* that stand in
for the profiler:

  * ``AnalyticBackend`` — closed-form roofline-with-efficiency-curve model
    of the target device (H100/H200/TPU v5e presets).  This is what the
    GPU-hours profiling job would have produced, up to calibration.
  * ``MeasuredBackend`` — actually executes the operation in JAX on THIS
    machine's CPU and times it.  Used by the fidelity experiments (Fig. 6/7
    reproduction): the simulator predicts, the real JAX serving engine runs,
    both on the same silicon.

Either way the simulator only ever sees a ``ProfileStore``: sparse grids of
(x, time, energy) points per (op, axes) key, linear interpolation between
grid points, linear extrapolation at the edges — faithful to §3.4's "If a
specific data point is missing, the Simulator applies linear interpolation
between the nearest profiling data points."
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from .cluster import Cluster
from . import collectives as _coll


# Grid of interpolation x-points the "profiler" samples. Log-spaced powers
# of two from 1 to 2^40 — covers token counts, qk products and byte sizes.
_GRID = [2 ** i for i in range(0, 41)]


def _interp(points: List[Tuple[float, float, float]], x: float
            ) -> Tuple[float, float]:
    """Piecewise-linear interpolation over sorted (x, t, e) points."""
    if x <= points[0][0]:
        # Linear through origin below the grid (cost ~ 0 at x = 0).
        x0, t0, e0 = points[0]
        return t0 * x / x0, e0 * x / x0
    if x >= points[-1][0]:
        # Linear extrapolation using the last segment's slope.
        (x0, t0, e0), (x1, t1, e1) = points[-2], points[-1]
        dt = (t1 - t0) / (x1 - x0)
        de = (e1 - e0) / (x1 - x0)
        return t1 + dt * (x - x1), e1 + de * (x - x1)
    xs = [p[0] for p in points]
    i = bisect.bisect_right(xs, x)
    (x0, t0, e0), (x1, t1, e1) = points[i - 1], points[i]
    w = (x - x0) / (x1 - x0)
    return t0 + w * (t1 - t0), e0 + w * (e1 - e0)


class ProfileBackend:
    """Produces one (time_s, energy_j) sample — the 'profiler' interface."""

    def measure(self, op: str, axes: tuple, x: float) -> Tuple[float, float]:
        raise NotImplementedError


@dataclasses.dataclass
class AnalyticBackend(ProfileBackend):
    """Roofline-style analytic device model.

    Time = max(flops / (peak * eff_c(x)), bytes / (hbm_bw * eff_m)) + launch
    overhead.  The compute-efficiency curve ``eff_c`` saturates with
    arithmetic intensity/batch (small GEMMs underutilize the MXU/tensor
    cores) — this is what makes decode memory-bound and prefill
    compute-bound in the simulation, matching §2.1.

    ``freq_ghz`` scales compute and bandwidth linearly from the device's
    base frequency (paper Table 4's 0.8 GHz rows); energy uses the
    frequency-aware power model in core/energy.py.
    """

    cluster: Cluster
    freq_ghz: Optional[float] = None
    gemm_eff_max: float = 0.85
    mem_eff: float = 0.80
    launch_overhead_s: float = 4e-6

    def __post_init__(self):
        from .energy import PowerModel  # local import to avoid cycle
        self.power = PowerModel(self.cluster.device,
                                freq_ghz=self.freq_ghz)

    def _rates(self, dtype: str) -> Tuple[float, float]:
        dev = self.cluster.device
        scale = 1.0
        if self.freq_ghz is not None:
            scale = self.freq_ghz / dev.base_freq_ghz
        return dev.flops(dtype) * scale, dev.hbm_bw * self.mem_eff * scale

    def measure(self, op: str, axes: tuple, x: float) -> Tuple[float, float]:
        flops, nbytes, dtype = _op_work(op, axes, x)
        peak, bw = self._rates(dtype)
        # MXU efficiency saturates with the x variable (token count / size).
        half = 256.0 if op == "gemm" else 4096.0
        eff = self.gemm_eff_max * (x / (x + half))
        t_compute = flops / (peak * max(eff, 1e-3))
        t_mem = nbytes / bw
        t = max(t_compute, t_mem) + self.launch_overhead_s
        util = min(1.0, (flops / peak) / t) if t > 0 else 0.0
        energy = self.power.energy(t, util)
        return t, energy


def _op_work(op: str, axes: tuple, x: float) -> Tuple[float, float, str]:
    """Recover (flops, bytes, dtype) for a profile sample point.

    Mirrors the OpCall construction in core/ir.py so that analytic samples
    land on the same work model the simulator reports MFU/MBU against.
    """
    if op == "gemm":
        n, k, dtype = axes
        m = x
        bytes_per = 2.0 if dtype in ("fp16", "bf16") else 1.0
        flops = 2.0 * m * n * k
        nbytes = (m * k + m * n + n * k) * bytes_per
        return flops, nbytes, dtype
    if op == "attn_prefill":
        heads, head_dim, dtype = axes
        qk = x
        flops = 4.0 * qk * heads * head_dim
        nbytes = 4.0 * math.sqrt(max(qk, 1.0)) * heads * head_dim * 2.0
        return flops, nbytes, dtype
    if op == "attn_decode":
        kv_heads, head_dim, dtype = axes
        kv_tokens = x
        bytes_per = 2.0 if dtype in ("fp16", "bf16") else 1.0
        flops = 4.0 * kv_tokens * kv_heads * head_dim
        nbytes = 2.0 * kv_tokens * kv_heads * head_dim * bytes_per
        return flops, nbytes, dtype
    if op == "ssd_scan":
        d_inner, d_state, dtype = axes
        t = x
        flops = 6.0 * t * d_inner * d_state
        nbytes = 2.0 * t * d_inner * 2.0
        return flops, nbytes, dtype
    if op in _coll.COLLECTIVE_FNS or op == "p2p":
        # handled by CollectiveModel, not the device backend
        raise ValueError(f"collective op {op} must go through CollectiveModel")
    raise KeyError(f"unknown profile op {op!r}")


class MeasuredBackend(ProfileBackend):
    """Times the ACTUAL operation in JAX on this host (the fidelity
    experiments' profiler: the simulator predicts the engine running on
    the same silicon, closing the paper's Fig. 6/7 loop on CPU).

    Pair with ``ProfileStore(x_max=...)`` so the grid stays measurable;
    beyond the grid the store extrapolates linearly — the same mechanism
    the paper uses between profiled points.
    """

    def __init__(self, cluster: Optional[Cluster] = None, repeats: int = 3):
        import jax
        import jax.numpy as jnp
        from .cluster import cpu_local
        from .energy import PowerModel
        self._jax, self._jnp = jax, jnp
        self.cluster = cluster or cpu_local()
        self.repeats = repeats
        self.power = PowerModel(self.cluster.device)

    def _build(self, op: str, axes: tuple, x: float):
        jax, jnp = self._jax, self._jnp
        key = jax.random.PRNGKey(0)
        n_x = max(1, int(x))
        if op == "gemm":
            n, k, _ = axes
            a = jax.random.normal(key, (n_x, k), jnp.float32)
            b = jax.random.normal(key, (k, n), jnp.float32)
            return jax.jit(lambda a, b: a @ b), (a, b)
        if op == "attn_prefill":
            heads, head_dim, _ = axes
            s = max(2, int(math.sqrt(n_x)))
            q = jax.random.normal(key, (1, s, heads, head_dim), jnp.float32)
            def f(q):
                w = jnp.einsum("bqhd,bkhd->bhqk", q, q)
                p = jax.nn.softmax(w, axis=-1)
                return jnp.einsum("bhqk,bkhd->bqhd", p, q)
            return jax.jit(f), (q,)
        if op == "attn_decode":
            kv_heads, head_dim, _ = axes
            kv = jax.random.normal(key, (1, n_x, kv_heads, head_dim),
                                   jnp.float32)
            q = jax.random.normal(key, (1, 1, kv_heads, head_dim),
                                  jnp.float32)
            def f(q, kv):
                w = jnp.einsum("bqhd,bkhd->bhqk", q, kv)
                p = jax.nn.softmax(w, axis=-1)
                return jnp.einsum("bhqk,bkhd->bqhd", p, kv)
            return jax.jit(f), (q, kv)
        if op == "ssd_scan":
            d_inner, d_state, _ = axes
            h = max(1, d_inner // 64)
            from repro.kernels.ssd_scan.ref import ssd_scan_ref
            xx = jax.random.normal(key, (1, n_x, h, 64), jnp.float32)
            dt = jnp.ones((1, n_x, h), jnp.float32)
            al = jnp.zeros((h,), jnp.float32)
            b = jax.random.normal(key, (1, n_x, d_state), jnp.float32)
            return (jax.jit(lambda x, d, a, bb:
                            ssd_scan_ref(x, d, a, bb, bb)),
                    (xx, dt, al, b))
        raise KeyError(op)

    def measure(self, op: str, axes: tuple, x: float) -> Tuple[float, float]:
        import time as _t
        fn, args = self._build(op, axes, x)
        out = fn(*args)
        self._jax.block_until_ready(out)        # compile + warm
        best = float("inf")
        for _ in range(self.repeats):
            t0 = _t.perf_counter()
            self._jax.block_until_ready(fn(*args))
            best = min(best, _t.perf_counter() - t0)
        return best, self.power.energy(best, 0.7)


class ProfileStore:
    """Grid-sampled profiling tables with linear interpolation.

    Tables are built lazily: the first query for an (op, axes) key samples
    the backend over the x-grid (bounded to a window around the query) and
    caches the curve; subsequent queries interpolate.  ``grid_stride``
    subsamples the grid (a stride of 2 keeps every 2nd power of two) to
    emulate a sparser profiling run — used by tests to bound interpolation
    error.  ``x_max`` caps the grid (measured backends can't run 2^40-token
    GEMMs); queries beyond it extrapolate linearly.
    """

    def __init__(self, backend: ProfileBackend, grid_stride: int = 1,
                 x_max: Optional[float] = None):
        self.backend = backend
        self.grid_stride = max(1, grid_stride)
        self.x_max = x_max
        self._tables: Dict[tuple, List[Tuple[float, float, float]]] = {}
        self.lookups = 0
        self.misses = 0

    def _table(self, op: str, axes: tuple) -> List[Tuple[float, float, float]]:
        key = (op, axes)
        tbl = self._tables.get(key)
        if tbl is None:
            self.misses += 1
            grid = [g for g in _GRID[:: self.grid_stride]
                    if self.x_max is None or g <= self.x_max]
            tbl = []
            for gx in grid:
                t, e = self.backend.measure(op, axes, float(gx))
                tbl.append((float(gx), t, e))
            self._tables[key] = tbl
        return tbl

    def query(self, op: str, axes: tuple, x: float) -> Tuple[float, float]:
        """(time_s, energy_j) for one operation instance."""
        self.lookups += 1
        if x <= 0:
            return 0.0, 0.0
        return _interp(self._table(op, axes), x)

    def time(self, op: str, axes: tuple, x: float) -> float:
        return self.query(op, axes, x)[0]


class CollectiveModel:
    """Collective-communication lookup (paper profiles these separately).

    Thin adapter over core/collectives.py cost functions + the energy model;
    grouped here so search.py passes one object around.
    """

    def __init__(self, cluster: Cluster, freq_ghz: Optional[float] = None):
        from .energy import PowerModel
        self.cluster = cluster
        self.power = PowerModel(cluster.device, freq_ghz=freq_ghz)

    def query(self, kind: str, nbytes: float, group_size: int
              ) -> Tuple[float, float]:
        if kind == "p2p":
            t = _coll.p2p_time(nbytes, group_size, self.cluster)
        else:
            t = _coll.collective_time(kind, nbytes, group_size, self.cluster)
        # Communication keeps devices at low compute utilization.
        e = self.power.energy(t, utilization=0.15) * group_size
        return t, e

    def time(self, kind: str, nbytes: float, group_size: int) -> float:
        return self.query(kind, nbytes, group_size)[0]
