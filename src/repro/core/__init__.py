"""APEX core — the paper's contribution: automated parallel execution
planning for LLM serving via dynamism-aware simulation."""

from .batching import BatchingModule, BatchingPolicy, BatchingResult
from .dynamic import (DynamicPlanSimulator, DynamicSpec, EpochSchedule,
                      ReconfigReport, SwitchCost, build_schedules,
                      fault_schedule, reactive_schedule)
from .engine import (ContinuousScheduler, Engine, PreemptionPolicy,
                     SacrificePolicy, SchedulerPolicy, SharedCostStore,
                     SharedLink, StaticScheduler, StepCostCache,
                     SwapPolicy, make_preemption)
from .faults import (FaultSchedule, LinkDegradation, ReplicaFault,
                     Straggler, fault_ensemble, normalize_faults)
from .metrics import (ClassReport, ResilienceReport, WindowReport, p50,
                      p95, p99, percentile, windowed_metrics)
from .cluster import (CLUSTER_PRESETS, Cluster, DeviceSpec, NetworkLevel,
                      cpu_local, cross_pool_link, get_cluster,
                      h100_multinode, h100_node, h200_node, host_link,
                      tpu_v5e_multipod, tpu_v5e_pod)
from .ir import (AttentionCell, Block, Cell, CrossAttentionCell, MLACell,
                 MLPCell, ModelIR, MoECell, OpCall, SSMCell, Workload,
                 ir_from_hf_config)
from .mapper import ExecutionPlan, assign_physical_ids, map_scheme
from .planner import (ParallelScheme, divisors, generate_schemes,
                      heuristic_scheme, prefilter_schemes)
from .profiles import AnalyticBackend, CollectiveModel, MeasuredBackend, \
    ProfileBackend, ProfileStore
from .fluid import FluidDisaggSimulator, FluidSimulator, TraceSummary
from .multifid import MultiFidelityResult, MultiFidelitySearch, RungStat
from .quant import FORMATS, QuantFormat, get_format, register_format
from .search import (ApexSearch, PlanEvaluationError, SearchResult,
                     compare_three_plans, fork_map)
from .simulator import PlanSimulator, SimulationReport, cost_fingerprint
from .templates import CellScheme, CollectiveCall, reshard_collectives, \
    schemes_for_cell
from .trace import (DEFAULT_SLO, ArrivalProcess, BurstProcess,
                    ClassTraffic, ConstantRate, DiurnalRate,
                    PiecewiseRate, Request, SLOClass,
                    TRACE_SPECS, as_arrival_process, get_trace,
                    mixed_trace, prefix_trace, retag_slo,
                    synthesize_mixed_trace, synthesize_trace,
                    trace_stats)

__all__ = [
    "ApexSearch", "AnalyticBackend", "ArrivalProcess", "AttentionCell",
    "BatchingModule", "BurstProcess", "ConstantRate", "DiurnalRate",
    "DynamicPlanSimulator", "DynamicSpec", "EpochSchedule",
    "PiecewiseRate", "ReconfigReport", "SwitchCost", "WindowReport",
    "as_arrival_process", "build_schedules", "fault_schedule",
    "reactive_schedule", "windowed_metrics",
    "BatchingPolicy", "BatchingResult", "Block", "Cell", "CellScheme",
    "CLUSTER_PRESETS", "ClassReport", "ClassTraffic", "Cluster",
    "CollectiveCall", "CollectiveModel",
    "ContinuousScheduler", "CrossAttentionCell", "DEFAULT_SLO",
    "DeviceSpec", "Engine",
    "ExecutionPlan", "FORMATS", "FluidDisaggSimulator", "FluidSimulator",
    "FaultSchedule", "LinkDegradation",
    "MLACell", "MLPCell", "MeasuredBackend", "ModelIR", "MoECell",
    "MultiFidelityResult", "MultiFidelitySearch", "RungStat",
    "NetworkLevel", "OpCall", "PlanEvaluationError", "PreemptionPolicy",
    "ReplicaFault", "ResilienceReport", "SLOClass", "Straggler",
    "TraceSummary", "cost_fingerprint", "cpu_local", "fault_ensemble",
    "fork_map", "normalize_faults",
    "ParallelScheme", "PlanSimulator", "ProfileBackend", "ProfileStore",
    "QuantFormat", "Request", "SSMCell", "SacrificePolicy",
    "SchedulerPolicy", "SearchResult",
    "SharedCostStore", "SharedLink", "SimulationReport", "StaticScheduler",
    "StepCostCache", "SwapPolicy",
    "TRACE_SPECS", "Workload", "assign_physical_ids", "compare_three_plans",
    "cross_pool_link", "divisors", "generate_schemes", "get_cluster",
    "get_format", "get_trace", "host_link", "make_preemption",
    "mixed_trace", "p50", "p95", "p99", "percentile", "prefix_trace",
    "h100_multinode", "h100_node", "h200_node", "heuristic_scheme",
    "ir_from_hf_config", "map_scheme", "prefilter_schemes",
    "register_format", "retag_slo",
    "reshard_collectives", "schemes_for_cell", "synthesize_mixed_trace",
    "synthesize_trace",
    "tpu_v5e_multipod", "tpu_v5e_pod", "trace_stats",
]
