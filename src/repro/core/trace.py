"""Request traces (paper §3.3, §4.1 Table 1) and multi-tenant SLO classes.

A request = (arrival time, context length, generation length).  The paper
derives three traces from public datasets; offline, we synthesize traces
matched to Table 1's first two moments with Poisson arrivals (the paper's
own arrival model, §4.1):

    Summarization : ctx 2742.11 +/- 944.33, gen  172.22 +/-  73.17, n=1188
    Creation      : ctx  306.82 +/-  81.03, gen 1128.34 +/- 419.64, n=512
    Chat          : ctx   73.32 +/- 148.65, gen  189.47 +/- 174.18, n=1024

Lengths are drawn from a log-normal fitted to (mu, sigma) — positive,
right-skewed, like real LLM traffic — then clamped to [1, max_len].
Generators are seeded and deterministic.

Multi-tenant traffic: every request carries an ``SLOClass`` — a named
tenant class with a scheduling priority and optional TTFT/TPOT targets.
``synthesize_mixed_trace`` merges independently-seeded per-class Poisson
streams (e.g. latency-sensitive chat sharing a deployment with batchy
summarization) into one trace; the engine's preemption policies and the
``"goodput"`` search objective (requests meeting their class SLO per
second) read the class off each request.  Single-class traces default to
``DEFAULT_SLO`` (priority 0, no targets), which keeps every legacy code
path byte-identical.
"""

from __future__ import annotations

import bisect
import dataclasses
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One tenant class: a name, a scheduling priority (higher = more
    important — preemption policies evict lower priorities first), and
    optional latency targets (None = unconstrained on that metric)."""

    name: str = "default"
    priority: int = 0
    ttft_target_s: Optional[float] = None
    tpot_target_s: Optional[float] = None

    def met_by(self, ttft: float, tpot: float, has_decode: bool) -> bool:
        """Does a request with these measured latencies meet the SLO?"""
        if self.ttft_target_s is not None and ttft > self.ttft_target_s:
            return False
        if (self.tpot_target_s is not None and has_decode
                and tpot > self.tpot_target_s):
            return False
        return True


DEFAULT_SLO = SLOClass()


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    arrival: float            # seconds
    context_len: int          # prompt tokens
    gen_len: int              # output tokens to produce
    source_len: int = 0       # encoder-side tokens (enc-dec models only)
    slo_class: SLOClass = DEFAULT_SLO


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    name: str
    ctx_mean: float
    ctx_std: float
    gen_mean: float
    gen_std: float
    num_requests: int


TRACE_SPECS = {
    "summarization": TraceSpec("summarization", 2742.11, 944.33,
                               172.22, 73.17, 1188),
    "creation": TraceSpec("creation", 306.82, 81.03, 1128.34, 419.64, 512),
    "chat": TraceSpec("chat", 73.32, 148.65, 189.47, 174.18, 1024),
}


def _lognormal_params(mean: float, std: float) -> tuple:
    """(mu, sigma) of a log-normal with the given mean/std."""
    var = std * std
    sigma2 = math.log(1.0 + var / (mean * mean))
    mu = math.log(mean) - sigma2 / 2.0
    return mu, math.sqrt(sigma2)


class _GeneratorDraws:
    """Adapts a ``numpy.random.Generator`` to the two draw methods the
    synthesizer uses, so parallel search workers can regenerate
    byte-identical traces: ``numpy.random.default_rng(seed)`` is a
    deterministic function of the seed in every process, with none of
    the cross-process state a shared module-level RNG would have."""

    def __init__(self, gen):
        self.gen = gen

    def expovariate(self, rate: float) -> float:
        return float(self.gen.exponential(1.0 / rate))

    def lognormvariate(self, mu: float, sigma: float) -> float:
        return float(self.gen.lognormal(mu, sigma))

    def random(self) -> float:
        return float(self.gen.random())


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

class ArrivalProcess:
    """A (possibly non-stationary) arrival-time process.

    ``iter_arrivals(rng)`` yields absolute arrival times, drawing from
    ``rng`` lazily — exactly one draw sequence per arrival — so a
    seeded generator produces the same trace in every process.  Every
    rate-accepting entry point (``synthesize_trace``, ``get_trace``,
    ``ClassTraffic``, ``mixed_trace``) takes an ``ArrivalProcess`` in
    place of the legacy float rate; a bare float means
    ``ConstantRate(rate)``, whose draw sequence is byte-identical to
    the pre-process code path (golden-pinned).
    """

    #: True for processes whose rate never varies in time.
    stationary: bool = False

    def iter_arrivals(self, rng):
        raise NotImplementedError

    def rate_at(self, t: float) -> float:
        """Instantaneous (or, for doubly-stochastic processes, mean)
        arrival rate at absolute time ``t``."""
        raise NotImplementedError

    def peak_rate(self) -> float:
        """An upper bound on the instantaneous rate (thinning bound /
        conservative capacity-planning rate)."""
        raise NotImplementedError

    def mean_rate(self, horizon_s: float) -> float:
        """Time-averaged rate over ``[0, horizon_s]``."""
        if horizon_s <= 0:
            raise ValueError(f"horizon must be positive, got {horizon_s}")
        k = 256
        dt = horizon_s / k
        return sum(self.rate_at((i + 0.5) * dt) for i in range(k)) / k


@dataclasses.dataclass(frozen=True)
class ConstantRate(ArrivalProcess):
    """Stationary Poisson arrivals — the legacy model, bit-identical."""

    rate: float
    stationary = True

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(
                f"arrival_rate must be positive, got {self.rate}")

    def iter_arrivals(self, rng):
        t = 0.0
        while True:
            t += rng.expovariate(self.rate)
            yield t

    def rate_at(self, t: float) -> float:
        return self.rate

    def peak_rate(self) -> float:
        return self.rate

    def mean_rate(self, horizon_s: float) -> float:
        return self.rate


@dataclasses.dataclass(frozen=True)
class PiecewiseRate(ArrivalProcess):
    """Piecewise-constant rate: ``rates[i]`` req/s from ``starts[i]``
    until ``starts[i+1]``; the last rate holds forever.  Arrivals are
    drawn by exact hazard inversion (one unit-exponential draw per
    arrival — no thinning, no discretization), so the draw count is
    deterministic and seeded traces replay bit-identically."""

    starts: Tuple[float, ...]
    rates: Tuple[float, ...]

    def __post_init__(self):
        object.__setattr__(self, "starts", tuple(self.starts))
        object.__setattr__(self, "rates", tuple(self.rates))
        if not self.starts or len(self.starts) != len(self.rates):
            raise ValueError("starts and rates must be equal-length and "
                             f"non-empty, got {len(self.starts)} starts / "
                             f"{len(self.rates)} rates")
        if self.starts[0] != 0.0:
            raise ValueError(f"first segment must start at 0, "
                             f"got {self.starts[0]}")
        if any(b >= a for a, b in zip(self.starts[1:], self.starts)):
            raise ValueError(f"segment starts must be strictly increasing, "
                             f"got {self.starts}")
        if any(r < 0 for r in self.rates):
            raise ValueError(f"rates must be non-negative, got {self.rates}")
        if self.rates[-1] <= 0:
            raise ValueError("final segment rate must be positive (it "
                             "holds forever and must eventually produce "
                             f"each arrival), got {self.rates[-1]}")

    def iter_arrivals(self, rng):
        t = 0.0
        idx = 0
        while True:
            e = rng.expovariate(1.0)     # unit-exponential hazard target
            while True:
                rate = self.rates[idx]
                end = self.starts[idx + 1] \
                    if idx + 1 < len(self.starts) else math.inf
                if rate > 0:
                    dt = e / rate
                    if t + dt <= end:
                        t += dt
                        break
                    e -= (end - t) * rate
                t = end
                idx += 1
            yield t

    def rate_at(self, t: float) -> float:
        return self.rates[max(0, bisect.bisect_right(self.starts, t) - 1)]

    def peak_rate(self) -> float:
        return max(self.rates)


@dataclasses.dataclass(frozen=True)
class DiurnalRate(ArrivalProcess):
    """Sinusoidal diurnal swing:
    ``rate(t) = base * (1 + amplitude * sin(2*pi*(t - phase)/period))``.
    Drawn by Lewis–Shedler thinning against the peak-rate bound — one
    exponential + one uniform draw per proposal."""

    base_rate: float
    amplitude: float = 0.5
    period_s: float = 86400.0
    phase_s: float = 0.0

    def __post_init__(self):
        if self.base_rate <= 0:
            raise ValueError(
                f"base_rate must be positive, got {self.base_rate}")
        if not 0.0 <= self.amplitude <= 1.0:
            raise ValueError(
                f"amplitude must be in [0, 1], got {self.amplitude}")
        if self.period_s <= 0:
            raise ValueError(
                f"period_s must be positive, got {self.period_s}")

    def iter_arrivals(self, rng):
        bound = self.base_rate * (1.0 + self.amplitude)
        t = 0.0
        while True:
            t += rng.expovariate(bound)
            if rng.random() * bound <= self.rate_at(t):
                yield t

    def rate_at(self, t: float) -> float:
        return self.base_rate * (1.0 + self.amplitude * math.sin(
            2.0 * math.pi * (t - self.phase_s) / self.period_s))

    def peak_rate(self) -> float:
        return self.base_rate * (1.0 + self.amplitude)

    def mean_rate(self, horizon_s: float) -> float:
        if horizon_s <= 0:
            raise ValueError(f"horizon must be positive, got {horizon_s}")
        # exact: integral of base*(1 + a*sin(...)) has closed form
        w = 2.0 * math.pi / self.period_s
        integral = self.base_rate * (
            horizon_s + (self.amplitude / w)
            * (math.cos(-w * self.phase_s)
               - math.cos(w * (horizon_s - self.phase_s))))
        return integral / horizon_s


@dataclasses.dataclass(frozen=True)
class BurstProcess(ArrivalProcess):
    """MMPP-style on/off bursts: a two-phase Markov-modulated Poisson
    process alternating between a quiet phase at ``base_rate`` and a
    burst phase at ``burst_rate``, with exponentially-distributed phase
    holding times.  Arrivals inside each phase are drawn by exact
    hazard inversion, with phase-transition draws interleaved
    deterministically, so seeded traces replay bit-identically."""

    base_rate: float
    burst_rate: float
    mean_burst_s: float
    mean_gap_s: float
    start_in_burst: bool = False

    def __post_init__(self):
        if self.base_rate < 0:
            raise ValueError(
                f"base_rate must be non-negative, got {self.base_rate}")
        if self.burst_rate <= 0:
            raise ValueError(
                f"burst_rate must be positive, got {self.burst_rate}")
        if self.burst_rate < self.base_rate:
            raise ValueError(
                f"burst_rate ({self.burst_rate}) must be >= base_rate "
                f"({self.base_rate})")
        if self.mean_burst_s <= 0 or self.mean_gap_s <= 0:
            raise ValueError(
                f"phase means must be positive, got burst="
                f"{self.mean_burst_s} gap={self.mean_gap_s}")

    def _hold(self, in_burst: bool) -> float:
        return self.mean_burst_s if in_burst else self.mean_gap_s

    def iter_arrivals(self, rng):
        t = 0.0
        in_burst = self.start_in_burst
        phase_end = t + rng.expovariate(1.0 / self._hold(in_burst))
        while True:
            e = rng.expovariate(1.0)
            while True:
                rate = self.burst_rate if in_burst else self.base_rate
                if rate > 0:
                    dt = e / rate
                    if t + dt <= phase_end:
                        t += dt
                        break
                    e -= (phase_end - t) * rate
                t = phase_end
                in_burst = not in_burst
                phase_end = t + rng.expovariate(1.0 / self._hold(in_burst))
            yield t

    def rate_at(self, t: float) -> float:
        """The duty-cycled MEAN rate — the modulating phase chain is
        part of the random draw, so the realized instantaneous rate is
        not a function of ``t`` alone."""
        total = self.mean_burst_s + self.mean_gap_s
        return (self.burst_rate * self.mean_burst_s
                + self.base_rate * self.mean_gap_s) / total

    def peak_rate(self) -> float:
        return self.burst_rate

    def mean_rate(self, horizon_s: float) -> float:
        return self.rate_at(0.0)


RateLike = Union[float, int, ArrivalProcess]


def as_arrival_process(rate: RateLike) -> ArrivalProcess:
    """Coerce a float rate (legacy API) or pass through a process."""
    if isinstance(rate, ArrivalProcess):
        return rate
    if not isinstance(rate, (int, float)) or isinstance(rate, bool):
        raise TypeError(f"arrival_rate must be a positive number or an "
                        f"ArrivalProcess, got {rate!r}")
    return ConstantRate(float(rate))


def synthesize_trace(spec: TraceSpec, arrival_rate: RateLike,
                     seed: int = 0, num_requests: Optional[int] = None,
                     max_len: int = 131072, source_len: int = 0,
                     rng=None, slo_class: SLOClass = DEFAULT_SLO
                     ) -> List[Request]:
    """Arrivals from ``arrival_rate`` (a req/s float = stationary
    Poisson, or any ``ArrivalProcess``), log-normal lengths.

    ``rng`` overrides the default seeded ``random.Random``: pass either a
    ``random.Random`` or an explicit ``numpy.random.Generator`` (adapted
    transparently).  Two calls with equal-state generators produce
    byte-identical traces — the determinism contract parallel search
    workers (``jobs=N``) rely on when each regenerates its own copy.
    The default path is unchanged (same draws as before): a float rate
    routes through ``ConstantRate``, whose per-arrival draw sequence is
    identical to the legacy inline loop (golden-pinned).

    ``slo_class`` tags every request with one tenant class (see
    ``synthesize_mixed_trace`` for multi-class traffic).

    Raises ``ValueError`` on non-positive ``arrival_rate`` or
    ``num_requests`` instead of silently emitting degenerate traces.
    """
    process = as_arrival_process(arrival_rate)
    if num_requests is not None and num_requests <= 0:
        raise ValueError(
            f"num_requests must be positive, got {num_requests}")
    if rng is None:
        rng = random.Random(seed)
    elif not hasattr(rng, "expovariate"):
        rng = _GeneratorDraws(rng)       # numpy Generator
    n = spec.num_requests if num_requests is None else num_requests
    if n <= 0:
        raise ValueError(f"trace spec {spec.name!r} has non-positive "
                         f"num_requests {n}")
    cmu, csig = _lognormal_params(spec.ctx_mean, spec.ctx_std)
    gmu, gsig = _lognormal_params(spec.gen_mean, spec.gen_std)
    out: List[Request] = []
    arrivals = process.iter_arrivals(rng)
    for i in range(n):
        t = next(arrivals)
        ctx = max(1, min(max_len, int(round(rng.lognormvariate(cmu, csig)))))
        gen = max(1, min(max_len, int(round(rng.lognormvariate(gmu, gsig)))))
        out.append(Request(rid=i, arrival=t, context_len=ctx, gen_len=gen,
                           source_len=source_len, slo_class=slo_class))
    return out


def get_trace(name: str, arrival_rate: RateLike = 0.5, seed: int = 0,
              num_requests: Optional[int] = None,
              source_len: int = 0, rng=None,
              slo_class: SLOClass = DEFAULT_SLO) -> List[Request]:
    if name not in TRACE_SPECS:
        raise KeyError(f"unknown trace {name!r}; known: {sorted(TRACE_SPECS)}")
    return synthesize_trace(TRACE_SPECS[name], arrival_rate, seed=seed,
                            num_requests=num_requests, source_len=source_len,
                            rng=rng, slo_class=slo_class)


# ---------------------------------------------------------------------------
# multi-tenant traffic
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClassTraffic:
    """One tenant class's share of a mixed trace: which length
    distribution it draws from, how fast it arrives, and its SLO."""

    spec: TraceSpec
    arrival_rate: RateLike         # this class's own rate or ArrivalProcess
    slo: SLOClass
    num_requests: Optional[int] = None
    source_len: int = 0


def synthesize_mixed_trace(components: Sequence[ClassTraffic],
                           seed: int = 0, max_len: int = 131072
                           ) -> List[Request]:
    """Merge independently-seeded per-class arrival streams into one
    trace (e.g. chat + summarization sharing a deployment).  Each
    component's ``arrival_rate`` may be a float (stationary Poisson) or
    any ``ArrivalProcess`` (e.g. a diurnal chat class over a piecewise
    batch class).

    Each component draws from its own sub-seeded generator
    (``seed + 1000 * index``) so adding or re-ordering classes never
    perturbs another class's draws; the merged trace is sorted by
    arrival (ties by class order) and re-numbered with contiguous rids.

    Raises ``ValueError`` on an empty ``components`` sequence.
    """
    if not components:
        raise ValueError("components must be a non-empty sequence of "
                         "ClassTraffic")
    streams: List[List[Request]] = []
    for k, comp in enumerate(components):
        streams.append(synthesize_trace(
            comp.spec, comp.arrival_rate, seed=seed + 1000 * k,
            num_requests=comp.num_requests, max_len=max_len,
            source_len=comp.source_len, slo_class=comp.slo))
    merged = sorted(((r, k) for k, s in enumerate(streams) for r in s),
                    key=lambda rk: (rk[0].arrival, rk[1], rk[0].rid))
    return [dataclasses.replace(r, rid=i) for i, (r, _) in enumerate(merged)]


def mixed_trace(components: Sequence[tuple], seed: int = 0,
                max_len: int = 131072) -> List[Request]:
    """Convenience front for ``synthesize_mixed_trace``: each component
    is ``(trace_name, arrival_rate, slo_class[, num_requests])``, where
    ``arrival_rate`` is a float or any ``ArrivalProcess``."""
    if not components:
        raise ValueError("components must be a non-empty sequence")
    parts = []
    for comp in components:
        name, rate, slo = comp[0], comp[1], comp[2]
        n = comp[3] if len(comp) > 3 else None
        if name not in TRACE_SPECS:
            raise KeyError(
                f"unknown trace {name!r}; known: {sorted(TRACE_SPECS)}")
        parts.append(ClassTraffic(TRACE_SPECS[name], rate, slo,
                                  num_requests=n))
    return synthesize_mixed_trace(parts, seed=seed, max_len=max_len)


def retag_slo(requests: Sequence[Request],
              slo_classes: Union[None, Dict[str, SLOClass],
                                 Sequence[SLOClass]]) -> List[Request]:
    """Re-attach SLO classes to a trace by class NAME.

    ``slo_classes`` maps class names to replacement ``SLOClass`` objects
    (a sequence is keyed by each class's own name).  Requests whose class
    name has no entry keep their class; ``None`` is a no-op returning the
    input unchanged — the single-tenant fast path.  This is the
    ``slo_classes=`` plumbing ``simulate()``/``search()`` expose: traces
    synthesized with bare class names can have targets attached at
    evaluation time without regenerating the trace.
    """
    if slo_classes is None:
        return list(requests) if not isinstance(requests, list) else requests
    if not isinstance(slo_classes, dict):
        slo_classes = {c.name: c for c in slo_classes}
    return [dataclasses.replace(r, slo_class=slo_classes[r.slo_class.name])
            if r.slo_class.name in slo_classes else r
            for r in requests]


def prefix_trace(requests: Sequence[Request], fraction: float,
                 presorted: bool = False) -> List[Request]:
    """The first ``ceil(fraction * n)`` requests of a trace, by arrival.

    Used by successive-halving rungs (``core/multifid.py``): a short
    prefix of the trace is a cheap but *exact* fidelity level.  The
    prefix is taken by COUNT with arrival times kept absolute, because
    the first k arrivals of a Poisson process are themselves a Poisson
    process observed over a shorter window — rate, length distributions
    and SLO-class mix are preserved in expectation, so rung rankings are
    unbiased estimates of the full-trace ranking.  Ties on arrival break
    by ``rid`` so the prefix is deterministic.  ``fraction >= 1`` returns
    the (sorted) full trace; ``presorted`` skips the sort when the caller
    already ordered by ``(arrival, rid)``.
    """
    if fraction <= 0:
        raise ValueError(f"prefix fraction must be positive, got {fraction}")
    ordered = list(requests) if presorted else \
        sorted(requests, key=lambda r: (r.arrival, r.rid))
    if fraction >= 1.0:
        return ordered
    k = max(1, math.ceil(len(ordered) * fraction))
    return ordered[:k]


def trace_stats(reqs: List[Request]) -> dict:
    n = len(reqs)
    cm = sum(r.context_len for r in reqs) / n
    gm = sum(r.gen_len for r in reqs) / n
    cv = math.sqrt(sum((r.context_len - cm) ** 2 for r in reqs) / n)
    gv = math.sqrt(sum((r.gen_len - gm) ** 2 for r in reqs) / n)
    return {"n": n, "ctx_mean": cm, "ctx_std": cv, "gen_mean": gm,
            "gen_std": gv, "span_s": reqs[-1].arrival if reqs else 0.0}
