"""Request traces (paper §3.3, §4.1 Table 1) and multi-tenant SLO classes.

A request = (arrival time, context length, generation length).  The paper
derives three traces from public datasets; offline, we synthesize traces
matched to Table 1's first two moments with Poisson arrivals (the paper's
own arrival model, §4.1):

    Summarization : ctx 2742.11 +/- 944.33, gen  172.22 +/-  73.17, n=1188
    Creation      : ctx  306.82 +/-  81.03, gen 1128.34 +/- 419.64, n=512
    Chat          : ctx   73.32 +/- 148.65, gen  189.47 +/- 174.18, n=1024

Lengths are drawn from a log-normal fitted to (mu, sigma) — positive,
right-skewed, like real LLM traffic — then clamped to [1, max_len].
Generators are seeded and deterministic.

Multi-tenant traffic: every request carries an ``SLOClass`` — a named
tenant class with a scheduling priority and optional TTFT/TPOT targets.
``synthesize_mixed_trace`` merges independently-seeded per-class Poisson
streams (e.g. latency-sensitive chat sharing a deployment with batchy
summarization) into one trace; the engine's preemption policies and the
``"goodput"`` search objective (requests meeting their class SLO per
second) read the class off each request.  Single-class traces default to
``DEFAULT_SLO`` (priority 0, no targets), which keeps every legacy code
path byte-identical.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Sequence, Union


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One tenant class: a name, a scheduling priority (higher = more
    important — preemption policies evict lower priorities first), and
    optional latency targets (None = unconstrained on that metric)."""

    name: str = "default"
    priority: int = 0
    ttft_target_s: Optional[float] = None
    tpot_target_s: Optional[float] = None

    def met_by(self, ttft: float, tpot: float, has_decode: bool) -> bool:
        """Does a request with these measured latencies meet the SLO?"""
        if self.ttft_target_s is not None and ttft > self.ttft_target_s:
            return False
        if (self.tpot_target_s is not None and has_decode
                and tpot > self.tpot_target_s):
            return False
        return True


DEFAULT_SLO = SLOClass()


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    arrival: float            # seconds
    context_len: int          # prompt tokens
    gen_len: int              # output tokens to produce
    source_len: int = 0       # encoder-side tokens (enc-dec models only)
    slo_class: SLOClass = DEFAULT_SLO


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    name: str
    ctx_mean: float
    ctx_std: float
    gen_mean: float
    gen_std: float
    num_requests: int


TRACE_SPECS = {
    "summarization": TraceSpec("summarization", 2742.11, 944.33,
                               172.22, 73.17, 1188),
    "creation": TraceSpec("creation", 306.82, 81.03, 1128.34, 419.64, 512),
    "chat": TraceSpec("chat", 73.32, 148.65, 189.47, 174.18, 1024),
}


def _lognormal_params(mean: float, std: float) -> tuple:
    """(mu, sigma) of a log-normal with the given mean/std."""
    var = std * std
    sigma2 = math.log(1.0 + var / (mean * mean))
    mu = math.log(mean) - sigma2 / 2.0
    return mu, math.sqrt(sigma2)


class _GeneratorDraws:
    """Adapts a ``numpy.random.Generator`` to the two draw methods the
    synthesizer uses, so parallel search workers can regenerate
    byte-identical traces: ``numpy.random.default_rng(seed)`` is a
    deterministic function of the seed in every process, with none of
    the cross-process state a shared module-level RNG would have."""

    def __init__(self, gen):
        self.gen = gen

    def expovariate(self, rate: float) -> float:
        return float(self.gen.exponential(1.0 / rate))

    def lognormvariate(self, mu: float, sigma: float) -> float:
        return float(self.gen.lognormal(mu, sigma))


def synthesize_trace(spec: TraceSpec, arrival_rate: float,
                     seed: int = 0, num_requests: Optional[int] = None,
                     max_len: int = 131072, source_len: int = 0,
                     rng=None, slo_class: SLOClass = DEFAULT_SLO
                     ) -> List[Request]:
    """Poisson arrivals at ``arrival_rate`` req/s, log-normal lengths.

    ``rng`` overrides the default seeded ``random.Random``: pass either a
    ``random.Random`` or an explicit ``numpy.random.Generator`` (adapted
    transparently).  Two calls with equal-state generators produce
    byte-identical traces — the determinism contract parallel search
    workers (``jobs=N``) rely on when each regenerates its own copy.
    The default path is unchanged (same draws as before).

    ``slo_class`` tags every request with one tenant class (see
    ``synthesize_mixed_trace`` for multi-class traffic).
    """
    if rng is None:
        rng = random.Random(seed)
    elif not hasattr(rng, "expovariate"):
        rng = _GeneratorDraws(rng)       # numpy Generator
    n = num_requests or spec.num_requests
    cmu, csig = _lognormal_params(spec.ctx_mean, spec.ctx_std)
    gmu, gsig = _lognormal_params(spec.gen_mean, spec.gen_std)
    out: List[Request] = []
    t = 0.0
    for i in range(n):
        t += rng.expovariate(arrival_rate)
        ctx = max(1, min(max_len, int(round(rng.lognormvariate(cmu, csig)))))
        gen = max(1, min(max_len, int(round(rng.lognormvariate(gmu, gsig)))))
        out.append(Request(rid=i, arrival=t, context_len=ctx, gen_len=gen,
                           source_len=source_len, slo_class=slo_class))
    return out


def get_trace(name: str, arrival_rate: float = 0.5, seed: int = 0,
              num_requests: Optional[int] = None,
              source_len: int = 0, rng=None,
              slo_class: SLOClass = DEFAULT_SLO) -> List[Request]:
    if name not in TRACE_SPECS:
        raise KeyError(f"unknown trace {name!r}; known: {sorted(TRACE_SPECS)}")
    return synthesize_trace(TRACE_SPECS[name], arrival_rate, seed=seed,
                            num_requests=num_requests, source_len=source_len,
                            rng=rng, slo_class=slo_class)


# ---------------------------------------------------------------------------
# multi-tenant traffic
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClassTraffic:
    """One tenant class's share of a mixed trace: which length
    distribution it draws from, how fast it arrives, and its SLO."""

    spec: TraceSpec
    arrival_rate: float            # this class's own Poisson rate (req/s)
    slo: SLOClass
    num_requests: Optional[int] = None
    source_len: int = 0


def synthesize_mixed_trace(components: Sequence[ClassTraffic],
                           seed: int = 0, max_len: int = 131072
                           ) -> List[Request]:
    """Merge independently-seeded per-class Poisson streams into one
    trace (e.g. chat + summarization sharing a deployment).

    Each component draws from its own sub-seeded generator
    (``seed + 1000 * index``) so adding or re-ordering classes never
    perturbs another class's draws; the merged trace is sorted by
    arrival (ties by class order) and re-numbered with contiguous rids.
    """
    streams: List[List[Request]] = []
    for k, comp in enumerate(components):
        streams.append(synthesize_trace(
            comp.spec, comp.arrival_rate, seed=seed + 1000 * k,
            num_requests=comp.num_requests, max_len=max_len,
            source_len=comp.source_len, slo_class=comp.slo))
    merged = sorted(((r, k) for k, s in enumerate(streams) for r in s),
                    key=lambda rk: (rk[0].arrival, rk[1], rk[0].rid))
    return [dataclasses.replace(r, rid=i) for i, (r, _) in enumerate(merged)]


def mixed_trace(components: Sequence[tuple], seed: int = 0,
                max_len: int = 131072) -> List[Request]:
    """Convenience front for ``synthesize_mixed_trace``: each component
    is ``(trace_name, arrival_rate, slo_class[, num_requests])``."""
    parts = []
    for comp in components:
        name, rate, slo = comp[0], comp[1], comp[2]
        n = comp[3] if len(comp) > 3 else None
        if name not in TRACE_SPECS:
            raise KeyError(
                f"unknown trace {name!r}; known: {sorted(TRACE_SPECS)}")
        parts.append(ClassTraffic(TRACE_SPECS[name], rate, slo,
                                  num_requests=n))
    return synthesize_mixed_trace(parts, seed=seed, max_len=max_len)


def retag_slo(requests: Sequence[Request],
              slo_classes: Union[None, Dict[str, SLOClass],
                                 Sequence[SLOClass]]) -> List[Request]:
    """Re-attach SLO classes to a trace by class NAME.

    ``slo_classes`` maps class names to replacement ``SLOClass`` objects
    (a sequence is keyed by each class's own name).  Requests whose class
    name has no entry keep their class; ``None`` is a no-op returning the
    input unchanged — the single-tenant fast path.  This is the
    ``slo_classes=`` plumbing ``simulate()``/``search()`` expose: traces
    synthesized with bare class names can have targets attached at
    evaluation time without regenerating the trace.
    """
    if slo_classes is None:
        return list(requests) if not isinstance(requests, list) else requests
    if not isinstance(slo_classes, dict):
        slo_classes = {c.name: c for c in slo_classes}
    return [dataclasses.replace(r, slo_class=slo_classes[r.slo_class.name])
            if r.slo_class.name in slo_classes else r
            for r in requests]


def prefix_trace(requests: Sequence[Request], fraction: float,
                 presorted: bool = False) -> List[Request]:
    """The first ``ceil(fraction * n)`` requests of a trace, by arrival.

    Used by successive-halving rungs (``core/multifid.py``): a short
    prefix of the trace is a cheap but *exact* fidelity level.  The
    prefix is taken by COUNT with arrival times kept absolute, because
    the first k arrivals of a Poisson process are themselves a Poisson
    process observed over a shorter window — rate, length distributions
    and SLO-class mix are preserved in expectation, so rung rankings are
    unbiased estimates of the full-trace ranking.  Ties on arrival break
    by ``rid`` so the prefix is deterministic.  ``fraction >= 1`` returns
    the (sorted) full trace; ``presorted`` skips the sort when the caller
    already ordered by ``(arrival, rid)``.
    """
    if fraction <= 0:
        raise ValueError(f"prefix fraction must be positive, got {fraction}")
    ordered = list(requests) if presorted else \
        sorted(requests, key=lambda r: (r.arrival, r.rid))
    if fraction >= 1.0:
        return ordered
    k = max(1, math.ceil(len(ordered) * fraction))
    return ordered[:k]


def trace_stats(reqs: List[Request]) -> dict:
    n = len(reqs)
    cm = sum(r.context_len for r in reqs) / n
    gm = sum(r.gen_len for r in reqs) / n
    cv = math.sqrt(sum((r.context_len - cm) ** 2 for r in reqs) / n)
    gv = math.sqrt(sum((r.gen_len - gm) ** 2 for r in reqs) / n)
    return {"n": n, "ctx_mean": cm, "ctx_std": cv, "gen_mean": gm,
            "gen_std": gv, "span_s": reqs[-1].arrival if reqs else 0.0}
