"""Request traces (paper §3.3, §4.1 Table 1).

A request = (arrival time, context length, generation length).  The paper
derives three traces from public datasets; offline, we synthesize traces
matched to Table 1's first two moments with Poisson arrivals (the paper's
own arrival model, §4.1):

    Summarization : ctx 2742.11 +/- 944.33, gen  172.22 +/-  73.17, n=1188
    Creation      : ctx  306.82 +/-  81.03, gen 1128.34 +/- 419.64, n=512
    Chat          : ctx   73.32 +/- 148.65, gen  189.47 +/- 174.18, n=1024

Lengths are drawn from a log-normal fitted to (mu, sigma) — positive,
right-skewed, like real LLM traffic — then clamped to [1, max_len].
Generators are seeded and deterministic.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    arrival: float            # seconds
    context_len: int          # prompt tokens
    gen_len: int              # output tokens to produce
    source_len: int = 0       # encoder-side tokens (enc-dec models only)


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    name: str
    ctx_mean: float
    ctx_std: float
    gen_mean: float
    gen_std: float
    num_requests: int


TRACE_SPECS = {
    "summarization": TraceSpec("summarization", 2742.11, 944.33,
                               172.22, 73.17, 1188),
    "creation": TraceSpec("creation", 306.82, 81.03, 1128.34, 419.64, 512),
    "chat": TraceSpec("chat", 73.32, 148.65, 189.47, 174.18, 1024),
}


def _lognormal_params(mean: float, std: float) -> tuple:
    """(mu, sigma) of a log-normal with the given mean/std."""
    var = std * std
    sigma2 = math.log(1.0 + var / (mean * mean))
    mu = math.log(mean) - sigma2 / 2.0
    return mu, math.sqrt(sigma2)


class _GeneratorDraws:
    """Adapts a ``numpy.random.Generator`` to the two draw methods the
    synthesizer uses, so parallel search workers can regenerate
    byte-identical traces: ``numpy.random.default_rng(seed)`` is a
    deterministic function of the seed in every process, with none of
    the cross-process state a shared module-level RNG would have."""

    def __init__(self, gen):
        self.gen = gen

    def expovariate(self, rate: float) -> float:
        return float(self.gen.exponential(1.0 / rate))

    def lognormvariate(self, mu: float, sigma: float) -> float:
        return float(self.gen.lognormal(mu, sigma))


def synthesize_trace(spec: TraceSpec, arrival_rate: float,
                     seed: int = 0, num_requests: Optional[int] = None,
                     max_len: int = 131072, source_len: int = 0,
                     rng=None) -> List[Request]:
    """Poisson arrivals at ``arrival_rate`` req/s, log-normal lengths.

    ``rng`` overrides the default seeded ``random.Random``: pass either a
    ``random.Random`` or an explicit ``numpy.random.Generator`` (adapted
    transparently).  Two calls with equal-state generators produce
    byte-identical traces — the determinism contract parallel search
    workers (``jobs=N``) rely on when each regenerates its own copy.
    The default path is unchanged (same draws as before).
    """
    if rng is None:
        rng = random.Random(seed)
    elif not hasattr(rng, "expovariate"):
        rng = _GeneratorDraws(rng)       # numpy Generator
    n = num_requests or spec.num_requests
    cmu, csig = _lognormal_params(spec.ctx_mean, spec.ctx_std)
    gmu, gsig = _lognormal_params(spec.gen_mean, spec.gen_std)
    out: List[Request] = []
    t = 0.0
    for i in range(n):
        t += rng.expovariate(arrival_rate)
        ctx = max(1, min(max_len, int(round(rng.lognormvariate(cmu, csig)))))
        gen = max(1, min(max_len, int(round(rng.lognormvariate(gmu, gsig)))))
        out.append(Request(rid=i, arrival=t, context_len=ctx, gen_len=gen,
                           source_len=source_len))
    return out


def get_trace(name: str, arrival_rate: float = 0.5, seed: int = 0,
              num_requests: Optional[int] = None,
              source_len: int = 0, rng=None) -> List[Request]:
    if name not in TRACE_SPECS:
        raise KeyError(f"unknown trace {name!r}; known: {sorted(TRACE_SPECS)}")
    return synthesize_trace(TRACE_SPECS[name], arrival_rate, seed=seed,
                            num_requests=num_requests, source_len=source_len,
                            rng=rng)


def trace_stats(reqs: List[Request]) -> dict:
    n = len(reqs)
    cm = sum(r.context_len for r in reqs) / n
    gm = sum(r.gen_len for r in reqs) / n
    cv = math.sqrt(sum((r.context_len - cm) ** 2 for r in reqs) / n)
    gv = math.sqrt(sum((r.gen_len - gm) ** 2 for r in reqs) / n)
    return {"n": n, "ctx_mean": cm, "ctx_std": cv, "gen_mean": gm,
            "gen_std": gv, "span_s": reqs[-1].arrival if reqs else 0.0}
