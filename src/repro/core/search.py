"""Automated parallel-execution search — APEX's top-level workflow (Fig. 2).

Given (model IR, cluster, request trace):
  1. generate parallel schemes (planner.py, Algorithm 1),
  2. map each to physical devices (mapper.py),
  3. simulate serving the trace under iteration-level batching
     (batching.py + simulator.py),
  4. rank by a parameterizable objective — latency, energy, or
     SLO-constrained (paper §3.1: "APEX can optimize towards different
     objectives ... based on a parametrizable target metric").

Also provides the paper's three comparison points (§4.2): the heuristic
baseline plan, the Feasible Optimal (no cell-level DP / heterogeneous
sharding), and the unconstrained APEX Optimal.

Candidate enumeration and simulator construction are factored out of the
search loop (``candidates()`` / ``make_simulator()``) so the exact path
here and the fluid-surrogate screening path (core/multifid.py) evaluate
the SAME candidate set through either fidelity.  ``search(jobs=N)``
fans the per-plan simulations out across forked worker processes —
plans are independent and every evaluation is a pure function of
(plan, requests), so the parallel reports are identical to serial.
"""

from __future__ import annotations

import dataclasses
import inspect
import time as _time
from typing import Callable, List, Optional, Sequence, Tuple

from .batching import BatchingPolicy
from .cluster import Cluster
from .ir import ModelIR
from .mapper import ExecutionPlan, map_scheme
from .planner import (ParallelScheme, generate_schemes, heuristic_scheme,
                      prefilter_schemes)
from .engine import SharedCostStore
from .profiles import AnalyticBackend, CollectiveModel, ProfileBackend, \
    ProfileStore
from .simulator import PlanSimulator, SimulationReport
from .trace import Request, retag_slo


Objective = Callable[[SimulationReport], float]

OBJECTIVES = {
    "latency": lambda r: r.e2e_latency,
    "energy": lambda r: r.total_energy,
    "ttft": lambda r: r.ttft_p95,
    "tpot": lambda r: r.tpot_p95,
    "throughput": lambda r: -r.throughput_tok_s,   # maximize tok/s
    # maximize requests meeting their own SLO class's targets per second
    # (classless traces degrade to request throughput)
    "goodput": lambda r: -r.goodput_rps,
    # resilience-aware: maximize SLO goodput under a seeded fault
    # ensemble (``search(..., faults=...)``).  Reports without a
    # resilience block (fluid surrogate screening, halving rungs — both
    # fault-free by design) rank by their fault-free goodput, so the
    # multi-fidelity ladder still orders candidates sensibly and only
    # exact confirmation pays for faulted re-simulation.
    "degraded_goodput": lambda r: -(r.resilience.goodput_rps
                                    if r.resilience is not None
                                    else r.goodput_rps),
}

# A candidate plan before simulation: family is "colocated" | "disagg",
# pools is None (shared cluster) or a (prefill_cluster, decode_cluster)
# pair from a heterogeneous pool menu.
Candidate = Tuple[str, object, Optional[tuple]]


# ---------------------------------------------------------------------------
# forked parallel evaluation
# ---------------------------------------------------------------------------

class PlanEvaluationError(RuntimeError):
    """A per-candidate evaluation crashed — carries WHICH candidate.

    Raised by ``fork_map`` for both serial and forked failures, so a
    crash on candidate 137 of 1000 names the failing plan instead of
    surfacing as an anonymous worker traceback (forked workers cannot
    even propagate arbitrary exceptions — they may not pickle)."""

    def __init__(self, index: int, label: Optional[str],
                 cause_repr: str, worker_traceback: str = ""):
        self.index = index
        self.label = label
        self.cause_repr = cause_repr
        self.worker_traceback = worker_traceback
        what = f"evaluation of candidate {index}"
        if label:
            what += f" ({label})"
        super().__init__(f"{what} failed: {cause_repr}")


class _WorkerFailure:
    """Picklable stand-in a forked worker sends back when ``fn(i)``
    raises (the exception object itself may hold unpicklable state —
    simulator closures, heap lambdas)."""

    __slots__ = ("index", "cause_repr", "traceback")

    def __init__(self, index: int, cause_repr: str, traceback: str):
        self.index = index
        self.cause_repr = cause_repr
        self.traceback = traceback


def _label_of(label, i: int) -> Optional[str]:
    if label is None:
        return None
    try:
        return label(i)
    except Exception:
        return None


# The work closure is stashed module-level and inherited by forked
# workers (copy-on-write), so nothing but an index crosses the pipe on
# the way in and a picklable report on the way out.
_FORK_WORK: dict = {"fn": None}


def _fork_call(i: int):
    try:
        return _FORK_WORK["fn"](i)
    except Exception as exc:          # -> picklable failure sentinel
        import traceback
        return _WorkerFailure(i, repr(exc), traceback.format_exc())


def _serial_map(fn: Callable[[int], object], n: int,
                progress: Optional[Callable[[int], None]] = None,
                label: Optional[Callable[[int], str]] = None) -> list:
    out = []
    for i in range(n):
        try:
            out.append(fn(i))
        except Exception as exc:
            raise PlanEvaluationError(i, _label_of(label, i),
                                      repr(exc)) from exc
        if progress:
            progress(i + 1)
    return out


def fork_map(fn: Callable[[int], object], n: int, jobs: int,
             progress: Optional[Callable[[int], None]] = None,
             label: Optional[Callable[[int], str]] = None) -> list:
    """``[fn(i) for i in range(n)]`` across ``jobs`` forked processes.

    Falls back to the serial loop when ``jobs <= 1``, there is nothing
    to parallelize, or the platform has no fork (the only start method
    that inherits the closure without pickling it).  Spawn-only
    platforms (Windows, some macOS configurations) get the serial
    fallback with a warning rather than a crash.  Results come back
    in index order, so callers see exactly the serial sequence.

    A crash inside ``fn(i)`` — serial or forked — raises
    ``PlanEvaluationError`` naming the failing index (and its
    ``label(i)``, when given), never a bare worker traceback.
    """
    if jobs <= 1 or n <= 1:
        return _serial_map(fn, n, progress, label)
    import multiprocessing as mp
    if "fork" not in mp.get_all_start_methods():
        import warnings
        warnings.warn(
            "search(jobs=N) needs the 'fork' start method, which this "
            "platform does not offer; evaluating serially instead",
            RuntimeWarning, stacklevel=2)
        return _serial_map(fn, n, progress, label)
    try:
        ctx = mp.get_context("fork")
    except ValueError:
        return _serial_map(fn, n, progress, label)
    _FORK_WORK["fn"] = fn
    try:
        with ctx.Pool(min(jobs, n)) as pool:
            out = []
            for i, res in enumerate(pool.imap(_fork_call, range(n))):
                if isinstance(res, _WorkerFailure):
                    raise PlanEvaluationError(
                        res.index, _label_of(label, res.index),
                        res.cause_repr, res.traceback)
                out.append(res)
                if progress:
                    progress(i + 1)
            return out
    finally:
        _FORK_WORK["fn"] = None


def _call_progress(progress, done: int, total: int, best) -> None:
    """Invoke a progress callback with (done, total) or, when it accepts
    a third parameter, (done, total, current_best_report)."""
    try:
        n_params = len(inspect.signature(progress).parameters)
    except (TypeError, ValueError):
        n_params = 2
    if n_params >= 3:
        progress(done, total, best)
    else:
        progress(done, total)


@dataclasses.dataclass
class SearchResult:
    best: SimulationReport
    best_plan: object              # ExecutionPlan | disagg.DisaggPlan
    all_reports: List[SimulationReport]
    num_schemes: int
    num_feasible: int
    search_seconds: float
    objective: str = "latency"     # what the search ranked by
    slo_ttft_s: Optional[float] = None   # the SLO filters the search used
    slo_tpot_s: Optional[float] = None
    cache_hits: int = 0            # summed StepCostCache counters across
    cache_misses: int = 0          # every simulated candidate

    def admissible(self, r: SimulationReport) -> bool:
        """Feasible AND within the search's own SLO filters — the same
        predicate ``search`` applied when picking ``best``, so ``top``
        never surfaces plans the search itself rejected."""
        if not r.feasible:
            return False
        if self.slo_ttft_s is not None and r.ttft_p95 > self.slo_ttft_s:
            return False
        if self.slo_tpot_s is not None and r.tpot_p95 > self.slo_tpot_s:
            return False
        return True

    def top(self, k: int = 5) -> List[SimulationReport]:
        """Best-k admissible reports under the *search's own* objective."""
        key = OBJECTIVES.get(self.objective, OBJECTIVES["latency"])
        return sorted(filter(self.admissible, self.all_reports),
                      key=key)[:k]


class ApexSearch:
    """One search context: model + cluster + profiling backend."""

    def __init__(self, model: ModelIR, cluster: Cluster,
                 backend: Optional[ProfileBackend] = None,
                 freq_ghz: Optional[float] = None,
                 grid_stride: int = 1,
                 share_step_costs: bool = True):
        self.model = model
        self.cluster = cluster
        self.freq_ghz = freq_ghz
        self.grid_stride = grid_stride
        self.backend = backend or AnalyticBackend(cluster, freq_ghz=freq_ghz)
        self.store = ProfileStore(self.backend, grid_stride=grid_stride)
        self.coll = CollectiveModel(cluster, freq_ghz=freq_ghz)
        # search-scoped cross-plan step-cost store: candidates with equal
        # cost fingerprints (e.g. DP widths of one layout) price each
        # workload once per SEARCH instead of once per plan; it persists
        # across search() calls on this context, like ProfileStore does.
        # share_step_costs=False restores fully private per-simulator
        # caches (results are bit-identical either way — tested).
        self.cost_store = SharedCostStore() if share_step_costs else None
        # per-pool-cluster cost models for heterogeneous disagg candidates
        self._pool_ctx: dict = {}

    def _pool_cost_models(self, cluster: Cluster):
        """(store, coll) for one pool cluster of a heterogeneous plan,
        cached so every candidate pair sharing a pool reuses its tables."""
        key = id(cluster)
        if key not in self._pool_ctx:
            backend = AnalyticBackend(cluster, freq_ghz=self.freq_ghz)
            self._pool_ctx[key] = (
                ProfileStore(backend, grid_stride=self.grid_stride),
                CollectiveModel(cluster, freq_ghz=self.freq_ghz))
        return self._pool_ctx[key]

    # -- single-plan evaluation -------------------------------------------------

    def evaluate(self, scheme: ParallelScheme, requests: Sequence[Request],
                 policy: Optional[BatchingPolicy] = None,
                 keep_records: bool = False,
                 preemption=None,
                 slo_classes=None,
                 faults=None) -> SimulationReport:
        from .faults import attach_resilience, normalize_faults
        faults = normalize_faults(faults)
        plan = map_scheme(scheme, self.cluster)
        sim = PlanSimulator(plan, self.store, self.coll,
                            cost_store=self.cost_store)
        rep = sim.simulate(requests, policy=policy,
                           keep_records=keep_records,
                           preemption=preemption, slo_classes=slo_classes)
        if faults and rep.feasible:
            members = [sim.simulate(requests, policy=policy,
                                    preemption=preemption,
                                    slo_classes=slo_classes, faults=f)
                       for f in faults]
            rep = attach_resilience(rep, members)
        return rep

    def evaluate_baseline(self, requests: Sequence[Request],
                          quant: str = "fp16",
                          policy: Optional[BatchingPolicy] = None
                          ) -> SimulationReport:
        """The heuristic plan: TP in-node, PP across nodes (paper §4.2)."""
        scheme = heuristic_scheme(self.model, self.cluster.num_devices,
                                  cluster=self.cluster, quant=quant)
        return self.evaluate(scheme, requests, policy=policy)

    # -- candidate enumeration (shared by exact and surrogate search) ----------

    def candidates(self, quant: str = "fp16",
                   feasible_only: bool = False,
                   max_model_dp: Optional[int] = None,
                   disaggregated: bool = False,
                   transfer_mode: str = "layerwise",
                   decode_quant: Optional[str] = None,
                   max_disagg_plans: int = 256,
                   pool_menu: Optional[Sequence[Cluster]] = None,
                   max_total_devices: Optional[int] = None
                   ) -> Tuple[List[Candidate], object]:
        """Enumerate the candidate set one search call would simulate.

        Returns ``(candidates, kv_model)`` where each candidate is
        ``(family, scheme, pools)`` — see ``make_simulator`` — and
        ``kv_model`` is the shared-cluster KV-transfer model (None for a
        colocated-only search).
        """
        schemes = generate_schemes(self.model, self.cluster.num_devices,
                                   quant=quant,
                                   allow_cell_dp=not feasible_only,
                                   max_model_dp=max_model_dp)
        if feasible_only:
            schemes = [s for s in schemes
                       if s.is_feasible_for_current_systems()]
        # cheap static pre-filter: drop plans whose weights alone overflow
        schemes = prefilter_schemes(schemes,
                                    self.cluster.device.hbm_bytes)

        candidates: List[Candidate] = [("colocated", s, None)
                                       for s in schemes]
        kv_model = None
        if disaggregated:
            from ..disagg import (KVTransferModel, generate_disagg_schemes)
            dschemes = generate_disagg_schemes(
                self.model, self.cluster, quant=quant,
                decode_quant=decode_quant,
                feasible_only=True, transfer_mode=transfer_mode,
                max_model_dp=max_model_dp, max_plans=max_disagg_plans)
            kv_model = KVTransferModel(self.coll, mode=transfer_mode)
            candidates += [("disagg", s, None) for s in dschemes]
            if pool_menu:
                budget = max_total_devices or self.cluster.num_devices
                pairs = [(a, b) for a in pool_menu for b in pool_menu
                         if a.num_devices + b.num_devices <= budget]
                # menu pairs get their own candidate budget, split evenly
                # so neither the shared-cluster split family nor an early
                # pair starves the rest of slots
                per_pair = max(1, max_disagg_plans // max(1, len(pairs)))
                for pre_c, dec_c in pairs:
                    hschemes = generate_disagg_schemes(
                        self.model, quant=quant,
                        decode_quant=decode_quant,
                        feasible_only=True,
                        transfer_mode=transfer_mode,
                        max_model_dp=max_model_dp, max_plans=per_pair,
                        prefill_cluster=pre_c, decode_cluster=dec_c)
                    candidates += [("disagg", s, (pre_c, dec_c))
                                   for s in hschemes]
        return candidates, kv_model

    def make_simulator(self, candidate: Candidate, kv_model=None,
                       fluid: bool = False):
        """(plan, simulator) for one candidate, at either fidelity.

        ``fluid=True`` builds the fluid-ODE surrogate (core/fluid.py)
        from the same cost models the exact simulator would use, so the
        two fidelities disagree only on dynamics, never on step costs.
        """
        family, scheme, pools = candidate
        cs = self.cost_store
        if family == "colocated":
            plan = map_scheme(scheme, self.cluster)
            if fluid:
                from .fluid import FluidSimulator
                return plan, FluidSimulator(plan, self.store, self.coll,
                                            cost_store=cs)
            return plan, PlanSimulator(plan, self.store, self.coll,
                                       cost_store=cs)
        from ..disagg import DisaggSimulator, map_disagg_scheme
        if fluid:
            from .fluid import FluidDisaggSimulator
            sim_cls = FluidDisaggSimulator
        else:
            sim_cls = DisaggSimulator
        if pools is None:
            plan = map_disagg_scheme(scheme, self.cluster)
            return plan, sim_cls(plan, self.store, self.coll, kv_model,
                                 cost_store=cs)
        pre_c, dec_c = pools
        plan = map_disagg_scheme(scheme, prefill_cluster=pre_c,
                                 decode_cluster=dec_c)
        pre_store, pre_coll = self._pool_cost_models(pre_c)
        dec_store, dec_coll = self._pool_cost_models(dec_c)
        return plan, sim_cls(plan, pre_store, pre_coll,
                             decode_store=dec_store, decode_coll=dec_coll,
                             cost_store=cs)

    # -- full search --------------------------------------------------------------

    def search(self, requests: Sequence[Request],
               objective: str = "latency",
               quant: str = "fp16",
               feasible_only: bool = False,
               policy: Optional[BatchingPolicy] = None,
               max_model_dp: Optional[int] = None,
               slo_ttft_s: Optional[float] = None,
               slo_tpot_s: Optional[float] = None,
               disaggregated: bool = False,
               transfer_mode: str = "layerwise",
               decode_quant: Optional[str] = None,
               max_disagg_plans: int = 256,
               pool_menu: Optional[Sequence[Cluster]] = None,
               max_total_devices: Optional[int] = None,
               prefill_policy: Optional[BatchingPolicy] = None,
               decode_policy: Optional[BatchingPolicy] = None,
               progress: Optional[Callable] = None,
               verbose: bool = False,
               jobs: int = 1,
               preemption=None,
               slo_classes=None,
               faults=None,
               dynamic=None) -> SearchResult:
        """Rank plans under ``objective``; with ``disaggregated=True`` the
        candidate set is the union of colocated schemes and two-pool
        disaggregated schemes (disagg/), scored by the same simulator
        metrics so one objective ranks both families jointly.

        ``pool_menu`` adds HETEROGENEOUS disaggregated candidates: every
        ordered (prefill_cluster, decode_cluster) pair from the menu whose
        combined device count fits ``max_total_devices`` (default: this
        search's cluster size) is enumerated — e.g. a menu of
        ``[h100_node(8), h200_node(8)]`` tries H100-prefill/H200-decode and
        every other assignment (including same-device pairs — two separate
        islands joined by a cross-pool link are a different deployment
        from splitting one shared cluster, and are labeled with their pool
        devices to stay distinguishable).  Each pool is costed on its own
        cluster's analytic model; the KV handoff crosses the pair's
        cross-pool link.  ``max_disagg_plans`` caps each disagg family
        separately (the shared-cluster splits, and the menu pairs jointly)
        — with a menu, up to ~2x that many disagg candidates simulate.

        ``prefill_policy``/``decode_policy`` drive the two pools of every
        disaggregated candidate with their own batching policies (e.g.
        chunked prefill only on the prefill pool, a different
        max_batch_size per pool), defaulting to the shared ``policy``;
        colocated candidates always use ``policy``.

        Long searches need not run silently: ``progress(done, total)`` —
        or ``progress(done, total, best_report)`` if the callback takes a
        third parameter — fires after every candidate, and
        ``verbose=True`` prints periodic candidates-evaluated /
        current-best lines.

        ``jobs=N`` evaluates candidates across N forked processes.  Plans
        are independent and each simulation is a pure function of
        (plan, requests), so the reports — and therefore the ranking —
        are identical to a serial run.

        ``preemption`` selects every candidate's KV-overflow policy
        (menu string or ``PreemptionPolicy``; None = sacrifice +
        recent-first); ``slo_classes`` re-tags the trace's SLO classes
        by name before simulation, so ``objective="goodput"`` ranks by
        requests meeting their class targets per second.

        ``faults`` (a ``FaultSchedule`` or a ``fault_ensemble`` list)
        re-simulates every feasible candidate under each member schedule
        and attaches the ensemble-aggregated ``ResilienceReport`` to its
        nominal report — required by ``objective="degraded_goodput"``,
        which ranks plans by how much SLO goodput survives the draws.

        ``dynamic`` (a ``core.dynamic.DynamicSpec``) extends the ranking
        with epoch-gated plan SWITCHING: schedules over the static
        sweep's top-k plans are simulated through
        ``DynamicPlanSimulator`` (reconfiguration costs itemized in each
        report's ``reconfig``) and ranked under the same objective and
        SLO filters, so the winner may be a switching timetable — or the
        best static plan, an honest negative result.  An empty spec
        returns the static result unchanged (bit-identical to
        ``dynamic=None``).  Dynamic candidates are evaluated fault-free;
        to rank plan switching UNDER faults, drive
        ``DynamicPlanSimulator`` with a ``fault_schedule`` directly.
        """
        t0 = _time.perf_counter()
        if objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {objective!r}; choose "
                             f"one of {sorted(OBJECTIVES)}")
        if jobs < 0:
            raise ValueError(f"jobs must be >= 0, got {jobs}")
        from .faults import attach_resilience, normalize_faults
        faults = normalize_faults(faults)
        if objective == "degraded_goodput" and not faults:
            raise ValueError(
                "objective='degraded_goodput' needs a non-empty fault "
                "ensemble: pass faults=FaultSchedule(...) or "
                "faults=fault_ensemble(...)")
        obj = OBJECTIVES[objective]
        requests = retag_slo(requests, slo_classes)
        candidates, kv_model = self.candidates(
            quant=quant, feasible_only=feasible_only,
            max_model_dp=max_model_dp, disaggregated=disaggregated,
            transfer_mode=transfer_mode, decode_quant=decode_quant,
            max_disagg_plans=max_disagg_plans, pool_menu=pool_menu,
            max_total_devices=max_total_devices)

        def eval_one(i: int):
            family = candidates[i][0]
            _, sim = self.make_simulator(candidates[i], kv_model)
            sim_kwargs = {} if family == "colocated" else {
                "prefill_policy": prefill_policy,
                "decode_policy": decode_policy}
            rep = sim.simulate(requests, policy=policy,
                               preemption=preemption, **sim_kwargs)
            st = getattr(sim, "cache_stats", None) or {}
            hits, misses = st.get("hits", 0), st.get("misses", 0)
            if faults and rep.feasible:
                members = []
                for f in faults:
                    members.append(sim.simulate(
                        requests, policy=policy, preemption=preemption,
                        faults=f, **sim_kwargs))
                    st = getattr(sim, "cache_stats", None) or {}
                    hits += st.get("hits", 0)
                    misses += st.get("misses", 0)
                rep = attach_resilience(rep, members)
            return rep, hits, misses

        reports, best_idx, hits, misses = self._evaluate_ranked(
            eval_one, len(candidates), obj, slo_ttft_s, slo_tpot_s,
            jobs=jobs, progress=progress, verbose=verbose,
            tag="search",
            label=lambda i: candidates[i][1].label())
        if best_idx is None:
            raise RuntimeError(
                "no feasible plan found (memory or SLO constraints too "
                f"tight) among {len(candidates)} schemes")
        best_plan, _ = self.make_simulator(candidates[best_idx], kv_model)
        result = SearchResult(best=reports[best_idx], best_plan=best_plan,
                              all_reports=reports,
                              num_schemes=len(candidates),
                              num_feasible=sum(r.feasible for r in reports),
                              search_seconds=_time.perf_counter() - t0,
                              objective=objective,
                              slo_ttft_s=slo_ttft_s, slo_tpot_s=slo_tpot_s,
                              cache_hits=hits, cache_misses=misses)
        if dynamic is None or dynamic.is_empty:
            return result
        return self._extend_dynamic(result, dynamic, candidates, kv_model,
                                    requests, obj, policy=policy,
                                    preemption=preemption, t0=t0)

    def _extend_dynamic(self, result: SearchResult, spec, candidates,
                        kv_model, requests, obj,
                        policy=None, preemption=None,
                        t0: float = 0.0) -> SearchResult:
        """Rank {static winners} ∪ {epoch schedules over the top-k static
        plans} under one objective (``search(dynamic=...)``'s second
        phase).  Schedule plan indices are ranks into the top-k list."""
        from .dynamic import DynamicPlanSimulator, build_schedules
        ranked = sorted((r for r in result.all_reports
                         if result.admissible(r)), key=obj)[:spec.top_k]
        by_label = {r.plan_label: i for i, r in enumerate(result.all_reports)}
        top_cands = [candidates[by_label[r.plan_label]] for r in ranked]
        if spec.mechanism == "migrate":
            top_cands = [c for c in top_cands if c[0] == "colocated"]
        if len(top_cands) < 2:
            return result          # nothing to switch between
        horizon = max((r.arrival for r in requests), default=0.0)
        schedules = build_schedules(spec, requests, horizon, len(top_cands))
        dyn_reports = []
        for sched in schedules:
            dyn = DynamicPlanSimulator(self, top_cands, sched,
                                       kv_model=kv_model,
                                       mechanism=spec.mechanism)
            dyn_reports.append(dyn.simulate(
                requests, policy=policy, preemption=preemption))
        all_reports = result.all_reports + dyn_reports
        merged = dataclasses.replace(
            result, all_reports=all_reports,
            num_schemes=result.num_schemes + len(dyn_reports),
            num_feasible=sum(r.feasible for r in all_reports),
            search_seconds=_time.perf_counter() - t0)
        winners = [r for r in all_reports if merged.admissible(r)]
        if winners:
            best = min(winners, key=obj)
            if best.plan_label != result.best.plan_label:
                # a switching timetable won: best_plan stays the epoch-0
                # static plan (the deployment you boot into); the full
                # timetable lives in best.reconfig + the plan label
                merged = dataclasses.replace(merged, best=best)
        return merged

    def _evaluate_ranked(self, eval_one: Callable[[int], tuple], n: int,
                         obj: Objective,
                         slo_ttft_s: Optional[float],
                         slo_tpot_s: Optional[float],
                         jobs: int = 1,
                         progress: Optional[Callable] = None,
                         verbose: bool = False,
                         tag: str = "search",
                         label: Optional[Callable[[int], str]] = None):
        """Run ``eval_one`` over ``range(n)`` (serial or forked), track
        the SLO-filtered objective winner, and aggregate cache counters.
        Returns (reports, best_idx, cache_hits, cache_misses)."""
        state = {"best": None, "best_idx": None, "done": 0}
        results: List[tuple] = []
        every = max(1, n // 20)

        def admit(rep) -> bool:
            if not rep.feasible:
                return False
            if slo_ttft_s is not None and rep.ttft_p95 > slo_ttft_s:
                return False
            if slo_tpot_s is not None and rep.tpot_p95 > slo_tpot_s:
                return False
            return True

        def on_result(i: int, rep) -> None:
            if admit(rep) and (state["best"] is None
                               or obj(rep) < obj(state["best"])):
                state["best"] = rep
                state["best_idx"] = i
            state["done"] += 1
            if progress:
                _call_progress(progress, state["done"], n, state["best"])
            if verbose and (state["done"] % every == 0
                            or state["done"] == n):
                b = state["best"]
                cur = (f"best={b.plan_label} obj={obj(b):.4g}"
                       if b is not None else "best=<none feasible>")
                print(f"[{tag}] {state['done']}/{n} evaluated, {cur}")

        def run(i: int):
            res = eval_one(i)
            return res

        ordered = fork_map(run, n, jobs, label=label)
        for i, res in enumerate(ordered):
            results.append(res)
            on_result(i, res[0])
        reports = [r for r, _, _ in results]
        hits = sum(h for _, h, _ in results)
        misses = sum(m for _, _, m in results)
        return reports, state["best_idx"], hits, misses


def compare_three_plans(model: ModelIR, cluster: Cluster,
                        requests: Sequence[Request], quant: str = "fp16",
                        policy: Optional[BatchingPolicy] = None) -> dict:
    """Reproduce a Table-2 row: baseline vs Feasible Optimal vs APEX Optimal."""
    search = ApexSearch(model, cluster)
    base = search.evaluate_baseline(requests, quant=quant, policy=policy)
    feas = search.search(requests, quant=quant, feasible_only=True,
                         policy=policy)
    full = search.search(requests, quant=quant, feasible_only=False,
                         policy=policy)
    return {
        "baseline": base,
        "feasible_optimal": feas.best,
        "apex_optimal": full.best,
        "feasible_speedup": base.e2e_latency / feas.best.e2e_latency,
        "apex_speedup": base.e2e_latency / full.best.e2e_latency,
        "search": full,
    }
