"""Automated parallel-execution search — APEX's top-level workflow (Fig. 2).

Given (model IR, cluster, request trace):
  1. generate parallel schemes (planner.py, Algorithm 1),
  2. map each to physical devices (mapper.py),
  3. simulate serving the trace under iteration-level batching
     (batching.py + simulator.py),
  4. rank by a parameterizable objective — latency, energy, or
     SLO-constrained (paper §3.1: "APEX can optimize towards different
     objectives ... based on a parametrizable target metric").

Also provides the paper's three comparison points (§4.2): the heuristic
baseline plan, the Feasible Optimal (no cell-level DP / heterogeneous
sharding), and the unconstrained APEX Optimal.
"""

from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, List, Optional, Sequence

from .batching import BatchingPolicy
from .cluster import Cluster
from .ir import ModelIR
from .mapper import ExecutionPlan, map_scheme
from .planner import (ParallelScheme, generate_schemes, heuristic_scheme,
                      prefilter_schemes)
from .profiles import AnalyticBackend, CollectiveModel, ProfileBackend, \
    ProfileStore
from .simulator import PlanSimulator, SimulationReport
from .trace import Request


Objective = Callable[[SimulationReport], float]

OBJECTIVES = {
    "latency": lambda r: r.e2e_latency,
    "energy": lambda r: r.total_energy,
    "ttft": lambda r: r.ttft_p95,
    "tpot": lambda r: r.tpot_p95,
    "throughput": lambda r: -r.throughput_tok_s,   # maximize tok/s
}


@dataclasses.dataclass
class SearchResult:
    best: SimulationReport
    best_plan: object              # ExecutionPlan | disagg.DisaggPlan
    all_reports: List[SimulationReport]
    num_schemes: int
    num_feasible: int
    search_seconds: float
    objective: str = "latency"     # what the search ranked by
    slo_ttft_s: Optional[float] = None   # the SLO filters the search used
    slo_tpot_s: Optional[float] = None

    def admissible(self, r: SimulationReport) -> bool:
        """Feasible AND within the search's own SLO filters — the same
        predicate ``search`` applied when picking ``best``, so ``top``
        never surfaces plans the search itself rejected."""
        if not r.feasible:
            return False
        if self.slo_ttft_s is not None and r.ttft_p95 > self.slo_ttft_s:
            return False
        if self.slo_tpot_s is not None and r.tpot_p95 > self.slo_tpot_s:
            return False
        return True

    def top(self, k: int = 5) -> List[SimulationReport]:
        """Best-k admissible reports under the *search's own* objective."""
        key = OBJECTIVES.get(self.objective, OBJECTIVES["latency"])
        return sorted(filter(self.admissible, self.all_reports),
                      key=key)[:k]


class ApexSearch:
    """One search context: model + cluster + profiling backend."""

    def __init__(self, model: ModelIR, cluster: Cluster,
                 backend: Optional[ProfileBackend] = None,
                 freq_ghz: Optional[float] = None,
                 grid_stride: int = 1):
        self.model = model
        self.cluster = cluster
        self.freq_ghz = freq_ghz
        self.grid_stride = grid_stride
        self.backend = backend or AnalyticBackend(cluster, freq_ghz=freq_ghz)
        self.store = ProfileStore(self.backend, grid_stride=grid_stride)
        self.coll = CollectiveModel(cluster, freq_ghz=freq_ghz)
        # per-pool-cluster cost models for heterogeneous disagg candidates
        self._pool_ctx: dict = {}

    def _pool_cost_models(self, cluster: Cluster):
        """(store, coll) for one pool cluster of a heterogeneous plan,
        cached so every candidate pair sharing a pool reuses its tables."""
        key = id(cluster)
        if key not in self._pool_ctx:
            backend = AnalyticBackend(cluster, freq_ghz=self.freq_ghz)
            self._pool_ctx[key] = (
                ProfileStore(backend, grid_stride=self.grid_stride),
                CollectiveModel(cluster, freq_ghz=self.freq_ghz))
        return self._pool_ctx[key]

    # -- single-plan evaluation -------------------------------------------------

    def evaluate(self, scheme: ParallelScheme, requests: Sequence[Request],
                 policy: Optional[BatchingPolicy] = None,
                 keep_records: bool = False) -> SimulationReport:
        plan = map_scheme(scheme, self.cluster)
        sim = PlanSimulator(plan, self.store, self.coll)
        return sim.simulate(requests, policy=policy,
                            keep_records=keep_records)

    def evaluate_baseline(self, requests: Sequence[Request],
                          quant: str = "fp16",
                          policy: Optional[BatchingPolicy] = None
                          ) -> SimulationReport:
        """The heuristic plan: TP in-node, PP across nodes (paper §4.2)."""
        scheme = heuristic_scheme(self.model, self.cluster.num_devices,
                                  cluster=self.cluster, quant=quant)
        return self.evaluate(scheme, requests, policy=policy)

    # -- full search --------------------------------------------------------------

    def search(self, requests: Sequence[Request],
               objective: str = "latency",
               quant: str = "fp16",
               feasible_only: bool = False,
               policy: Optional[BatchingPolicy] = None,
               max_model_dp: Optional[int] = None,
               slo_ttft_s: Optional[float] = None,
               slo_tpot_s: Optional[float] = None,
               disaggregated: bool = False,
               transfer_mode: str = "layerwise",
               decode_quant: Optional[str] = None,
               max_disagg_plans: int = 256,
               pool_menu: Optional[Sequence[Cluster]] = None,
               max_total_devices: Optional[int] = None,
               prefill_policy: Optional[BatchingPolicy] = None,
               decode_policy: Optional[BatchingPolicy] = None,
               progress: Optional[Callable[[int, int], None]] = None
               ) -> SearchResult:
        """Rank plans under ``objective``; with ``disaggregated=True`` the
        candidate set is the union of colocated schemes and two-pool
        disaggregated schemes (disagg/), scored by the same simulator
        metrics so one objective ranks both families jointly.

        ``pool_menu`` adds HETEROGENEOUS disaggregated candidates: every
        ordered (prefill_cluster, decode_cluster) pair from the menu whose
        combined device count fits ``max_total_devices`` (default: this
        search's cluster size) is enumerated — e.g. a menu of
        ``[h100_node(8), h200_node(8)]`` tries H100-prefill/H200-decode and
        every other assignment (including same-device pairs — two separate
        islands joined by a cross-pool link are a different deployment
        from splitting one shared cluster, and are labeled with their pool
        devices to stay distinguishable).  Each pool is costed on its own
        cluster's analytic model; the KV handoff crosses the pair's
        cross-pool link.  ``max_disagg_plans`` caps each disagg family
        separately (the shared-cluster splits, and the menu pairs jointly)
        — with a menu, up to ~2x that many disagg candidates simulate.

        ``prefill_policy``/``decode_policy`` drive the two pools of every
        disaggregated candidate with their own batching policies (e.g.
        chunked prefill only on the prefill pool, a different
        max_batch_size per pool), defaulting to the shared ``policy``;
        colocated candidates always use ``policy``.
        """
        t0 = _time.perf_counter()
        obj = OBJECTIVES[objective]
        schemes = generate_schemes(self.model, self.cluster.num_devices,
                                   quant=quant,
                                   allow_cell_dp=not feasible_only,
                                   max_model_dp=max_model_dp)
        if feasible_only:
            schemes = [s for s in schemes
                       if s.is_feasible_for_current_systems()]
        # cheap static pre-filter: drop plans whose weights alone overflow
        schemes = prefilter_schemes(schemes,
                                    self.cluster.device.hbm_bytes)

        candidates: List[tuple] = [("colocated", s, None) for s in schemes]
        kv_model = None
        if disaggregated:
            from ..disagg import (DisaggSimulator, KVTransferModel,
                                  generate_disagg_schemes,
                                  map_disagg_scheme)
            dschemes = generate_disagg_schemes(
                self.model, self.cluster, quant=quant,
                decode_quant=decode_quant,
                feasible_only=True, transfer_mode=transfer_mode,
                max_model_dp=max_model_dp, max_plans=max_disagg_plans)
            kv_model = KVTransferModel(self.coll, mode=transfer_mode)
            candidates += [("disagg", s, None) for s in dschemes]
            if pool_menu:
                budget = max_total_devices or self.cluster.num_devices
                pairs = [(a, b) for a in pool_menu for b in pool_menu
                         if a.num_devices + b.num_devices <= budget]
                # menu pairs get their own candidate budget, split evenly
                # so neither the shared-cluster split family nor an early
                # pair starves the rest of slots
                per_pair = max(1, max_disagg_plans // max(1, len(pairs)))
                for pre_c, dec_c in pairs:
                    hschemes = generate_disagg_schemes(
                        self.model, quant=quant,
                        decode_quant=decode_quant,
                        feasible_only=True,
                        transfer_mode=transfer_mode,
                        max_model_dp=max_model_dp, max_plans=per_pair,
                        prefill_cluster=pre_c, decode_cluster=dec_c)
                    candidates += [("disagg", s, (pre_c, dec_c))
                                   for s in hschemes]

        reports: List[SimulationReport] = []
        best: Optional[SimulationReport] = None
        best_plan = None
        for i, (family, scheme, pools) in enumerate(candidates):
            sim_kwargs = {} if family == "colocated" else {
                "prefill_policy": prefill_policy,
                "decode_policy": decode_policy}
            if family == "colocated":
                plan = map_scheme(scheme, self.cluster)
                sim = PlanSimulator(plan, self.store, self.coll)
            elif pools is None:
                plan = map_disagg_scheme(scheme, self.cluster)
                sim = DisaggSimulator(plan, self.store, self.coll,
                                      kv_model)
            else:
                pre_c, dec_c = pools
                plan = map_disagg_scheme(scheme, prefill_cluster=pre_c,
                                         decode_cluster=dec_c)
                pre_store, pre_coll = self._pool_cost_models(pre_c)
                dec_store, dec_coll = self._pool_cost_models(dec_c)
                sim = DisaggSimulator(plan, pre_store, pre_coll,
                                      decode_store=dec_store,
                                      decode_coll=dec_coll)
            rep = sim.simulate(requests, policy=policy, **sim_kwargs)
            reports.append(rep)
            if progress:
                progress(i + 1, len(candidates))
            if not rep.feasible:
                continue
            if slo_ttft_s is not None and rep.ttft_p95 > slo_ttft_s:
                continue
            if slo_tpot_s is not None and rep.tpot_p95 > slo_tpot_s:
                continue
            if best is None or obj(rep) < obj(best):
                best, best_plan = rep, plan
        if best is None:
            raise RuntimeError(
                "no feasible plan found (memory or SLO constraints too "
                f"tight) among {len(candidates)} schemes")
        return SearchResult(best=best, best_plan=best_plan,
                            all_reports=reports,
                            num_schemes=len(candidates),
                            num_feasible=sum(r.feasible for r in reports),
                            search_seconds=_time.perf_counter() - t0,
                            objective=objective,
                            slo_ttft_s=slo_ttft_s, slo_tpot_s=slo_tpot_s)


def compare_three_plans(model: ModelIR, cluster: Cluster,
                        requests: Sequence[Request], quant: str = "fp16",
                        policy: Optional[BatchingPolicy] = None) -> dict:
    """Reproduce a Table-2 row: baseline vs Feasible Optimal vs APEX Optimal."""
    search = ApexSearch(model, cluster)
    base = search.evaluate_baseline(requests, quant=quant, policy=policy)
    feas = search.search(requests, quant=quant, feasible_only=True,
                         policy=policy)
    full = search.search(requests, quant=quant, feasible_only=False,
                         policy=policy)
    return {
        "baseline": base,
        "feasible_optimal": feas.best,
        "apex_optimal": full.best,
        "feasible_speedup": base.e2e_latency / feas.best.e2e_latency,
        "apex_speedup": base.e2e_latency / full.best.e2e_latency,
        "search": full,
    }
