"""Automated parallel-execution search — APEX's top-level workflow (Fig. 2).

Given (model IR, cluster, request trace):
  1. generate parallel schemes (planner.py, Algorithm 1),
  2. map each to physical devices (mapper.py),
  3. simulate serving the trace under iteration-level batching
     (batching.py + simulator.py),
  4. rank by a parameterizable objective — latency, energy, or
     SLO-constrained (paper §3.1: "APEX can optimize towards different
     objectives ... based on a parametrizable target metric").

Also provides the paper's three comparison points (§4.2): the heuristic
baseline plan, the Feasible Optimal (no cell-level DP / heterogeneous
sharding), and the unconstrained APEX Optimal.
"""

from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, List, Optional, Sequence

from .batching import BatchingPolicy
from .cluster import Cluster
from .ir import ModelIR
from .mapper import ExecutionPlan, map_scheme
from .planner import ParallelScheme, generate_schemes, heuristic_scheme
from .profiles import AnalyticBackend, CollectiveModel, ProfileBackend, \
    ProfileStore
from .simulator import PlanSimulator, SimulationReport
from .trace import Request


Objective = Callable[[SimulationReport], float]

OBJECTIVES = {
    "latency": lambda r: r.e2e_latency,
    "energy": lambda r: r.total_energy,
    "ttft": lambda r: r.ttft_p95,
    "tpot": lambda r: r.tpot_p95,
}


@dataclasses.dataclass
class SearchResult:
    best: SimulationReport
    best_plan: ExecutionPlan
    all_reports: List[SimulationReport]
    num_schemes: int
    num_feasible: int
    search_seconds: float

    def top(self, k: int = 5) -> List[SimulationReport]:
        return sorted((r for r in self.all_reports if r.feasible),
                      key=lambda r: r.e2e_latency)[:k]


class ApexSearch:
    """One search context: model + cluster + profiling backend."""

    def __init__(self, model: ModelIR, cluster: Cluster,
                 backend: Optional[ProfileBackend] = None,
                 freq_ghz: Optional[float] = None,
                 grid_stride: int = 1):
        self.model = model
        self.cluster = cluster
        self.backend = backend or AnalyticBackend(cluster, freq_ghz=freq_ghz)
        self.store = ProfileStore(self.backend, grid_stride=grid_stride)
        self.coll = CollectiveModel(cluster, freq_ghz=freq_ghz)

    # -- single-plan evaluation -------------------------------------------------

    def evaluate(self, scheme: ParallelScheme, requests: Sequence[Request],
                 policy: Optional[BatchingPolicy] = None,
                 keep_records: bool = False) -> SimulationReport:
        plan = map_scheme(scheme, self.cluster)
        sim = PlanSimulator(plan, self.store, self.coll)
        return sim.simulate(requests, policy=policy,
                            keep_records=keep_records)

    def evaluate_baseline(self, requests: Sequence[Request],
                          quant: str = "fp16",
                          policy: Optional[BatchingPolicy] = None
                          ) -> SimulationReport:
        """The heuristic plan: TP in-node, PP across nodes (paper §4.2)."""
        scheme = heuristic_scheme(self.model, self.cluster.num_devices,
                                  cluster=self.cluster, quant=quant)
        return self.evaluate(scheme, requests, policy=policy)

    # -- full search --------------------------------------------------------------

    def search(self, requests: Sequence[Request],
               objective: str = "latency",
               quant: str = "fp16",
               feasible_only: bool = False,
               policy: Optional[BatchingPolicy] = None,
               max_model_dp: Optional[int] = None,
               slo_ttft_s: Optional[float] = None,
               slo_tpot_s: Optional[float] = None,
               progress: Optional[Callable[[int, int], None]] = None
               ) -> SearchResult:
        t0 = _time.perf_counter()
        obj = OBJECTIVES[objective]
        schemes = generate_schemes(self.model, self.cluster.num_devices,
                                   quant=quant,
                                   allow_cell_dp=not feasible_only,
                                   max_model_dp=max_model_dp)
        if feasible_only:
            schemes = [s for s in schemes
                       if s.is_feasible_for_current_systems()]
        # cheap static pre-filter: drop plans whose weights alone overflow
        cap = self.cluster.device.hbm_bytes * 0.92
        schemes = [s for s in schemes if s.weight_bytes_per_device() < cap]

        reports: List[SimulationReport] = []
        best: Optional[SimulationReport] = None
        best_plan: Optional[ExecutionPlan] = None
        for i, scheme in enumerate(schemes):
            plan = map_scheme(scheme, self.cluster)
            sim = PlanSimulator(plan, self.store, self.coll)
            rep = sim.simulate(requests, policy=policy)
            reports.append(rep)
            if progress:
                progress(i + 1, len(schemes))
            if not rep.feasible:
                continue
            if slo_ttft_s is not None and rep.ttft_p95 > slo_ttft_s:
                continue
            if slo_tpot_s is not None and rep.tpot_p95 > slo_tpot_s:
                continue
            if best is None or obj(rep) < obj(best):
                best, best_plan = rep, plan
        if best is None:
            raise RuntimeError(
                "no feasible plan found (memory or SLO constraints too "
                f"tight) among {len(schemes)} schemes")
        return SearchResult(best=best, best_plan=best_plan,
                            all_reports=reports, num_schemes=len(schemes),
                            num_feasible=sum(r.feasible for r in reports),
                            search_seconds=_time.perf_counter() - t0)


def compare_three_plans(model: ModelIR, cluster: Cluster,
                        requests: Sequence[Request], quant: str = "fp16",
                        policy: Optional[BatchingPolicy] = None) -> dict:
    """Reproduce a Table-2 row: baseline vs Feasible Optimal vs APEX Optimal."""
    search = ApexSearch(model, cluster)
    base = search.evaluate_baseline(requests, quant=quant, policy=policy)
    feas = search.search(requests, quant=quant, feasible_only=True,
                         policy=policy)
    full = search.search(requests, quant=quant, feasible_only=False,
                         policy=policy)
    return {
        "baseline": base,
        "feasible_optimal": feas.best,
        "apex_optimal": full.best,
        "feasible_speedup": base.e2e_latency / feas.best.e2e_latency,
        "apex_speedup": base.e2e_latency / full.best.e2e_latency,
        "search": full,
    }
