"""Serving metrics shared by every simulation path.

``SimulationReport`` is the per-plan outcome both the colocated and the
disaggregated simulators emit (so one objective ranks both families), and
``percentile`` is the rank-order estimator the paper's P95 numbers use.
Promoted out of ``simulator.py`` so the disagg subsystem no longer
imports private helpers or re-builds the infeasible report by hand.

Multi-tenant extension: every request record carries an ``SLOClass``
(core/trace.py), so a report also breaks TTFT/TPOT percentiles out per
class (``class_reports``) and measures **SLO goodput** — requests that
met their own class's TTFT/TPOT targets, per second of simulated time.
A class with no targets counts every finished request, so single-tenant
traces degrade to plain request throughput.  ``request_metrics`` is the
one place the latency/goodput block is computed, shared by both exact
simulators so the two families aggregate identically.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence


def percentile(xs: List[float], q: float) -> float:
    """Rank-order percentile (no interpolation): the smallest sample with
    at least ``q`` of the mass at or below it.  Returns 0.0 when empty."""
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, int(math.ceil(q * len(s))) - 1)]


def p50(xs: List[float]) -> float:
    return percentile(xs, 0.50)


def p95(xs: List[float]) -> float:
    return percentile(xs, 0.95)


def p99(xs: List[float]) -> float:
    return percentile(xs, 0.99)


def slo_met(rec) -> bool:
    """Did this finished request meet its own class's SLO targets?"""
    return rec.slo_class.met_by(rec.ttft, rec.tpot, rec.gen_len > 1)


@dataclasses.dataclass
class ClassReport:
    """One SLO class's slice of a simulation: latency percentiles over
    just its requests, and how many of them met the class targets."""

    name: str
    priority: int
    num_requests: int
    ttft_mean: float
    ttft_p50: float
    ttft_p95: float
    ttft_p99: float
    tpot_mean: float
    tpot_p50: float
    tpot_p95: float
    tpot_p99: float
    slo_met: int                  # requests meeting their class targets
    goodput_rps: float            # slo_met / simulated seconds

    def summary(self) -> str:
        return (f"[{self.name} p{self.priority}] n={self.num_requests} "
                f"TTFT p50/p95/p99="
                f"{self.ttft_p50 * 1e3:.0f}/{self.ttft_p95 * 1e3:.0f}/"
                f"{self.ttft_p99 * 1e3:.0f}ms "
                f"TPOT p50/p95/p99="
                f"{self.tpot_p50 * 1e3:.1f}/{self.tpot_p95 * 1e3:.1f}/"
                f"{self.tpot_p99 * 1e3:.1f}ms "
                f"SLO {self.slo_met}/{self.num_requests} "
                f"({self.goodput_rps:.2f} req/s)")


def per_class_reports(records: Sequence, total_time: float
                      ) -> List[ClassReport]:
    """Group records by SLO class (highest priority first, then name)."""
    groups: dict = {}
    for rec in records:
        groups.setdefault(rec.slo_class, []).append(rec)
    out: List[ClassReport] = []
    for slo in sorted(groups, key=lambda s: (-s.priority, s.name)):
        recs = groups[slo]
        ttfts = [r.ttft for r in recs]
        tpots = [r.tpot for r in recs if r.gen_len > 1]
        met = sum(1 for r in recs if slo_met(r))
        out.append(ClassReport(
            name=slo.name, priority=slo.priority, num_requests=len(recs),
            ttft_mean=sum(ttfts) / len(ttfts) if ttfts else 0.0,
            ttft_p50=p50(ttfts), ttft_p95=p95(ttfts), ttft_p99=p99(ttfts),
            tpot_mean=sum(tpots) / len(tpots) if tpots else 0.0,
            tpot_p50=p50(tpots), tpot_p95=p95(tpots), tpot_p99=p99(tpots),
            slo_met=met,
            goodput_rps=met / total_time if total_time > 0 else 0.0))
    return out


def request_metrics(records: Sequence, total_time: float) -> dict:
    """The latency/goodput block of a ``SimulationReport``, computed one
    way for every exact simulator (colocated and disagg ``**`` this dict
    into the report constructor)."""
    ttfts = [r.ttft for r in records]
    tpots = [r.tpot for r in records if r.gen_len > 1]
    e2es = [r.e2e for r in records]
    met = sum(1 for r in records if slo_met(r))
    return dict(
        ttft_mean=sum(ttfts) / len(ttfts) if ttfts else 0.0,
        ttft_p50=p50(ttfts), ttft_p95=p95(ttfts), ttft_p99=p99(ttfts),
        tpot_mean=sum(tpots) / len(tpots) if tpots else 0.0,
        tpot_p50=p50(tpots), tpot_p95=p95(tpots), tpot_p99=p99(tpots),
        latency_p95=p95(e2es),
        goodput_rps=met / total_time if total_time > 0 else 0.0,
        class_reports=per_class_reports(records, total_time))


@dataclasses.dataclass
class WindowReport:
    """One time window's slice of a simulation — the unit of the
    TTFT/TPOT/goodput *timeline* a non-stationary run is judged by.

    Arrivals are bucketed by arrival time; latency percentiles and
    goodput cover the requests that FINISHED inside the window (the
    service the operator observed during it).  Unfinished and
    admission-rejected requests appear in ``arrivals``/``rejected``
    only."""

    start: float
    end: float
    arrivals: int                 # requests arriving in [start, end)
    finished: int                 # requests finishing in [start, end)
    rejected: int                 # admission-control drops arriving here
    slo_met: int
    goodput_rps: float            # slo_met / window seconds
    ttft_mean: float
    ttft_p95: float
    tpot_p95: float
    arrival_rate: float           # arrivals / window seconds

    def summary(self) -> str:
        return (f"[{self.start:8.1f}-{self.end:8.1f}s] "
                f"in={self.arrivals} ({self.arrival_rate:.2f}/s) "
                f"out={self.finished} "
                f"TTFT p95={self.ttft_p95 * 1e3:.0f}ms "
                f"TPOT p95={self.tpot_p95 * 1e3:.1f}ms "
                f"goodput={self.goodput_rps:.2f}req/s"
                + (f" rejected={self.rejected}" if self.rejected else ""))


def windowed_metrics(records: Sequence, window_s: Optional[float] = None,
                     boundaries: Optional[Sequence[float]] = None,
                     horizon: Optional[float] = None) -> List[WindowReport]:
    """Slice a run's records into a per-window metric timeline.

    Pass EITHER ``window_s`` (uniform windows from 0) or explicit
    ``boundaries`` (window start times, first must be 0 — e.g. the epoch
    boundaries of a dynamic plan schedule).  ``horizon`` extends the
    last window's end (default: the latest arrival/finish observed).
    """
    if (window_s is None) == (boundaries is None):
        raise ValueError("pass exactly one of window_s / boundaries")
    last = max([max(r.arrival, r.finish_time) for r in records],
               default=0.0)
    horizon = max(horizon if horizon is not None else 0.0, last)
    if window_s is not None:
        if window_s <= 0:
            raise ValueError(f"window_s must be positive, got {window_s}")
        n = max(1, int(math.ceil(horizon / window_s - 1e-12)))
        edges = [i * window_s for i in range(n + 1)]
    else:
        edges = list(boundaries)
        if not edges or edges[0] != 0.0:
            raise ValueError(f"boundaries must start at 0, got {edges!r}")
        if any(b >= a for a, b in zip(edges[1:], edges)):
            raise ValueError(f"boundaries must be strictly increasing, "
                             f"got {edges!r}")
        edges.append(max(horizon, edges[-1] + 1e-9))
    out: List[WindowReport] = []
    for start, end in zip(edges, edges[1:]):
        is_last = end == edges[-1]
        arrived = [r for r in records
                   if start <= r.arrival and (r.arrival < end or is_last)]
        done = [r for r in records if r.finish_time > 0.0
                and start <= r.finish_time
                and (r.finish_time < end or is_last)]
        ttfts = [r.ttft for r in done]
        tpots = [r.tpot for r in done if r.gen_len > 1]
        met = sum(1 for r in done if slo_met(r))
        span = end - start
        out.append(WindowReport(
            start=start, end=end, arrivals=len(arrived),
            finished=len(done),
            rejected=sum(1 for r in arrived
                         if getattr(r, "rejected", False)),
            slo_met=met,
            goodput_rps=met / span if span > 0 else 0.0,
            ttft_mean=sum(ttfts) / len(ttfts) if ttfts else 0.0,
            ttft_p95=p95(ttfts), tpot_p95=p95(tpots),
            arrival_rate=len(arrived) / span if span > 0 else 0.0))
    return out


@dataclasses.dataclass
class ResilienceReport:
    """Outcome of one faulted run (or an ensemble aggregate) — what a
    plan's service looked like while the cluster was degraded.

    ``goodput_rps`` is the WHOLE faulted run's SLO goodput (the
    ``degraded_goodput`` search objective ranks on it: resilience is
    how much good service survives the fault draw, not only inside the
    outage windows); the window-split fields compare service during vs
    outside merged fault windows.  For an ensemble aggregate
    (``ensemble_size > 1``) counts are summed across members and
    rates/percentiles are member means.
    """

    availability: float           # 1 - down replica-seconds / total
    requests_total: int
    requests_finished: int
    requests_dropped: int         # never finished (e.g. stuck on a dead
                                  # replica with no survivor to take them)
    requests_requeued: int        # fault-induced KV losses re-queued
    degraded_seconds: float       # merged fault-window time
    goodput_rps: float            # SLO-met / s over the whole faulted run
    degraded_window_goodput_rps: float
    nominal_window_goodput_rps: float
    ttft_p95_degraded: float      # requests finishing inside fault windows
    ttft_p95_nominal: float
    tpot_p95_degraded: float
    tpot_p95_nominal: float
    ensemble_size: int = 1

    def summary(self) -> str:
        return (f"avail={self.availability:.3f} "
                f"goodput={self.goodput_rps:.2f}req/s "
                f"(degraded-window "
                f"{self.degraded_window_goodput_rps:.2f}, nominal "
                f"{self.nominal_window_goodput_rps:.2f}) "
                f"requeued={self.requests_requeued} "
                f"dropped={self.requests_dropped} "
                f"[x{self.ensemble_size}]")


@dataclasses.dataclass
class SimulationReport:
    """Per-plan simulation outcome (the paper's 'comprehensive evaluation')."""

    plan_label: str
    e2e_latency: float            # seconds to drain the trace
    total_energy: float           # joules across the whole cluster
    ttft_mean: float
    ttft_p95: float
    tpot_mean: float
    tpot_p95: float
    latency_p95: float            # per-request e2e P95
    throughput_tok_s: float
    mfu: float
    mbu: float
    iterations: int
    preemptions: int              # total evictions (sacrifices + swaps)
    peak_kv_tokens: int
    peak_batch: int
    feasible: bool = True
    records: Optional[list] = None
    # latency tails beyond the paper's p95
    ttft_p50: float = 0.0
    ttft_p99: float = 0.0
    tpot_p50: float = 0.0
    tpot_p99: float = 0.0
    # preemption-mechanism split: sacrifices recompute, swaps round-trip
    # the KV over the host link (kv_swap_s) — distinguishable in output
    swap_outs: int = 0
    swap_ins: int = 0
    kv_swap_s: float = 0.0
    kv_refetch_s: float = 0.0     # disagg decode re-fetch delay total
    # multi-tenant SLO outcome
    goodput_rps: float = 0.0      # requests meeting their class SLO / s
    class_reports: Optional[List[ClassReport]] = None
    # fault-injection outcome: set only when the run (or an ensemble of
    # re-simulations) carried a non-empty FaultSchedule
    resilience: Optional[ResilienceReport] = None
    # memory-threshold admission control (BatchingPolicy.admission_*)
    admission_rejected: int = 0   # requests dropped at the watermark
    admission_deferred: int = 0   # unique requests held at the watermark
    # per-window metric timeline (simulate(window_s=...) or a dynamic
    # run's epoch boundaries) — list of WindowReport
    windows: Optional[List[WindowReport]] = None
    # epoch-gated re-planning outcome (core/dynamic.ReconfigReport):
    # itemized reconfiguration cost of a dynamic plan schedule
    reconfig: Optional[object] = None

    @classmethod
    def infeasible(cls, plan_label: str) -> "SimulationReport":
        """The canonical 'this plan cannot run' report (ranked last by
        every minimizing objective)."""
        return cls(
            plan_label=plan_label, e2e_latency=float("inf"),
            total_energy=float("inf"), ttft_mean=0, ttft_p95=0,
            tpot_mean=0, tpot_p95=0, latency_p95=0, throughput_tok_s=0,
            mfu=0, mbu=0, iterations=0, preemptions=0, peak_kv_tokens=0,
            peak_batch=0, feasible=False)

    @property
    def sacrifices(self) -> int:
        """Evictions served by recompute (preemptions minus swap-outs)."""
        return self.preemptions - self.swap_outs

    def summary(self) -> str:
        line = (f"{self.plan_label}: e2e={self.e2e_latency:.2f}s "
                f"energy={self.total_energy / 1e3:.2f}kJ "
                f"TTFT={self.ttft_mean * 1e3:.1f}ms "
                f"TPOT={self.tpot_mean * 1e3:.2f}ms "
                f"MFU={self.mfu:.2%} MBU={self.mbu:.2%} "
                f"preempt={self.preemptions}")
        if self.swap_outs:
            line += (f" (swap={self.swap_outs}, "
                     f"{self.kv_swap_s:.2f}s on host link)")
        if self.kv_refetch_s > 0:
            line += f" refetch={self.kv_refetch_s:.2f}s"
        if self.goodput_rps > 0:
            line += f" goodput={self.goodput_rps:.2f}req/s"
        if self.admission_rejected or self.admission_deferred:
            line += (f" admission(rej={self.admission_rejected}, "
                     f"defer={self.admission_deferred})")
        return line

    def __str__(self) -> str:
        if not self.feasible:
            return f"{self.plan_label}: INFEASIBLE"
        lines = [self.summary(),
                 (f"  TTFT p50/p95/p99 = {self.ttft_p50 * 1e3:.1f}/"
                  f"{self.ttft_p95 * 1e3:.1f}/{self.ttft_p99 * 1e3:.1f} ms"),
                 (f"  TPOT p50/p95/p99 = {self.tpot_p50 * 1e3:.2f}/"
                  f"{self.tpot_p95 * 1e3:.2f}/{self.tpot_p99 * 1e3:.2f} ms")]
        for cr in self.class_reports or ():
            lines.append("  " + cr.summary())
        if self.resilience is not None:
            lines.append("  resilience: " + self.resilience.summary())
        if self.reconfig is not None:
            lines.append("  reconfig: " + self.reconfig.summary())
        for w in self.windows or ():
            lines.append("  " + w.summary())
        return "\n".join(lines)
