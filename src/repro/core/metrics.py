"""Serving metrics shared by every simulation path.

``SimulationReport`` is the per-plan outcome both the colocated and the
disaggregated simulators emit (so one objective ranks both families), and
``percentile`` is the rank-order estimator the paper's P95 numbers use.
Promoted out of ``simulator.py`` so the disagg subsystem no longer
imports private helpers or re-builds the infeasible report by hand.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional


def percentile(xs: List[float], q: float) -> float:
    """Rank-order percentile (no interpolation): the smallest sample with
    at least ``q`` of the mass at or below it.  Returns 0.0 when empty."""
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, int(math.ceil(q * len(s))) - 1)]


def p95(xs: List[float]) -> float:
    return percentile(xs, 0.95)


@dataclasses.dataclass
class SimulationReport:
    """Per-plan simulation outcome (the paper's 'comprehensive evaluation')."""

    plan_label: str
    e2e_latency: float            # seconds to drain the trace
    total_energy: float           # joules across the whole cluster
    ttft_mean: float
    ttft_p95: float
    tpot_mean: float
    tpot_p95: float
    latency_p95: float            # per-request e2e P95
    throughput_tok_s: float
    mfu: float
    mbu: float
    iterations: int
    preemptions: int
    peak_kv_tokens: int
    peak_batch: int
    feasible: bool = True
    records: Optional[list] = None

    @classmethod
    def infeasible(cls, plan_label: str) -> "SimulationReport":
        """The canonical 'this plan cannot run' report (ranked last by
        every minimizing objective)."""
        return cls(
            plan_label=plan_label, e2e_latency=float("inf"),
            total_energy=float("inf"), ttft_mean=0, ttft_p95=0,
            tpot_mean=0, tpot_p95=0, latency_p95=0, throughput_tok_s=0,
            mfu=0, mbu=0, iterations=0, preemptions=0, peak_kv_tokens=0,
            peak_batch=0, feasible=False)

    def summary(self) -> str:
        return (f"{self.plan_label}: e2e={self.e2e_latency:.2f}s "
                f"energy={self.total_energy / 1e3:.2f}kJ "
                f"TTFT={self.ttft_mean * 1e3:.1f}ms "
                f"TPOT={self.tpot_mean * 1e3:.2f}ms "
                f"MFU={self.mfu:.2%} MBU={self.mbu:.2%} "
                f"preempt={self.preemptions}")
