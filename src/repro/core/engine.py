"""Event-driven simulation core — one engine for every serving topology.

The dynamism-aware simulation that used to live in three divergent
hand-rolled loops (``BatchingModule._run_continuous``, ``_run_static``,
and the disaggregated simulator's coupled two-pool dance) is expressed
here once, as a global-clock discrete-event machine:

  * a single event heap orders *deliveries* (a request arriving at a
    replica: a routed admission, a finished KV handoff, a re-fetch
    return) and *iteration ends* (a replica's batch completing) across
    every replica of every pool;
  * each replica is an actor whose batch-construction, admission and
    preemption logic comes from a ``SchedulerPolicy`` — continuous
    batching (with chunked prefill and the decode-only pool role) and
    static batching are policy variants of one actor lifecycle, not
    separate loops;
  * a ``SharedLink`` resource serializes cross-pool KV transfers through
    a FIFO wire so simultaneous prefill completions contend for the
    min-bandwidth link instead of transferring independently;
  * a ``StepCostCache`` memoizes the (workload -> time, energy) cost
    boundary per plan, so identical iterations recurring across the
    event stream are priced once.

Single-replica colocated simulation through the engine is numerically
identical to the deleted per-replica loops (frozen goldens in
tests/test_engine_golden.py): a replica's event chain performs exactly
the old loop's arithmetic; the heap only interleaves independent chains.

Extension points:

  * subclass ``SchedulerPolicy`` (``admit`` / ``build`` / ``apply``) to
    model a new batching discipline — priority scheduling, fairness
    quanta, speculative-decode steps — and pass it anywhere a
    ``BatchingPolicy`` config is accepted today;
  * subclass ``PreemptionPolicy`` (``select`` / ``evict``) to model a
    new KV-overflow response.  The built-in menu crosses two mechanisms
    — ``sacrifice`` (drop the victim's KV and recompute, the paper's
    default) and ``swap`` (park the KV on the host over a PCIe-class
    link and restore it later, progress preserved) — with two victim
    orders — ``recent-first`` (LIFO, the paper's rule) and
    ``lowest-priority-first`` (evict the cheapest SLO class first).
    Any scheduler composes with any preemption policy; in the disagg
    decode role the ``on_preempt`` re-prefill coupling fires only for
    sacrifice (a swapped victim's KV never left the node).
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .batching import (BatchingPolicy, BatchingResult, RefetchDelay,
                       RequestRecord, StepCost, SwapCost)
from .ir import Workload
from .trace import Request

# Event priority classes at equal timestamps: deliveries must land in a
# replica's pending queue before an iteration boundary at the same time
# inspects it (legacy semantics: admission admits ``arrival <= now``).
# Fault transitions (fail-stop / repair, core/faults.py) fire before
# both, so a delivery at the instant of a failure already sees the
# replica down and reroutes to a survivor.
_PRIO_FAULT = 0
_PRIO_EPOCH = 1
_PRIO_DELIVER = 2
_PRIO_ITER_END = 3


# ---------------------------------------------------------------------------
# step-cost memoization
# ---------------------------------------------------------------------------

# A day-long trace at ~10 req/s with per-request workload churn produces
# on the order of 10^5 distinct workload signatures per plan family; the
# default bound comfortably holds several searches' worth of tables while
# capping worst-case memory at a few hundred MB (entries are small
# tuples).  Override per cache/store when profiling long traces.
DEFAULT_COST_CACHE_SIZE = 200_000


class _CostTable(OrderedDict):
    """Bounded LRU map from ``Workload.signature()`` to cost entries.

    Plain ``OrderedDict`` with an eviction counter: lookups that hit
    refresh recency, inserts past ``maxsize`` evict the least recently
    used entry.  Shared by every ``StepCostCache`` view onto the same
    plan-fingerprint bucket of a ``SharedCostStore``.
    """

    def __init__(self, maxsize: int = DEFAULT_COST_CACHE_SIZE):
        super().__init__()
        self.maxsize = maxsize
        self.evictions = 0

    def lookup(self, key: tuple) -> Optional[tuple]:
        ent = self.get(key)
        if ent is not None:
            self.move_to_end(key)
        return ent

    def store(self, key: tuple, ent: tuple) -> None:
        self[key] = ent
        self.move_to_end(key)
        while len(self) > self.maxsize:
            self.popitem(last=False)
            self.evictions += 1


class StepCostCache:
    """Memoized (time, energy) lookups on the engine's cost boundary.

    Keyed by ``Workload.signature()``.  The wrapped callback may tally
    per-call FLOP/byte increments on its owner (``PlanSimulator``'s
    ``_last_inc``); the cache stores that increment with the hit entry so
    utilization accounting can be replayed in deterministic replica order
    after the run — identical whether or not a workload hit the cache.

    The backing ``table`` may be private (default) or a ``_CostTable``
    handed in by a ``SharedCostStore``, in which case entries priced by
    one simulator are visible to every later simulator with the same
    cost-model fingerprint.  Hit/miss counters are always per-view, so
    ``stats()`` still describes *this* run; ``entries``/``evictions``
    describe the backing table.
    """

    def __init__(self, step_cost: StepCost, owner=None,
                 maxsize: int = DEFAULT_COST_CACHE_SIZE,
                 table: Optional[_CostTable] = None):
        self.step_cost = step_cost
        self.owner = owner
        self.table: _CostTable = table if table is not None \
            else _CostTable(maxsize)
        self.hits = 0
        self.misses = 0

    def stats(self) -> Dict[str, int]:
        """Hit/miss counters for cost-reuse observability (reported by
        the search as per-plan aggregates and by bench_core.py)."""
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self.table),
                "evictions": self.table.evictions}

    def reset_stats(self) -> None:
        self.hits = 0
        self.misses = 0

    def cost(self, w: Workload) -> tuple:
        """(time_s, energy_j, (flops_inc, bytes_inc)) for one iteration."""
        key = w.signature()
        ent = self.table.lookup(key)
        if ent is None:
            t, e = self.step_cost(w)
            inc = getattr(self.owner, "_last_inc", (0.0, 0.0)) \
                if self.owner is not None else (0.0, 0.0)
            ent = (t, e, inc)
            self.table.store(key, ent)
            self.misses += 1
        else:
            self.hits += 1
        return ent


class SharedCostStore:
    """Cross-plan step-cost store, keyed by cost-model fingerprint.

    Candidate plans in a search overwhelmingly share per-stage schemes —
    e.g. every ``model_dp`` width of one layout prices iterations
    identically — so a search-scoped store lets the thousands of
    identical decode-step workloads recurring across sibling candidates
    be priced once per search instead of once per plan.  Two levels keep
    the hot path cheap: a simulator resolves its fingerprint to a
    ``_CostTable`` once, then per-step lookups hash only the workload
    signature.

    Fingerprints (``core.simulator.cost_fingerprint``) cover everything
    ``iteration_cost`` reads — scheme layout, quant format, cluster
    device/network specs, profile-backend knobs — so plans that differ
    in any cost-relevant way can never share a bucket (tested
    adversarially in tests/test_halving.py).

    With ``search(jobs=N)`` the store is pre-seeded in the parent (by
    fluid screening probes and any earlier runs) and each forked worker
    inherits that snapshot copy-on-write; entries priced inside a worker
    stay in the worker.  Costs are deterministic functions of the
    fingerprint+signature key, so sharing never changes results — only
    how often ``step_cost`` is re-run.
    """

    def __init__(self, maxsize: int = DEFAULT_COST_CACHE_SIZE):
        self.maxsize = maxsize
        self.tables: Dict[tuple, _CostTable] = {}

    def table(self, fingerprint: tuple) -> _CostTable:
        tab = self.tables.get(fingerprint)
        if tab is None:
            tab = self.tables[fingerprint] = _CostTable(self.maxsize)
        return tab

    def cache(self, fingerprint: tuple, step_cost: StepCost,
              owner=None) -> StepCostCache:
        """A per-run ``StepCostCache`` view onto this store's table for
        ``fingerprint`` (fresh hit/miss counters, shared entries)."""
        return StepCostCache(step_cost, owner=owner,
                             table=self.table(fingerprint))

    def stats(self) -> Dict[str, int]:
        return {"tables": len(self.tables),
                "entries": sum(len(t) for t in self.tables.values()),
                "evictions": sum(t.evictions for t in self.tables.values())}


# ---------------------------------------------------------------------------
# shared cross-pool wire
# ---------------------------------------------------------------------------

class SharedLink:
    """FIFO congestion model of the cross-pool KV wire.

    Transfers claim the wire in prefill-completion order.  A layerwise
    transfer streamed all but its last chunk behind the prefill, so its
    wire occupancy window *ends*, uncontended, at
    ``finish + delay_s`` — modeled as a contiguous ``wire_s`` window
    starting ``stream_lead_s`` before the prefill completed.  When the
    wire is still busy at that start time, the window (and the decode
    pool's admission) slides later: simultaneous completions queue.

    ``congestion=False`` reproduces the independent-per-request transfer
    model exactly (so does any link fast enough never to queue).

    ``degradation`` (optional, ``time -> factor >= 1``) models a
    fault-injected bandwidth drop: the wire/delay components of a
    transfer starting while the factor exceeds 1 stretch by it.  The
    default (None) is arithmetically identical to factor 1.0.
    """

    def __init__(self, congestion: bool = True,
                 degradation: Optional[Callable[[float], float]] = None):
        self.congestion = congestion
        self.degradation = degradation
        self.free_at = 0.0
        self.queued_s = 0.0          # total queuing delay added by contention
        self.degraded_s = 0.0        # extra wire time added by degradation

    def transfer(self, finish_time: float, est) -> float:
        """Completion time of a transfer whose prefill ended at
        ``finish_time``, with per-request costs ``est``
        (a ``TransferEstimate``)."""
        f = self.degradation(finish_time) if self.degradation else 1.0
        independent = finish_time + est.delay_s * f
        if not self.congestion:
            self.degraded_s += est.delay_s * (f - 1.0)
            return independent
        self.degraded_s += est.wire_s * (f - 1.0)
        start = max(finish_time - est.stream_lead_s * f, self.free_at)
        done = start + est.wire_s * f
        self.free_at = done
        self.queued_s += max(0.0, done - independent)
        return done


# ---------------------------------------------------------------------------
# active-request state (moved from the legacy BatchingModule)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Active:
    req: Request
    admitted_at: float
    order: int                    # admission order (for preemption LIFO)
    prefill_done: int = 0         # prompt tokens already processed
    generated: int = 0            # output tokens produced
    first_token_time: Optional[float] = None

    @property
    def kv_tokens(self) -> int:
        return self.prefill_done + self.generated

    @property
    def kv_reserved(self) -> int:
        """Admission-time reservation: an admitted request's prompt KV is
        committed even before its prefill runs (prevents admission storms
        that thrash prefill/evict cycles and starve decodes)."""
        return max(self.req.context_len, self.kv_tokens)

    @property
    def prefill_remaining(self) -> int:
        return self.req.context_len - self.prefill_done

    @property
    def done(self) -> bool:
        return self.generated >= self.req.gen_len

    def reset(self) -> None:
        self.prefill_done = 0
        self.generated = 0
        self.first_token_time = None


# ---------------------------------------------------------------------------
# preemption policies (victim selection x eviction mechanism)
# ---------------------------------------------------------------------------

class PreemptionPolicy:
    """What happens when a replica's KV memory overflows.

    Two orthogonal axes, each a subclass hook:

      * ``select(A)`` — WHICH active request to evict.  ``recent``
        (default) is the paper's LIFO rule: the most recently admitted
        request goes first.  ``priority`` evicts the lowest
        ``SLOClass.priority`` first (most-recent within a class), so a
        latency-sensitive tenant survives pressure from a batchy one.
      * ``evict(A, victim, now)`` — HOW to free the memory (the
        mechanism subclasses implement).

    ``overflow`` preserves the engine's two invariants verbatim: evict
    until KV fits, and never evict the last active request (a single
    sequence whose prompt+generation exceeds capacity must run to
    completion — evicting it would requeue-loop forever).
    """

    mechanism = "abstract"

    def __init__(self, victim: str = "recent"):
        victim = _VICTIM_ALIASES.get(victim, victim)
        if victim not in ("recent", "priority"):
            raise ValueError(
                f"unknown victim order {victim!r}; known: recent-first, "
                f"lowest-priority-first")
        self.victim = victim

    def select(self, A: "Replica") -> "_Active":
        if self.victim == "priority":
            return max(A.active,
                       key=lambda a: (-a.req.slo_class.priority, a.order))
        return max(A.active, key=lambda a: a.order)

    def evict(self, A: "Replica", victim: "_Active", now: float) -> None:
        raise NotImplementedError

    def overflow(self, A: "Replica", now: float) -> None:
        while A.kv_used() > A.capacity and len(A.active) > 1:
            victim = self.select(A)
            A.active.remove(victim)
            A.records[victim.req.rid].preemptions += 1
            A.preemptions += 1
            self.evict(A, victim, now)

    def label(self) -> str:
        return f"{self.mechanism}/{self.victim}"


class SacrificePolicy(PreemptionPolicy):
    """Drop the victim's KV and recompute from scratch (paper §3.3's
    only mode, and still the default).  In the disagg decode role the
    shipped prompt KV is gone, so the victim must re-fetch it — the
    ``on_preempt`` re-prefill coupling fires here and ONLY here."""

    mechanism = "sacrifice"

    def evict(self, A: "Replica", victim: "_Active", now: float) -> None:
        victim.reset()
        if A.role == "decode":
            # the shipped prompt KV was dropped; the victim only
            # becomes admissible again after re-fetching it
            A.refetch(victim.req, now)
        else:
            A.pending.insert(0, victim.req)


class SwapPolicy(PreemptionPolicy):
    """Move the victim's KV to host memory and bring it back later —
    progress preserved, no recompute.  The victim re-enters the pending
    queue ``delay`` seconds out, where ``delay`` is the host-link round
    trip (swap-out now + swap-in before resumption) priced by the pool's
    ``swap_cost`` callback over a PCIe-class ``NetworkLevel``; on
    re-admission its prefill/decode counters are restored from the
    parked snapshot.  Works identically in the decode role: the KV never
    left the node, so no re-prefill and no wire re-ship."""

    mechanism = "swap"

    def evict(self, A: "Replica", victim: "_Active", now: float) -> None:
        delay, energy = A.pool.swap_cost(victim.req, victim.kv_tokens)
        delay = max(0.0, delay)
        rec = A.records[victim.req.rid]
        rec.swaps += 1
        rec.swap_s += delay
        A.swap_outs += 1
        A.kv_swap_s += delay
        A.energy += energy
        A.swapped[victim.req.rid] = (victim.prefill_done, victim.generated,
                                     victim.first_token_time)
        ready = now + delay
        re_req = dataclasses.replace(victim.req, arrival=ready)
        idx = 0
        while (idx < len(A.pending)
               and A.pending[idx].arrival <= ready):
            idx += 1
        A.pending.insert(idx, re_req)


_VICTIM_ALIASES = {
    "recent-first": "recent", "lifo": "recent",
    "lowest-priority-first": "priority", "lowest-priority": "priority",
}
_MECHANISMS = {"sacrifice": SacrificePolicy, "swap": SwapPolicy}


def make_preemption(spec) -> PreemptionPolicy:
    """Resolve the ``preemption=`` plumbing: None (the default,
    sacrifice + recent-first), a ``PreemptionPolicy`` instance, or a
    menu string ``"<mechanism>[/<victim>]"`` — e.g. ``"swap"``,
    ``"sacrifice/lowest-priority-first"``."""
    if spec is None:
        return SacrificePolicy()
    if isinstance(spec, PreemptionPolicy):
        return spec
    mechanism, _, victim = str(spec).partition("/")
    if mechanism not in _MECHANISMS:
        raise ValueError(f"unknown preemption mechanism {mechanism!r}; "
                         f"known: {sorted(_MECHANISMS)}")
    return _MECHANISMS[mechanism](victim or "recent")


# ---------------------------------------------------------------------------
# scheduler policies
# ---------------------------------------------------------------------------

class SchedulerPolicy:
    """Batch construction + admission + preemption for one replica actor.

    Subclass hooks (all operate on a ``Replica``'s state):
      * ``admit(A)``  — move arrived pending requests into ``A.active``
                        (may advance ``A.now`` for clock-jumping modes);
      * ``build(A)``  — assemble one iteration's batch, returning
                        ``(iter_prefills, iter_decodes, workload)``;
      * ``apply(A, prefills, decodes, dur)`` — apply the iteration's
                        effects at ``A.now`` (completions, fast-forward,
                        preemption).
    """

    def __init__(self, cfg: BatchingPolicy):
        self.cfg = cfg

    def admit(self, A: "Replica") -> None:
        raise NotImplementedError

    def build(self, A: "Replica"):
        raise NotImplementedError

    def apply(self, A: "Replica", prefills, decodes, dur: float) -> None:
        raise NotImplementedError


class ContinuousScheduler(SchedulerPolicy):
    """Iteration-level continuous batching (paper §3.3): greedy
    memory-gated admission, contiguous or Sarathi-chunked prefill, LIFO
    preemption on KV overflow, fast-forward over uneventful decode runs.
    ``role="decode"`` models the decode pool of a disaggregated
    deployment (admission materializes the shipped prompt KV)."""

    # -- admission (greedy, memory-gated) --
    # headroom of one decode token per active sequence prevents the
    # admit -> prefill -> immediately-evict livelock
    def admit(self, A: "Replica") -> None:
        cfg = self.cfg
        while A.pending and A.pending[0].arrival <= A.now:
            # a swap-parked victim's demand is its full parked KV
            # (prompt + generated so far), not just its prompt
            saved = A.swapped.get(A.pending[0].rid) if A.swapped else None
            demand = (saved[0] + saved[1]) if saved is not None \
                else A.pending[0].context_len
            # memory-threshold admission control: when projected KV
            # occupancy would cross the watermark, defer (hold in queue)
            # or reject (drop, counted) the head instead of admitting
            # into near-certain preemption.  A busy watermark never
            # starves: the liveness rule below still admits onto an
            # idle replica, so every deferred request eventually runs.
            wm = cfg.admission_watermark
            if wm is not None and A.active:
                projected = A.kv_reserved() + demand
                if projected > wm * A.capacity:
                    req = A.pending[0]
                    if cfg.admission_mode == "reject":
                        A.pending.pop(0)
                        rec = A.records[req.rid]
                        rec.rejected = True
                        rec.finish_time = 0.0
                        A.admission_rejected += 1
                        continue
                    if req.rid not in A.deferred_rids:
                        A.deferred_rids.add(req.rid)
                        A.admission_deferred += 1
                    break
            headroom = len(A.active) + 1
            cap_ok = (A.kv_reserved() + demand
                      + headroom <= A.capacity)
            # liveness: an idle engine always admits its head request,
            # even one whose prompt alone exceeds KV capacity (it runs
            # solo and may overshoot — dual of never-evict-last)
            if not A.active:
                cap_ok = True
            seq_ok = len(A.active) < A.max_sequences
            bs_ok = (cfg.max_batch_size is None
                     or len(A.active) < cfg.max_batch_size)
            if not (cap_ok and seq_ok and bs_ok):
                break
            req = A.pending.pop(0)
            a = _Active(req=req, admitted_at=A.now, order=A.order)
            A.order += 1
            if saved is not None:
                # swap-in: restore the parked progress snapshot — no
                # recompute, no first-token re-stamp, and (enc-dec) no
                # re-run of the encoder
                del A.swapped[req.rid]
                a.prefill_done, a.generated, a.first_token_time = saved
                A.swap_ins += 1
                A.active.append(a)
                continue
            if A.role == "decode":
                # prompt KV arrived from the prefill pool; the first
                # token was already emitted there.  Standalone records
                # stamp first-token at FIRST admission only (a re-fetch
                # after preemption does not re-emit the first token); a
                # coupled simulation overwrites it with the prefill
                # pool's timestamp.
                a.prefill_done = req.context_len
                a.generated = 1
                a.first_token_time = A.now
                rec = A.records[req.rid]
                if rec.preemptions == 0:
                    rec.first_token_time = A.now
                if a.done:          # gen_len <= 1: nothing to decode
                    rec.finish_time = A.now
                    A.finish(req, rec, A.now)
                    continue
            A.active.append(a)
            A.new_admissions.append(a)

    def build(self, A: "Replica"):
        cfg = self.cfg
        prefills = [a for a in A.active if a.prefill_remaining > 0]
        decodes = [a for a in A.active if a.prefill_remaining == 0
                   and not a.done]
        chunk = cfg.chunked_prefill
        iter_prefills: List[Tuple[_Active, int]] = []
        budget = cfg.max_prefill_tokens
        for a in prefills:
            if budget <= 0:
                break
            take = min(a.prefill_remaining, budget)
            if chunk is not None:
                take = min(take, chunk)
            iter_prefills.append((a, take))
            budget -= take
            if chunk is None and budget <= 0:
                break
        # contiguous batching: prefill iterations exclude decodes;
        # chunked prefill mixes them (Sarathi-style).
        iter_decodes = decodes if (chunk is not None or not iter_prefills) \
            else []
        w = A.workload(iter_prefills, iter_decodes, A.new_admissions)
        A.new_admissions = []
        return iter_prefills, iter_decodes, w

    def apply(self, A: "Replica", iter_prefills, iter_decodes,
              dur: float) -> None:
        now = A.now
        notified = set()          # finish-callback dedup within this step
        for a, take in iter_prefills:
            a.prefill_done += take
            if a.prefill_remaining == 0:
                # prompt fully processed -> first token emitted
                a.generated = 1
                a.first_token_time = now
                rec = A.records[a.req.rid]
                rec.first_token_time = now
                if a.done:
                    rec.finish_time = now
                    notified.add(a.req.rid)
                    A.finish(a.req, rec, now)
        for a in iter_decodes:
            a.generated += 1
        # sample peak BEFORE completions release their KV: the true
        # peak includes each finishing request's final token
        A.peak_kv = max(A.peak_kv, A.kv_used())

        finished = [a for a in A.active if a.done]
        for a in finished:
            rec = A.records[a.req.rid]
            rec.finish_time = now
            if a.req.rid not in notified:
                A.finish(a.req, rec, now)
        A.active = [a for a in A.active if not a.done]

        # ---- fast-forward uneventful decode runs ----
        if (self.cfg.fast_forward and not iter_prefills and A.active
                and all(a.prefill_remaining == 0 for a in A.active)):
            steps = self._ff_steps(A, dur)
            if steps > 1:
                kv_lens = [a.kv_tokens for a in A.active]
                mid = [k + steps // 2 for k in kv_lens]
                w_mid = A.workload_decode(mid, len(A.active))
                d_mid, e_mid = A.cost(w_mid)
                scale = A.step_scale()
                if scale != 1.0:
                    # the whole run stays inside one straggler regime:
                    # _ff_steps is bounded by the next fault transition
                    d_mid *= scale
                    e_mid *= scale
                for a in A.active:
                    a.generated += steps
                # per-token times: uniform at d_mid
                A.now = now = now + d_mid * steps
                A.energy += e_mid * steps
                A.iters += steps
                # peak inside the run = KV total at the END of the run
                # (no arrival/completion/overflow can occur within it),
                # just before completions are removed
                A.peak_kv = max(A.peak_kv,
                                sum(kv_lens) + steps * len(A.active))
                finished = [a for a in A.active if a.done]
                for a in finished:
                    over = a.generated - a.req.gen_len
                    rec = A.records[a.req.rid]
                    rec.finish_time = now - d_mid * over
                    a.generated = a.req.gen_len
                    A.finish(a.req, rec, rec.finish_time)
                A.active = [a for a in A.active if not a.done]

        # ---- KV overflow -> the pool's PreemptionPolicy decides ----
        # (default: sacrifice + recent-first, the paper's §3.3 rule)
        A.pool.preemption.overflow(A, now)
        A.peak_kv = max(A.peak_kv, A.kv_used())

    def _ff_steps(self, A: "Replica", dur: float) -> int:
        """Max decode steps guaranteed uneventful (no completion,
        arrival — local pending OR in-flight engine delivery — or
        overflow)."""
        to_finish = min(a.req.gen_len - a.generated for a in A.active)
        kv = sum(a.kv_tokens for a in A.active)
        to_overflow = max(0, (A.capacity - kv)) // max(1, len(A.active))
        cap = self.cfg.fast_forward_cap
        steps = min(to_finish, to_overflow, cap)
        nxt = A.next_arrival_bound()
        if nxt is not None and dur > 0:
            to_arrival = int((nxt - A.now) / dur)
            steps = min(steps, max(0, to_arrival))
        return max(steps, 0)


class StaticScheduler(SchedulerPolicy):
    """Static batching (paper §2.3 strawman): admit a fixed batch, prefill
    it whole, decode until EVERY member finishes (the inefficiency the
    paper motivates against), only then admit the next batch.  Finished
    members keep their KV until the batch drains."""

    def admit(self, A: "Replica") -> None:
        if A.active or not A.pending:
            return
        bs = self.cfg.max_batch_size or 32
        batch: List[Request] = []
        kv = 0
        while (A.pending and len(batch) < bs
               and kv + A.pending[0].context_len <= A.capacity):
            r = A.pending.pop(0)
            batch.append(r)
            kv += r.context_len
        if not batch:
            # head prompt alone exceeds KV capacity: admit it solo and
            # let it overshoot (the continuous path's liveness rule —
            # refusing it would loop forever with no progress)
            batch.append(A.pending.pop(0))
        # static batching waits for the whole batch to assemble
        A.now = max(A.now, max(r.arrival for r in batch))
        acts = [_Active(req=r, admitted_at=A.now, order=j)
                for j, r in enumerate(batch)]
        A.active.extend(acts)
        A.new_admissions.extend(acts)
        A.peak_batch = max(A.peak_batch, len(batch))

    def build(self, A: "Replica"):
        prefills = [a for a in A.active if a.prefill_remaining > 0]
        if prefills:
            iter_prefills = [(a, a.prefill_remaining) for a in prefills]
            w = A.workload(iter_prefills, [], A.new_admissions)
            A.new_admissions = []
            return iter_prefills, [], w
        live = [a for a in A.active if not a.done]
        return [], live, A.workload_decode([a.kv_tokens for a in live],
                                           len(live))

    def apply(self, A: "Replica", iter_prefills, iter_decodes,
              dur: float) -> None:
        now = A.now
        if iter_prefills:
            for a, take in iter_prefills:
                a.prefill_done += take
                a.generated = 1
                rec = A.records[a.req.rid]
                rec.first_token_time = now
                if a.done:        # gen_len == 1: done at prefill end,
                    # not when the whole batch drains
                    rec.finish_time = now
                    A.finish(a.req, rec, now)
        else:
            for a in A.active:
                if not a.done:
                    a.generated += 1
                    if a.done:
                        rec = A.records[a.req.rid]
                        rec.finish_time = now
                        A.finish(a.req, rec, now)
        # finished members hold their KV until the batch drains
        A.peak_kv = max(A.peak_kv, sum(a.kv_tokens for a in A.active))
        if all(a.done for a in A.active):
            A.active = []


def make_policy(cfg: BatchingPolicy) -> SchedulerPolicy:
    if cfg.admission_watermark is not None:
        if not 0.0 < cfg.admission_watermark <= 1.0:
            raise ValueError(f"admission_watermark must be in (0, 1], "
                             f"got {cfg.admission_watermark}")
        if cfg.admission_mode not in ("defer", "reject"):
            raise ValueError(f"unknown admission_mode "
                             f"{cfg.admission_mode!r} (defer|reject)")
        if cfg.mode == "static":
            raise ValueError("admission_watermark requires continuous "
                             "batching (static admission is batch-gated)")
    if cfg.mode == "static":
        return StaticScheduler(cfg)
    if cfg.mode == "continuous":
        return ContinuousScheduler(cfg)
    raise ValueError(f"unknown batching mode {cfg.mode!r}")


# ---------------------------------------------------------------------------
# replica actor
# ---------------------------------------------------------------------------

class Replica:
    """One replica's batching state, advanced by engine events.

    The actor's lifecycle per iteration — admit, build, cost, schedule
    the iteration-end event, then (when it fires) apply effects and start
    the next iteration — performs exactly the arithmetic of the legacy
    per-replica loop; the policy object owns every mode-specific step.
    """

    def __init__(self, pool: "Pool", index: int,
                 requests: Sequence[Request]):
        self.pool = pool
        self.index = index
        self.pending: List[Request] = sorted(requests,
                                             key=lambda r: r.arrival)
        self.records: Dict[int, RequestRecord] = {
            r.rid: RequestRecord(r.rid, r.arrival, r.context_len, r.gen_len,
                                 slo_class=r.slo_class)
            for r in requests}
        self.shadow: set = set()      # rids of engine-internal jobs
        self.active: List[_Active] = []
        self.swapped: Dict[int, tuple] = {}   # rid -> parked progress
        self.new_admissions: List[_Active] = []
        self.now = 0.0
        self.busy = False
        self._busy_until: Optional[float] = None  # scheduled iteration end
        self._wake_at: Optional[float] = None   # pending idle-wake event
        self.failed = False           # fail-stopped (core/faults.py)
        self.fail_epoch = 0           # invalidates in-flight iteration ends
        self.order = 0
        self.iters = 0
        self.energy = 0.0
        self.preemptions = 0
        self.swap_outs = 0
        self.swap_ins = 0
        self.kv_swap_s = 0.0
        self.peak_kv = 0
        self.peak_batch = 0
        self.kv_refetch_s = 0.0
        self.admission_rejected = 0
        self.admission_deferred = 0
        self.deferred_rids: set = set()   # dedup for the deferred counter
        self.cost_calls: List[tuple] = []    # (flops_inc, bytes_inc)
        self._refetch_cache: Dict[int, float] = {}

    # -- config shortcuts --------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.pool.capacity

    @property
    def max_sequences(self) -> int:
        return self.pool.live_max_sequences()

    @property
    def role(self) -> str:
        return self.pool.role

    def kv_used(self) -> int:
        return sum(a.kv_tokens for a in self.active)

    def kv_reserved(self) -> int:
        return sum(a.kv_reserved for a in self.active)

    # -- cost boundary -----------------------------------------------------

    def cost(self, w: Workload) -> Tuple[float, float]:
        cache = self.pool.cache
        if cache is not None:
            t, e, inc = cache.cost(w)
            self.cost_calls.append(inc)
            return t, e
        return self.pool.step_cost(w)

    def step_scale(self) -> float:
        """Straggler slowdown factor at ``now`` — applied AFTER the cost
        lookup so degraded iterations never pollute the (fault-free)
        step-cost cache, and so fault-free runs stay bit-identical."""
        if not self.pool.stragglers:
            return 1.0
        return self.pool.slowdown(self, self.now)

    # -- event handlers ----------------------------------------------------

    def advance(self) -> None:
        """Run admissions and start the next iteration (or go idle)."""
        if self.busy or self.failed:
            return
        policy = self.pool.policy
        while True:
            policy.admit(self)
            if self.active:
                prefills, decodes, w = policy.build(self)
                dur, en = self.cost(w)
                scale = self.step_scale()
                if scale != 1.0:
                    dur *= scale
                    en *= scale
                self.energy += en
                self.iters += 1
                self.peak_batch = max(self.peak_batch,
                                      len(prefills) + len(decodes))
                self.busy = True
                self._busy_until = self.now + dur
                self.pool.engine.schedule(
                    self.now + dur, _PRIO_ITER_END, self.order,
                    lambda t, p=prefills, d=decodes, dd=dur,
                    ep=self.fail_epoch:
                    self.on_iter_end(t, p, d, dd, ep))
                return
            if self.pending:
                t = self.pending[0].arrival
                if t <= self.now:
                    # arrived but refused by the policy with an empty
                    # batch (no standard policy does this); jump to keep
                    # liveness rather than deadlock
                    self.now = t
                    continue
                # sleep until the next KNOWN arrival — committing the
                # iteration now would run past any delivery (a transfer,
                # a re-fetch return) landing in the skipped idle window,
                # so wake through the heap and let earlier events win
                if self._wake_at is None or self._wake_at > t:
                    self._wake_at = t
                    self.pool.engine.schedule(
                        t, _PRIO_ITER_END, self.order, self.on_wake)
                return
            return                      # idle; a delivery may wake us

    def on_wake(self, t: float) -> None:
        if self._wake_at is not None and self._wake_at <= t:
            self._wake_at = None
        if self.busy or self.failed:
            return                      # a delivery already woke us
        self.now = max(self.now, t)
        self.advance()

    def on_iter_end(self, now: float, prefills, decodes,
                    dur: float, epoch: Optional[int] = None) -> None:
        if epoch is not None and epoch != self.fail_epoch:
            return      # the iteration was aborted by a fail-stop
        self.busy = False
        self._busy_until = None
        self.now = now
        self.pool.policy.apply(self, prefills, decodes, dur)
        self.advance()

    # -- fault transitions (core/faults.py) --------------------------------

    def fail(self, now: float) -> None:
        """Fail-stop: the in-flight iteration and all KV (device AND
        host-parked) are lost.  Active and pending requests re-queue to
        surviving replicas through the pool's sacrifice/recompute path
        (graceful degradation); with no survivor they wait here for
        repair."""
        if self.failed:
            return
        self.failed = True
        self.fail_epoch += 1            # invalidates the in-flight step
        self.now = max(self.now, now)
        self.busy = False
        self._busy_until = None
        self._wake_at = None
        victims = self.active
        self.active = []
        self.swapped.clear()            # host-parked KV dies with the node
        pending = self.pending
        self.pending = []
        self.pool.on_replica_fail(self, victims, pending, now)

    def repair(self, now: float) -> None:
        """Return to service with an empty cache; any requests stranded
        here (no survivor existed at failure time) resume.  The clock
        only jumps to the repair time when there IS stranded work —
        an idle repaired replica must not inflate the run's makespan
        (later deliveries advance it through the heap as usual)."""
        if not self.failed:
            return
        self.failed = False
        self.pool.down -= 1
        if self.pending or self.active:
            self.now = max(self.now, now)
        self.advance()

    def deliver(self, req: Request, now: float) -> None:
        """A routed/transferred/re-fetched request becomes visible."""
        if self.failed:
            alt = self.pool.least_loaded_alive()
            if alt is not None:
                # reroute to a survivor, moving this request's record
                # (and shadow membership) so its history follows it
                rec = self.records.pop(req.rid, None)
                if rec is not None and req.rid not in alt.records:
                    alt.records[req.rid] = rec
                if req.rid in self.shadow:
                    self.shadow.discard(req.rid)
                    alt.shadow.add(req.rid)
                alt.deliver(req, now)
                return
            # no survivor: queue here and wait for repair
        if req.rid not in self.records:
            self.records[req.rid] = RequestRecord(
                req.rid, req.arrival, req.context_len, req.gen_len,
                slo_class=req.slo_class)
        idx = bisect.bisect_right([p.arrival for p in self.pending],
                                  req.arrival)
        self.pending.insert(idx, req)
        if not self.busy:
            self.advance()

    # -- coupling hooks ----------------------------------------------------

    def finish(self, req: Request, rec: RequestRecord, now: float) -> None:
        if self.pool.on_finish is not None:
            self.pool.on_finish(self, req, rec, now)

    def refetch(self, req: Request, now: float) -> None:
        """Decode-role preemption: the victim must re-materialize its
        prompt KV before re-admission."""
        if self.pool.on_preempt is not None:
            # engine-coupled: the prefill pool re-runs the prompt (real
            # occupancy) and the cache re-ships over the shared link;
            # the victim is parked until the engine re-delivers it
            self.pool.on_preempt(self, req, now)
            return
        # delay-mode: charge a per-request delay (the coupled KV-transfer
        # wire time, or a re-prefill estimate priced through step_cost)
        if req.rid not in self._refetch_cache:
            if self.pool.refetch_delay is not None:
                delay = max(0.0, self.pool.refetch_delay(req))
            else:
                w = Workload.from_batch(
                    [(req.context_len, req.context_len)], [],
                    self.pool.windows, batch_sequences=1)
                delay, _ = self.cost(w)
            self._refetch_cache[req.rid] = delay
        delay = self._refetch_cache[req.rid]
        self.records[req.rid].refetch_s += delay
        self.kv_refetch_s += delay
        ready = now + delay
        re_req = dataclasses.replace(req, arrival=ready)
        idx = 0
        while (idx < len(self.pending)
               and self.pending[idx].arrival <= ready):
            idx += 1
        self.pending.insert(idx, re_req)

    def next_arrival_bound(self) -> Optional[float]:
        """Earliest future work this replica could see — its own pending
        head, any in-flight engine delivery headed for this pool, or
        (in a coupled topology) the earliest upstream-pool event that
        could *spawn* a delivery (a transfer is only initiated when a
        prefill iteration ends, so no delivery can precede the upstream
        pool's next scheduled event).  ``now`` (disabling fast-forward)
        while a parked victim's return time is still unknown."""
        bounds = []
        if self.pending:
            bounds.append(self.pending[0].arrival)
        boundary_t = self.pool.engine.next_boundary(self.now)
        if boundary_t is not None:
            # never fast-forward across a world-change boundary: a fault
            # transition, straggler-window edge, or epoch re-planning
            # boundary changes this replica's world
            bounds.append(boundary_t)
        pool_bound = self.pool.incoming_bound()
        if pool_bound is not None:
            bounds.append(pool_bound)
        if self.pool.incoming_unknown > 0:
            bounds.append(self.now)
        up = self.pool.upstream
        if up is not None:
            up_bound = up.next_event_bound()
            if up_bound is not None:
                bounds.append(up_bound)
            if self.pool.on_preempt is not None:
                # a PEER replica's preemption can inject upstream work at
                # its own next iteration end
                peer = self.pool.next_event_bound(exclude=self)
                if peer is not None:
                    bounds.append(peer)
        return min(bounds) if bounds else None

    # -- workload builders (shared by every policy) ------------------------

    def workload(self, iter_prefills, iter_decodes,
                 newly_admitted) -> Workload:
        pool = self.pool
        chunks = [(take, a.prefill_done + take) for a, take in iter_prefills]
        kv_lens = [a.kv_tokens for a in iter_decodes]
        # decode role: the encoder already ran in the prefill pool — its
        # memory ships with the KV; only cross-attention reads remain here
        enc_tokens = sum(a.req.source_len for a in newly_admitted) \
            if pool.is_encdec and pool.role != "decode" else 0
        pre_src = [a.req.source_len for a, _ in iter_prefills] \
            if pool.is_encdec else ()
        dec_src = [a.req.source_len for a in iter_decodes] \
            if pool.is_encdec else ()
        n_seq = len(iter_prefills) + len(iter_decodes)
        return Workload.from_batch(chunks, kv_lens, pool.windows,
                                   batch_sequences=n_seq,
                                   encoder_tokens=enc_tokens,
                                   prefill_source=pre_src,
                                   decode_source=dec_src)

    def workload_decode(self, kv_lens: List[int], n_seq: int) -> Workload:
        return Workload.from_batch([], kv_lens, self.pool.windows,
                                   batch_sequences=n_seq)

    # -- result ------------------------------------------------------------

    @property
    def touched(self) -> bool:
        return bool(self.records) or self.iters > 0

    def result(self) -> BatchingResult:
        records = [rec for rid, rec in self.records.items()
                   if rid not in self.shadow]
        return BatchingResult(records=records, iterations=self.iters,
                              total_time=self.now,
                              total_energy=self.energy,
                              preemptions=self.preemptions,
                              peak_kv_tokens=self.peak_kv,
                              peak_batch=self.peak_batch,
                              kv_refetch_s=self.kv_refetch_s,
                              swap_outs=self.swap_outs,
                              swap_ins=self.swap_ins,
                              kv_swap_s=self.kv_swap_s,
                              admission_rejected=self.admission_rejected,
                              admission_deferred=self.admission_deferred)


# ---------------------------------------------------------------------------
# pools
# ---------------------------------------------------------------------------

class Pool:
    """A group of replicas sharing one scheduler policy, KV capacity and
    step-cost model (one pool for colocated serving; a prefill pool and a
    decode pool for disaggregated serving)."""

    def __init__(self, engine: "Engine", name: str,
                 buckets: Sequence[Sequence[Request]],
                 capacity: int, policy: BatchingPolicy,
                 cost, windows: Sequence = (None,),
                 max_sequences: int = 512, is_encdec: bool = False,
                 role: str = "both",
                 refetch_delay: Optional[RefetchDelay] = None,
                 on_finish: Optional[Callable] = None,
                 on_preempt: Optional[Callable] = None,
                 preemption=None,
                 swap_cost: Optional[SwapCost] = None):
        if capacity <= 0:
            raise ValueError("pool has no KV capacity — infeasible")
        if role not in ("both", "decode"):
            raise ValueError(f"unknown batching role {role!r}")
        if role == "decode" and policy.mode == "static":
            raise ValueError("decode role requires continuous batching")
        self.engine = engine
        self.name = name
        self.capacity = capacity
        self.policy = make_policy(policy)
        if isinstance(cost, StepCostCache):
            self.cache: Optional[StepCostCache] = cost
            self.step_cost: Optional[StepCost] = cost.step_cost
        else:
            self.cache = None
            self.step_cost = cost
        self.windows = tuple(windows)
        self.max_sequences = max_sequences
        self.is_encdec = is_encdec
        self.role = role
        self.refetch_delay = refetch_delay
        self.on_finish = on_finish
        self.on_preempt = on_preempt
        # KV-overflow policy: every replica of the pool shares one
        # PreemptionPolicy (menu string or instance; None = sacrifice +
        # recent-first, the legacy behaviour, bit-identical to goldens).
        self.preemption = make_preemption(preemption)
        # Prices one victim's host round trip: (req, kv_tokens) ->
        # (delay_s, energy_j).  Only the swap mechanism consults it.
        self.swap_cost: SwapCost = swap_cost or (lambda req, kv: (0.0, 0.0))
        self.incoming: List[float] = []      # scheduled delivery times
        self.incoming_unknown = 0            # parked, time not yet known
        # coupled topologies: the pool whose iteration-end events spawn
        # this pool's deliveries (bounds downstream fast-forward runs)
        self.upstream: Optional["Pool"] = None
        # fault-injection state (core/faults.py; inert by default)
        self.down = 0                        # currently failed replicas
        self.stragglers: List = []           # applied Straggler windows
        self.fault_throttle = 1.0            # admission scale while down
        self.replicas = [Replica(self, i, b) for i, b in enumerate(buckets)]

    # -- fault handling (core/faults.py) -----------------------------------

    def live_max_sequences(self) -> int:
        """Admission concurrency cap, throttled while the pool is
        degraded (graceful degradation: survivors admit less so queued
        work does not thrash their KV into preemption storms)."""
        if self.down and self.fault_throttle < 1.0:
            return max(1, int(self.max_sequences * self.fault_throttle))
        return self.max_sequences

    def slowdown(self, replica: "Replica", t: float) -> float:
        """Product of straggler factors active on ``replica`` at ``t``."""
        f = 1.0
        for s in self.stragglers:
            if s.replica == replica.index and s.start <= t < s.end:
                f *= s.slowdown
        return f

    def least_loaded_alive(self, exclude: Optional["Replica"] = None
                           ) -> Optional["Replica"]:
        alive = [r for r in self.replicas
                 if not r.failed and r is not exclude]
        if not alive:
            return None
        return min(alive, key=lambda r: (len(r.active) + len(r.pending),
                                         r.index))

    def on_replica_fail(self, rep: "Replica", victims, pending,
                        now: float) -> None:
        """Redistribute a failed replica's work to survivors.

        ``victims`` (its active set) lost their KV — each counts as a
        preemption and re-enters via the sacrifice/recompute path: in the
        disagg decode role that means a re-fetch through the prefill pool
        (engine-coupled re-prefill or delay model), elsewhere a plain
        re-queue.  ``pending`` re-queues as-is.  With no survivor,
        everything waits on ``rep`` for repair.
        """
        self.down += 1
        self.engine.fault_requeues += len(victims)
        for v in victims:
            rep.records[v.req.rid].preemptions += 1
            rep.preemptions += 1
            v.reset()
        if self.role == "decode" and victims:
            # shipped prompt KV is gone: victims re-materialize it like
            # sacrificed preemptees.  Engine-coupled refetch parks them
            # upstream (they return via deliver(), which reroutes off a
            # dead replica); delay-mode refetch re-inserts into
            # rep.pending, collected below for redistribution.
            for v in victims:
                rep.refetch(v.req, now)
            pending = pending + rep.pending
            rep.pending = []
        else:
            pending = [v.req for v in victims] + pending
        if not pending:
            return
        if all(r.failed for r in self.replicas):
            rep.pending = sorted(pending, key=lambda r: r.arrival)
            return                       # total outage: wait for repair
        for req in pending:
            target = self.least_loaded_alive()
            rec = rep.records.pop(req.rid, None)
            if rec is not None and req.rid not in target.records:
                target.records[req.rid] = rec
            if req.rid in rep.shadow:
                rep.shadow.discard(req.rid)
                target.shadow.add(req.rid)
            target.deliver(req, now)

    # -- in-flight delivery bookkeeping (fast-forward bounds) --------------

    def incoming_bound(self) -> Optional[float]:
        return self.incoming[0] if self.incoming else None

    def expect(self, time: float) -> None:
        bisect.insort(self.incoming, time)

    def arrived(self, time: float) -> None:
        idx = bisect.bisect_left(self.incoming, time)
        if idx < len(self.incoming) and self.incoming[idx] == time:
            self.incoming.pop(idx)

    def next_event_bound(self, exclude: Optional["Replica"] = None
                         ) -> Optional[float]:
        """Earliest scheduled event of this pool (a replica's iteration
        end or idle-wake, or an inbound delivery) — nothing this pool
        does can affect the rest of the system before that time."""
        bounds = [b for rep in self.replicas if rep is not exclude
                  for b in (rep._busy_until, rep._wake_at)
                  if b is not None]
        if self.incoming:
            bounds.append(self.incoming[0])
        return min(bounds) if bounds else None

    # -- results -----------------------------------------------------------

    def results(self) -> List[BatchingResult]:
        return [r.result() for r in self.replicas if r.touched]

    def replay_accumulators(self, owner) -> None:
        """Fold every replica's per-call FLOP/byte increments into the
        owner simulator's accumulators in replica order — the exact
        summation order of the legacy sequential loops."""
        for rep in self.replicas:
            for f, b in rep.cost_calls:
                owner._flops_accum += f
                owner._bytes_accum += b


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class Engine:
    """Global event heap driving every pool's replicas on one clock."""

    def __init__(self):
        self.heap: List[tuple] = []
        self.pools: Dict[str, Pool] = {}
        self._seq = 0
        # fault-injection state (inert unless install_faults ran)
        self.faults = None                  # the installed FaultSchedule
        self.fault_times: List[float] = []  # sorted transition times
        self.fault_requeues = 0             # requests re-queued by failures
        # epoch-gated re-planning state (inert unless install_epoch ran)
        self.epoch_times: List[float] = []  # sorted epoch boundaries
        self.stopped = False                # epoch handler halts run()
        self._boundary_times: List[float] = []  # faults | epochs, merged

    def add_pool(self, name: str, buckets, capacity: int,
                 policy: BatchingPolicy, cost, **kw) -> Pool:
        pool = Pool(self, name, buckets, capacity, policy, cost, **kw)
        self.pools[name] = pool
        return pool

    # -- world-change boundaries (faults + epoch re-planning) --------------

    def _rebuild_boundaries(self) -> None:
        self._boundary_times = sorted(set(self.fault_times)
                                      | set(self.epoch_times))

    def next_boundary(self, now: float) -> Optional[float]:
        """Earliest world-change boundary strictly after ``now`` — a
        fault transition or an epoch re-planning boundary.  Both bound
        fast-forward runs identically: past either, this replica's world
        may change, so uneventful-decode runs must not cross it.  One
        shared helper means faults + re-planning compose without
        double-bounding bugs.  None when neither is installed."""
        times = self._boundary_times
        if not times:
            return None
        i = bisect.bisect_right(times, now)
        return times[i] if i < len(times) else None

    def fault_bound(self, now: float) -> Optional[float]:
        """Earliest fault transition strictly after ``now``; kept as a
        delegating alias of ``next_boundary`` (which also folds in epoch
        boundaries) for callers of the PR-9 API."""
        return self.next_boundary(now)

    def install_epoch(self, time: float,
                      handler: Callable[[float], None]) -> None:
        """Push one epoch boundary onto the heap.  The handler fires at
        ``_PRIO_EPOCH`` — after fault transitions at the same instant,
        before deliveries and iteration ends — and typically freezes the
        engine via ``stop()`` so a plan controller can re-shard and
        resume on a new engine.  Must run after ``add_pool`` and before
        ``run()``."""
        self.epoch_times.append(time)
        self.epoch_times.sort()
        self._rebuild_boundaries()
        self.schedule(time, _PRIO_EPOCH, 0, handler)

    def stop(self) -> None:
        """Halt ``run()`` after the current event (epoch switching)."""
        self.stopped = True

    # -- fault injection (core/faults.py) ----------------------------------

    def install_faults(self, schedule) -> None:
        """Resolve a ``FaultSchedule`` against the registered pools and
        push its transitions onto the event heap.  Must run after every
        ``add_pool`` and before ``run()``.  Events aimed at replicas a
        pool does not have are inert; an empty schedule installs
        nothing (bit-identical to a fault-free run)."""
        if schedule is None or schedule.empty:
            return
        times = set()
        for f in schedule.replica_faults:
            for pool in self.pools.values():
                if f.pool not in ("*", pool.name):
                    continue
                if f.replica >= len(pool.replicas):
                    continue
                rep = pool.replicas[f.replica]
                times.add(f.start)
                self.schedule(f.start, _PRIO_FAULT, f.replica,
                              lambda t, r=rep: r.fail(t))
                if f.repair != float("inf"):
                    times.add(f.repair)
                    self.schedule(f.repair, _PRIO_FAULT, f.replica,
                                  lambda t, r=rep: r.repair(t))
        for s in schedule.stragglers:
            for pool in self.pools.values():
                if s.pool not in ("*", pool.name):
                    continue
                if s.replica >= len(pool.replicas):
                    continue
                pool.stragglers.append(s)
                times.add(s.start)
                times.add(s.end)
        for pool in self.pools.values():
            pool.fault_throttle = schedule.throttle
        self.fault_times = sorted(times)
        self.faults = schedule
        self._rebuild_boundaries()

    def schedule(self, time: float, prio: int, tie: int,
                 fn: Callable[[float], None]) -> None:
        self._seq += 1
        heapq.heappush(self.heap, (time, prio, tie, self._seq, fn))

    def deliver(self, pool: Pool, replica, req: Request,
                time: float) -> None:
        """Schedule a request delivery (a finished transfer, a re-fetch
        return) into a replica's pending queue at ``time``.

        ``replica`` may be a ``Replica`` or a callable
        ``(fire_time) -> Replica`` resolved when the event fires, so
        load-balancing routers observe deliveries in completion-time
        order (ties broken by rid)."""
        pool.expect(time)

        def fire(t: float, r=req) -> None:
            pool.arrived(t)
            target = replica(t) if callable(replica) else replica
            target.deliver(r, t)

        self.schedule(time, _PRIO_DELIVER, req.rid, fire)

    def run(self) -> None:
        for pool in self.pools.values():
            for rep in pool.replicas:
                rep.advance()
        heap = self.heap
        while heap and not self.stopped:
            time, _prio, _tie, _seq, fn = heapq.heappop(heap)
            fn(time)
