"""Power / energy model (paper §4.2.4, Table 4).

The paper profiles per-operation energy and observes that (a) energy-optimal
plans differ from latency-optimal ones, and (b) lowering GPU frequency to
0.8 GHz cuts energy up to 45% at a TTFT/TPOT cost.  We model device power as

    P(util, f) = P_idle + (P_peak - P_idle) * util * (f / f_base)^2

(dynamic power ~ f * V^2 with V ~ f — the standard CMOS scaling argument),
while compute/bandwidth rates scale ~ f.  Energy per op = P * time.  This
reproduces the paper's qualitative structure: downclocking stretches time by
f_base/f but cuts dynamic power by (f/f_base)^2, netting ~f energy savings
on compute-bound ops, less on memory-bound ones.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .cluster import DeviceSpec


@dataclasses.dataclass
class PowerModel:
    device: DeviceSpec
    freq_ghz: Optional[float] = None

    @property
    def freq_ratio(self) -> float:
        if self.freq_ghz is None:
            return 1.0
        return self.freq_ghz / self.device.base_freq_ghz

    def power(self, utilization: float) -> float:
        """Watts at the given compute utilization in [0, 1]."""
        u = min(max(utilization, 0.0), 1.0)
        dyn = (self.device.peak_power_w - self.device.idle_power_w)
        return self.device.idle_power_w + dyn * u * self.freq_ratio ** 2

    def energy(self, time_s: float, utilization: float) -> float:
        """Joules consumed by ONE device over ``time_s``."""
        return self.power(utilization) * time_s
