"""Multi-fidelity plan search: fluid screening, exact confirmation.

Plan search cost is dominated by exact trace simulation — tens of
milliseconds per candidate — while the fluid surrogate (core/fluid.py)
scores a candidate in a few hundred microseconds from the same cost
models.  ``MultiFidelitySearch`` exploits the gap with the classic
screen-then-confirm loop:

  1. SCREEN every candidate ``ApexSearch.candidates()`` enumerates with
     the fluid surrogate (one shared ``TraceSummary``, computed once),
  2. keep a SURVIVOR FRONTIER: the top ``frontier_k`` surrogate
     candidates under EVERY objective in ``OBJECTIVES`` (not just the
     requested one — the surrogate's ranking noise is objective-
     dependent, so a multi-objective frontier hedges against it), plus
     the top ``frontier_k`` under the requested objective among
     candidates whose surrogate means fit a ``slo_slack``-widened SLO
     band (candidates the surrogate thinks are near-feasible survive
     even if their surrogate objective is middling),
  3. CONFIRM only the survivors with the exact event engine — serially
     or across ``jobs`` forked workers — and rank them exactly as
     ``ApexSearch.search`` would have.

With a ~1000-candidate joint search this turns a many-minute exact
sweep into roughly a second of screening plus a handful of exact
simulations, while the frontier (default width 8 per objective) is wide
enough that the exact search's winner survives screening (tested in
tests/test_fluid.py across seeded model/trace points).
"""

from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, List, Optional, Sequence

from .batching import BatchingPolicy
from .cluster import Cluster
from .fluid import TraceSummary
from .metrics import SimulationReport
from .search import (OBJECTIVES, ApexSearch, SearchResult, _call_progress,
                     fork_map)
from .trace import Request, retag_slo


@dataclasses.dataclass
class MultiFidelityResult:
    """A ``SearchResult`` over the confirmed survivors, plus the
    screening telemetry that justifies trusting it."""

    result: SearchResult               # exact ranking over survivors
    num_candidates: int                # size of the full candidate set
    num_survivors: int                 # candidates exact-confirmed
    screen_seconds: float              # fluid sweep wall time
    confirm_seconds: float             # exact confirmation wall time
    surrogate_reports: List[SimulationReport]   # fluid report per candidate
    survivor_indices: List[int]        # into the candidate/surrogate lists

    @property
    def best(self) -> SimulationReport:
        return self.result.best

    @property
    def best_plan(self):
        return self.result.best_plan

    @property
    def surrogate_plans_per_sec(self) -> float:
        if self.screen_seconds <= 0:
            return float("inf")
        return self.num_candidates / self.screen_seconds


class MultiFidelitySearch:
    """Layered on an ``ApexSearch``: same candidate set, same objectives,
    same exact simulators for the final ranking — only the sweep over
    non-survivors is replaced by the fluid surrogate."""

    def __init__(self, search: ApexSearch, frontier_k: int = 8,
                 slo_slack: float = 1.5,
                 screen_objectives: Optional[Sequence[str]] = None,
                 tie_rel: float = 5e-3):
        self.inner = search
        self.frontier_k = frontier_k
        self.slo_slack = slo_slack
        self.tie_rel = tie_rel
        self.screen_objectives = list(screen_objectives or OBJECTIVES)
        unknown = [o for o in self.screen_objectives if o not in OBJECTIVES]
        if unknown:
            raise KeyError(f"unknown screening objectives {unknown}; "
                           f"known: {sorted(OBJECTIVES)}")

    # -- survivor selection ---------------------------------------------------

    def _topk_with_ties(self, feas: List[int],
                        reports: List[SimulationReport], key) -> List[int]:
        """Top ``frontier_k`` of ``feas`` under ``key``, EXPANDED to every
        candidate within ``tie_rel`` of the k-th value: when the surrogate
        cannot distinguish plans (e.g. span-dominated latency at light
        load, where dozens tie to the arrival window), cutting the tie
        block at k would drop candidates on index order — an arbitrary
        choice the exact engine, not the surrogate, should make."""
        ranked = sorted(feas, key=lambda i: key(reports[i]))
        if len(ranked) <= self.frontier_k:
            return ranked
        kth = key(reports[ranked[self.frontier_k - 1]])
        thr = kth + self.tie_rel * abs(kth)
        return [i for i in ranked if key(reports[i]) <= thr]

    def _frontier(self, reports: List[SimulationReport], objective: str,
                  slo_ttft_s: Optional[float],
                  slo_tpot_s: Optional[float]) -> List[int]:
        feas = [i for i, r in enumerate(reports) if r.feasible]
        if not feas:
            return []
        keep: set = set()
        for name in self.screen_objectives:
            keep.update(self._topk_with_ties(feas, reports,
                                             OBJECTIVES[name]))
        # near-SLO band under the requested objective: surrogate MEANS
        # within slack x SLO (means, not p95 — the surrogate's percentiles
        # are dispersion-scaled means, so the band uses the sturdier
        # statistic and the slack absorbs the dispersion)
        if slo_ttft_s is not None or slo_tpot_s is not None:
            def in_band(i: int) -> bool:
                r = reports[i]
                if slo_ttft_s is not None and \
                        r.ttft_mean > slo_ttft_s * self.slo_slack:
                    return False
                if slo_tpot_s is not None and \
                        r.tpot_mean > slo_tpot_s * self.slo_slack:
                    return False
                return True
            band = [i for i in feas if in_band(i)]
            if band:
                keep.update(self._topk_with_ties(band, reports,
                                                 OBJECTIVES[objective]))
        return sorted(keep)

    # -- the search -----------------------------------------------------------

    def search(self, requests: Sequence[Request],
               objective: str = "latency",
               quant: str = "fp16",
               feasible_only: bool = False,
               policy: Optional[BatchingPolicy] = None,
               max_model_dp: Optional[int] = None,
               slo_ttft_s: Optional[float] = None,
               slo_tpot_s: Optional[float] = None,
               disaggregated: bool = False,
               transfer_mode: str = "layerwise",
               decode_quant: Optional[str] = None,
               max_disagg_plans: int = 256,
               pool_menu: Optional[Sequence[Cluster]] = None,
               max_total_devices: Optional[int] = None,
               prefill_policy: Optional[BatchingPolicy] = None,
               decode_policy: Optional[BatchingPolicy] = None,
               progress: Optional[Callable] = None,
               verbose: bool = False,
               jobs: int = 1,
               preemption=None,
               slo_classes=None) -> MultiFidelityResult:
        """Same signature semantics as ``ApexSearch.search``; returns a
        ``MultiFidelityResult`` whose ``result`` ranks only the confirmed
        survivors (``result.all_reports`` holds one EXACT report per
        survivor, in survivor order).  ``objective="goodput"`` screens by
        the surrogate's per-class SLO-attainment estimate (the frontier
        always includes the top-k under every objective, goodput among
        them) and confirms with the engine's measured goodput."""
        obj = OBJECTIVES[objective]
        inner = self.inner
        requests = retag_slo(requests, slo_classes)
        candidates, kv_model = inner.candidates(
            quant=quant, feasible_only=feasible_only,
            max_model_dp=max_model_dp, disaggregated=disaggregated,
            transfer_mode=transfer_mode, decode_quant=decode_quant,
            max_disagg_plans=max_disagg_plans, pool_menu=pool_menu,
            max_total_devices=max_total_devices)
        n_cand = len(candidates)
        ts = TraceSummary.of(requests)

        # ---- phase 1: fluid screening (cheap enough to stay serial) ----
        t0 = _time.perf_counter()
        surrogate: List[SimulationReport] = []
        for i, cand in enumerate(candidates):
            family = cand[0]
            _, sim = inner.make_simulator(cand, kv_model, fluid=True)
            sim_kwargs = {} if family == "colocated" else {
                "prefill_policy": prefill_policy,
                "decode_policy": decode_policy}
            surrogate.append(sim.simulate(requests, policy=policy,
                                          summary=ts, **sim_kwargs))
            if verbose and (i + 1) % max(1, n_cand // 10) == 0:
                print(f"[screen] {i + 1}/{n_cand} surrogate-scored")
        screen_s = _time.perf_counter() - t0

        survivors = self._frontier(surrogate, objective,
                                   slo_ttft_s, slo_tpot_s)
        if not survivors:
            # surrogate found nothing feasible — fall back to confirming
            # every candidate rather than failing on surrogate pessimism
            survivors = list(range(n_cand))
        if verbose:
            print(f"[screen] {n_cand} candidates -> "
                  f"{len(survivors)} survivors "
                  f"({screen_s:.2f}s, "
                  f"{n_cand / screen_s if screen_s > 0 else 0:.0f} plans/s)")

        # ---- phase 2: exact confirmation of the survivors ----
        t1 = _time.perf_counter()

        def eval_one(j: int):
            cand = candidates[survivors[j]]
            _, sim = inner.make_simulator(cand, kv_model)
            sim_kwargs = {} if cand[0] == "colocated" else {
                "prefill_policy": prefill_policy,
                "decode_policy": decode_policy}
            rep = sim.simulate(requests, policy=policy,
                               preemption=preemption, **sim_kwargs)
            st = getattr(sim, "cache_stats", None) or {}
            return rep, st.get("hits", 0), st.get("misses", 0)

        def confirm_progress(done, total, best):
            if progress:
                _call_progress(progress, done, total, best)
            if verbose and (done == total or done % max(1, total // 5) == 0):
                lbl = best.plan_label if best is not None else "<none>"
                print(f"[confirm] {done}/{total} exact, best={lbl}")

        reports, best_j, hits, misses = inner._evaluate_ranked(
            eval_one, len(survivors), obj, slo_ttft_s, slo_tpot_s,
            jobs=jobs, progress=confirm_progress, tag="confirm")
        confirm_s = _time.perf_counter() - t1
        if best_j is None:
            raise RuntimeError(
                "no feasible plan found (memory or SLO constraints too "
                f"tight) among {len(survivors)} survivors of "
                f"{n_cand} candidates")
        best_plan, _ = inner.make_simulator(candidates[survivors[best_j]],
                                            kv_model)
        result = SearchResult(
            best=reports[best_j], best_plan=best_plan,
            all_reports=reports, num_schemes=n_cand,
            num_feasible=sum(r.feasible for r in reports),
            search_seconds=screen_s + confirm_s,
            objective=objective,
            slo_ttft_s=slo_ttft_s, slo_tpot_s=slo_tpot_s,
            cache_hits=hits, cache_misses=misses)
        return MultiFidelityResult(
            result=result, num_candidates=n_cand,
            num_survivors=len(survivors),
            screen_seconds=screen_s, confirm_seconds=confirm_s,
            surrogate_reports=surrogate, survivor_indices=survivors)
