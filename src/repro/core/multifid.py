"""Multi-fidelity plan search: fluid screening, exact confirmation.

Plan search cost is dominated by exact trace simulation — tens of
milliseconds per candidate — while the fluid surrogate (core/fluid.py)
scores a candidate in a few hundred microseconds from the same cost
models.  ``MultiFidelitySearch`` exploits the gap with the classic
screen-then-confirm loop:

  1. SCREEN every candidate ``ApexSearch.candidates()`` enumerates with
     the fluid surrogate (one shared ``TraceSummary``, computed once),
  2. keep a SURVIVOR FRONTIER: the top ``frontier_k`` surrogate
     candidates under EVERY objective in ``OBJECTIVES`` (not just the
     requested one — the surrogate's ranking noise is objective-
     dependent, so a multi-objective frontier hedges against it), plus
     the top ``frontier_k`` under the requested objective among
     candidates whose surrogate means fit a ``slo_slack``-widened SLO
     band (candidates the surrogate thinks are near-feasible survive
     even if their surrogate objective is middling),
  3. CONFIRM the survivors with the exact event engine — but as a
     successive-halving LADDER, not a cliff: survivors are first
     simulated exactly on a short PREFIX of the trace (default 25% of
     requests — the first k arrivals of a Poisson trace are themselves a
     Poisson sample, so prefix rankings are unbiased), the top fraction
     per objective (tie-aware, SLO-band-slackened — the same frontier
     semantics as screening) promotes to the next longer prefix, and
     only the finalists pay for the full trace.  Serial or across
     ``jobs`` forked workers; ranked exactly as ``ApexSearch.search``
     would have.

With a ~1000-candidate joint search this turns a many-minute exact
sweep into roughly a second of screening plus a handful of exact
simulations, while the frontier (default width 8 per objective) is wide
enough that the exact search's winner survives screening AND every
halving rung (tested in tests/test_fluid.py and tests/test_halving.py
across seeded model/trace points).
"""

from __future__ import annotations

import dataclasses
import math
import time as _time
from typing import Callable, List, Optional, Sequence

from .batching import BatchingPolicy
from .cluster import Cluster
from .fluid import TraceSummary
from .metrics import SimulationReport
from .search import (OBJECTIVES, ApexSearch, SearchResult, _call_progress)
from .trace import Request, prefix_trace, retag_slo


@dataclasses.dataclass
class RungStat:
    """Telemetry for one successive-halving rung: who was evaluated on
    how much trace, who promoted, and what it cost."""

    fraction: float                    # of the trace (by request count)
    n_requests: int                    # prefix length actually simulated
    evaluated: int                     # survivors entering this rung
    promoted: int                      # survivors leaving this rung
    seconds: float                     # rung wall time
    cache_hits: int                    # summed StepCostCache counters
    cache_misses: int
    survivor_indices: List[int]        # global candidate indices promoted


@dataclasses.dataclass
class MultiFidelityResult:
    """A ``SearchResult`` over the confirmed survivors, plus the
    screening/halving telemetry that justifies trusting it."""

    result: SearchResult               # exact ranking over the finalists
    num_candidates: int                # size of the full candidate set
    num_survivors: int                 # finalists exact-confirmed on the
                                       # FULL trace (= len(all_reports))
    screen_seconds: float              # fluid sweep wall time
    confirm_seconds: float             # exact wall time: rungs + finals
    surrogate_reports: List[SimulationReport]   # fluid report per candidate
    survivor_indices: List[int]        # finalists, as candidate indices
    screen_survivors: int = 0          # survivors out of fluid screening
                                       # (what enters the first rung)
    rungs: List[RungStat] = dataclasses.field(default_factory=list)

    @property
    def best(self) -> SimulationReport:
        return self.result.best

    @property
    def best_plan(self):
        return self.result.best_plan

    @property
    def surrogate_plans_per_sec(self) -> float:
        if self.screen_seconds <= 0:
            return float("inf")
        return self.num_candidates / self.screen_seconds


class MultiFidelitySearch:
    """Layered on an ``ApexSearch``: same candidate set, same objectives,
    same exact simulators for the final ranking — only the sweep over
    non-survivors is replaced by the fluid surrogate."""

    # quarter-window deviation (Poisson standard errors) above which a
    # trace is treated as non-stationary — ~2 is ordinary Poisson noise,
    # so 6 only trips on flagrant diurnal/burst structure
    NONSTATIONARY_Z = 6.0

    def __init__(self, search: ApexSearch, frontier_k: int = 8,
                 slo_slack: float = 1.5,
                 screen_objectives: Optional[Sequence[str]] = None,
                 tie_rel: float = 5e-3,
                 rungs: Sequence[float] = (0.25, 0.5),
                 promote_frac: float = 1 / 3,
                 min_rung_requests: int = 8,
                 rung_tie_rel: float = 1e-6):
        """``rungs`` are trace-prefix fractions for successive halving
        (ascending; the full trace is the implicit final rung); each rung
        promotes the tie-aware top ``max(frontier_k, ceil(promote_frac *
        entrants))`` under the requested objective, plus the SLO band —
        never narrower than the screening frontier, so halving only ever
        prunes when there is real headroom.  Rungs whose prefix would be
        shorter than ``min_rung_requests`` are skipped (tiny prefixes
        rank on noise).

        ``tie_rel`` (screening) and ``rung_tie_rel`` (halving rungs) are
        deliberately different: the wide screening band absorbs the
        surrogate's MODEL error, but rungs run the exact engine, where
        only genuine ties (symmetric plan variants with bit-equal
        objectives) are ambiguous — a wide band at rungs floods
        promotion past ``promote_frac`` and erases the ladder's savings.
        Prefix-vs-full ranking drift is instead absorbed by the generous
        ``promote_frac`` and the ``frontier_k`` floor."""
        self.inner = search
        if frontier_k <= 0:
            raise ValueError(f"frontier_k must be > 0, got {frontier_k}")
        self.frontier_k = frontier_k
        self.slo_slack = slo_slack
        self.tie_rel = tie_rel
        self.rungs = list(rungs)
        if any(not 0.0 < f < 1.0 for f in self.rungs):
            raise ValueError(f"rung fractions must lie in (0, 1), "
                             f"got {list(rungs)}")
        if any(b <= a for a, b in zip(self.rungs, self.rungs[1:])):
            raise ValueError(f"rung fractions must be strictly "
                             f"increasing, got {list(rungs)}")
        if not 0.0 < promote_frac <= 1.0:
            raise ValueError(f"promote_frac must lie in (0, 1], "
                             f"got {promote_frac}")
        self.promote_frac = promote_frac
        self.min_rung_requests = min_rung_requests
        self.rung_tie_rel = rung_tie_rel
        self.screen_objectives = list(screen_objectives or OBJECTIVES)
        unknown = [o for o in self.screen_objectives if o not in OBJECTIVES]
        if unknown:
            raise KeyError(f"unknown screening objectives {unknown}; "
                           f"known: {sorted(OBJECTIVES)}")

    # -- survivor selection ---------------------------------------------------

    def _topk_with_ties(self, feas: List[int],
                        reports: List[SimulationReport], key,
                        k: Optional[int] = None,
                        tie_rel: Optional[float] = None) -> List[int]:
        """Top ``k`` (default ``frontier_k``) of ``feas`` under ``key``,
        EXPANDED to every candidate within ``tie_rel`` of the k-th value:
        when a fidelity level cannot distinguish plans (e.g. span-
        dominated latency at light load, where dozens tie to the arrival
        window), cutting the tie block at k would drop candidates on
        index order — an arbitrary choice the next, higher fidelity
        should make."""
        k = self.frontier_k if k is None else k
        tie_rel = self.tie_rel if tie_rel is None else tie_rel
        ranked = sorted(feas, key=lambda i: key(reports[i]))
        if len(ranked) <= k:
            return ranked
        kth = key(reports[ranked[k - 1]])
        thr = kth + tie_rel * abs(kth)
        return [i for i in ranked if key(reports[i]) <= thr]

    def _frontier(self, reports: List[SimulationReport], objective: str,
                  slo_ttft_s: Optional[float],
                  slo_tpot_s: Optional[float],
                  objectives: Optional[Sequence[str]] = None,
                  k: Optional[int] = None,
                  tie_rel: Optional[float] = None) -> List[int]:
        """Indices surviving one fidelity level: the tie-aware top ``k``
        under every objective in ``objectives`` (default: the screening
        objectives), plus the top ``k`` under the requested objective
        among candidates in the slackened SLO band.  Halving rungs reuse
        this with ``objectives=(objective,)``, a promotion-sized ``k``,
        and the exact-fidelity ``rung_tie_rel`` — same semantics,
        narrower lens."""
        feas = [i for i, r in enumerate(reports) if r.feasible]
        if not feas:
            return []
        keep: set = set()
        for name in (objectives if objectives is not None
                     else self.screen_objectives):
            keep.update(self._topk_with_ties(feas, reports,
                                             OBJECTIVES[name], k=k,
                                             tie_rel=tie_rel))
        # near-SLO band under the requested objective: surrogate MEANS
        # within slack x SLO (means, not p95 — the surrogate's percentiles
        # are dispersion-scaled means, so the band uses the sturdier
        # statistic and the slack absorbs the dispersion)
        if slo_ttft_s is not None or slo_tpot_s is not None:
            def in_band(i: int) -> bool:
                r = reports[i]
                if slo_ttft_s is not None and \
                        r.ttft_mean > slo_ttft_s * self.slo_slack:
                    return False
                if slo_tpot_s is not None and \
                        r.tpot_mean > slo_tpot_s * self.slo_slack:
                    return False
                return True
            band = [i for i in feas if in_band(i)]
            if band:
                keep.update(self._topk_with_ties(band, reports,
                                                 OBJECTIVES[objective],
                                                 k=k, tie_rel=tie_rel))
        return sorted(keep)

    # -- the search -----------------------------------------------------------

    def search(self, requests: Sequence[Request],
               objective: str = "latency",
               quant: str = "fp16",
               feasible_only: bool = False,
               policy: Optional[BatchingPolicy] = None,
               max_model_dp: Optional[int] = None,
               slo_ttft_s: Optional[float] = None,
               slo_tpot_s: Optional[float] = None,
               disaggregated: bool = False,
               transfer_mode: str = "layerwise",
               decode_quant: Optional[str] = None,
               max_disagg_plans: int = 256,
               pool_menu: Optional[Sequence[Cluster]] = None,
               max_total_devices: Optional[int] = None,
               prefill_policy: Optional[BatchingPolicy] = None,
               decode_policy: Optional[BatchingPolicy] = None,
               progress: Optional[Callable] = None,
               verbose: bool = False,
               jobs: int = 1,
               preemption=None,
               slo_classes=None,
               halving: bool = True,
               faults=None,
               nonstationary: str = "raise",
               dynamic=None) -> MultiFidelityResult:
        """Same signature semantics as ``ApexSearch.search``; returns a
        ``MultiFidelityResult`` whose ``result`` ranks only the confirmed
        finalists (``result.all_reports`` holds one EXACT full-trace
        report per finalist, in ``survivor_indices`` order).
        ``objective="goodput"`` screens by the surrogate's per-class
        SLO-attainment estimate (the frontier always includes the top-k
        under every objective, goodput among them) and confirms with the
        engine's measured goodput.

        ``halving=True`` (default) climbs the successive-halving ladder
        between screening and full confirmation: survivors are exactly
        simulated on each ``rungs`` trace prefix in turn, promoting the
        tie-aware frontier under the requested objective, so the full
        trace is paid only by the finalists.  ``halving=False`` restores
        the PR 4 behavior (every screening survivor runs the full
        trace).

        ``faults`` applies ONLY to the final full-trace confirmation:
        screening (fluid surrogate) and the halving rungs stay
        fault-free by design — the surrogate has no fault dynamics and
        prefix rungs would rank on truncated fault windows — so the
        ladder orders candidates by nominal service and the finalists
        pay for the seeded faulted re-simulations that
        ``objective="degraded_goodput"`` ranks on.

        The fluid surrogate assumes ONE arrival rate; on a markedly
        non-stationary trace (``TraceSummary.nonstationarity`` above
        ~6 Poisson standard errors — diurnal or bursty arrivals) it
        would silently mis-rank.  ``nonstationary`` picks the response:
        ``"raise"`` (default) refuses with a clear error, ``"peak"``
        screens conservatively at the busiest quarter-window's arrival
        rate, ``"ignore"`` keeps the mean-rate screening (exact rungs
        and confirmation still correct the ranking downstream).

        ``dynamic`` (a ``core.dynamic.DynamicSpec``) extends the final
        confirmed ranking with epoch-gated plan-switching schedules over
        the finalists, exactly as in ``ApexSearch.search(dynamic=...)``
        — only exact-confirmed plans enter timetables, so the surrogate
        never ranks a switch."""
        if objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {objective!r}; choose "
                             f"one of {sorted(OBJECTIVES)}")
        if jobs < 0:
            raise ValueError(f"jobs must be >= 0, got {jobs}")
        from .faults import attach_resilience, normalize_faults
        faults = normalize_faults(faults)
        if objective == "degraded_goodput" and not faults:
            raise ValueError(
                "objective='degraded_goodput' needs a non-empty fault "
                "ensemble: pass faults=FaultSchedule(...) or "
                "faults=fault_ensemble(...)")
        obj = OBJECTIVES[objective]
        inner = self.inner
        requests = retag_slo(requests, slo_classes)
        candidates, kv_model = inner.candidates(
            quant=quant, feasible_only=feasible_only,
            max_model_dp=max_model_dp, disaggregated=disaggregated,
            transfer_mode=transfer_mode, decode_quant=decode_quant,
            max_disagg_plans=max_disagg_plans, pool_menu=pool_menu,
            max_total_devices=max_total_devices)
        n_cand = len(candidates)
        # one shared sort: the screening summary and every rung prefix
        # slice off the same arrival-ordered trace
        ordered = sorted(requests, key=lambda r: (r.arrival, r.rid))
        summaries = TraceSummary.of_prefixes(
            ordered, self.rungs if halving else ())
        ts = summaries[1.0]
        if nonstationary not in ("raise", "peak", "ignore"):
            raise ValueError(f"unknown nonstationary mode "
                             f"{nonstationary!r} (raise|peak|ignore)")
        if ts.nonstationarity > self.NONSTATIONARY_Z:
            if nonstationary == "raise":
                raise ValueError(
                    f"trace is non-stationary (z={ts.nonstationarity:.1f} "
                    f"Poisson standard errors across quarter-windows, "
                    f"threshold {self.NONSTATIONARY_Z:g}): the fluid "
                    "surrogate screens on ONE arrival rate and would "
                    "mis-rank.  Pass nonstationary='peak' to screen at "
                    "the busiest window's rate, 'ignore' to accept "
                    "mean-rate screening, or use ApexSearch.search "
                    "(exact, optionally with dynamic=DynamicSpec(...)).")
            if nonstationary == "peak":
                summaries = {f: dataclasses.replace(
                    s, arrival_rate=max(s.arrival_rate, s.peak_rate))
                    for f, s in summaries.items()}
                ts = summaries[1.0]

        # ---- phase 1: fluid screening (cheap enough to stay serial) ----
        t0 = _time.perf_counter()
        surrogate: List[SimulationReport] = []
        for i, cand in enumerate(candidates):
            family = cand[0]
            _, sim = inner.make_simulator(cand, kv_model, fluid=True)
            sim_kwargs = {} if family == "colocated" else {
                "prefill_policy": prefill_policy,
                "decode_policy": decode_policy}
            surrogate.append(sim.simulate(requests, policy=policy,
                                          summary=ts, **sim_kwargs))
            if verbose and (i + 1) % max(1, n_cand // 10) == 0:
                print(f"[screen] {i + 1}/{n_cand} surrogate-scored")
        screen_s = _time.perf_counter() - t0

        survivors = self._frontier(surrogate, objective,
                                   slo_ttft_s, slo_tpot_s)
        if not survivors:
            # surrogate found nothing feasible — fall back to confirming
            # every candidate rather than failing on surrogate pessimism
            survivors = list(range(n_cand))
        screen_survivors = len(survivors)
        if verbose:
            print(f"[screen] {n_cand} candidates -> "
                  f"{len(survivors)} survivors "
                  f"({screen_s:.2f}s, "
                  f"{n_cand / screen_s if screen_s > 0 else 0:.0f} plans/s)")

        def make_eval(idx: List[int], reqs: Sequence[Request],
                      fault_set=()):
            """Exact evaluation of candidates ``idx`` on trace ``reqs`` —
            one closure shape for every rung and the final confirm
            (``fault_set`` is non-empty only at the final confirm)."""
            def eval_one(j: int):
                cand = candidates[idx[j]]
                _, sim = inner.make_simulator(cand, kv_model)
                sim_kwargs = {} if cand[0] == "colocated" else {
                    "prefill_policy": prefill_policy,
                    "decode_policy": decode_policy}
                rep = sim.simulate(reqs, policy=policy,
                                   preemption=preemption, **sim_kwargs)
                st = getattr(sim, "cache_stats", None) or {}
                hits, misses = st.get("hits", 0), st.get("misses", 0)
                if fault_set and rep.feasible:
                    members = []
                    for f in fault_set:
                        members.append(sim.simulate(
                            reqs, policy=policy, preemption=preemption,
                            faults=f, **sim_kwargs))
                        st = getattr(sim, "cache_stats", None) or {}
                        hits += st.get("hits", 0)
                        misses += st.get("misses", 0)
                    rep = attach_resilience(rep, members)
                return rep, hits, misses
            return eval_one

        # ---- phase 2a: successive-halving rungs on trace prefixes ----
        t1 = _time.perf_counter()
        rung_stats: List[RungStat] = []
        hits = misses = 0
        if halving:
            for frac in self.rungs:
                if len(survivors) <= self.frontier_k:
                    break       # nothing left to halve
                prefix = prefix_trace(ordered, frac, presorted=True)
                if len(prefix) < self.min_rung_requests:
                    continue    # too short to rank on signal
                tr = _time.perf_counter()
                rung_reports, _, rh, rm = inner._evaluate_ranked(
                    make_eval(survivors, prefix), len(survivors), obj,
                    slo_ttft_s, slo_tpot_s, jobs=jobs,
                    verbose=verbose, tag=f"rung {frac:.0%}")
                hits += rh
                misses += rm
                k_promote = max(self.frontier_k,
                                math.ceil(self.promote_frac
                                          * len(survivors)))
                promoted = self._frontier(rung_reports, objective,
                                          slo_ttft_s, slo_tpot_s,
                                          objectives=(objective,),
                                          k=k_promote,
                                          tie_rel=self.rung_tie_rel)
                if promoted:
                    next_survivors = [survivors[j] for j in promoted]
                else:
                    # every survivor infeasible on this prefix (e.g. the
                    # prefix undershoots a KV/SLO cliff) — promotion by
                    # pessimism: keep everyone, let a higher fidelity rank
                    next_survivors = survivors
                rung_stats.append(RungStat(
                    fraction=frac, n_requests=len(prefix),
                    evaluated=len(survivors),
                    promoted=len(next_survivors),
                    seconds=_time.perf_counter() - tr,
                    cache_hits=rh, cache_misses=rm,
                    survivor_indices=next_survivors))
                if verbose:
                    print(f"[rung {frac:.0%}] {len(survivors)} -> "
                          f"{len(next_survivors)} promoted "
                          f"({len(prefix)} requests, "
                          f"{rung_stats[-1].seconds:.2f}s)")
                survivors = next_survivors

        # ---- phase 2b: full-trace confirmation of the finalists ----
        def confirm_progress(done, total, best):
            if progress:
                _call_progress(progress, done, total, best)
            if verbose and (done == total or done % max(1, total // 5) == 0):
                lbl = best.plan_label if best is not None else "<none>"
                print(f"[confirm] {done}/{total} exact, best={lbl}")

        reports, best_j, fh, fm = inner._evaluate_ranked(
            make_eval(survivors, requests, fault_set=faults),
            len(survivors), obj,
            slo_ttft_s, slo_tpot_s,
            jobs=jobs, progress=confirm_progress, tag="confirm")
        hits += fh
        misses += fm
        confirm_s = _time.perf_counter() - t1
        if best_j is None:
            raise RuntimeError(
                "no feasible plan found (memory or SLO constraints too "
                f"tight) among {len(survivors)} survivors of "
                f"{n_cand} candidates")
        best_plan, _ = inner.make_simulator(candidates[survivors[best_j]],
                                            kv_model)
        result = SearchResult(
            best=reports[best_j], best_plan=best_plan,
            all_reports=reports, num_schemes=n_cand,
            num_feasible=sum(r.feasible for r in reports),
            search_seconds=screen_s + confirm_s,
            objective=objective,
            slo_ttft_s=slo_ttft_s, slo_tpot_s=slo_tpot_s,
            cache_hits=hits, cache_misses=misses)
        if dynamic is not None and not dynamic.is_empty:
            # schedules draw only on the exact-confirmed finalists
            # (reports align with ``survivors`` positions)
            result = inner._extend_dynamic(
                result, dynamic, [candidates[i] for i in survivors],
                kv_model, requests, obj, policy=policy,
                preemption=preemption, t0=t0)
        return MultiFidelityResult(
            result=result, num_candidates=n_cand,
            num_survivors=len(survivors),
            screen_seconds=screen_s, confirm_seconds=confirm_s,
            surrogate_reports=surrogate, survivor_indices=survivors,
            screen_survivors=screen_survivors, rungs=rung_stats)
