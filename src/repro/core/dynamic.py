"""Epoch-gated dynamic re-planning (non-stationary serving).

A static parallel plan is tuned for ONE operating point; a non-stationary
trace (diurnal swings, bursts — core/trace.py's ``ArrivalProcess``) sweeps
through many.  This module asks the natural follow-up question: does
SWITCHING plans at epoch boundaries beat the best static plan once the
switch itself is priced honestly?

``DynamicPlanSimulator`` runs one ``EpochSchedule`` — a piecewise-constant
map from time to a plan index over a shared candidate list — and charges
every reconfiguration with modeled costs, never zero:

  * **weight re-shard**: the incoming plan's per-device weight bytes move
    over the cluster interconnect (``CollectiveModel.query("p2p", ...)``);
  * **KV hand-off**, one of two mechanisms:
      - ``"drain"``  — the outgoing plan keeps serving its admitted and
        queued requests to completion past the boundary; the new plan
        starts only after the drain finishes AND the re-shard lands
        (the cluster is shared, so late arrivals queue and eat the wait
        in their TTFT).  Works for every plan family, including
        disaggregated pools.
      - ``"migrate"`` — the outgoing engine stops AT the boundary;
        in-flight KV caches ship to the new plan's layout (priced per
        request through ``KVTransferModel``, blocking mode) and resume
        without recompute via the engine's swap-restore admission path.
        Colocated plans only (a mid-flight pool hand-off has no
        well-defined owner for a half-prefilled cache).

The per-switch bill lands in the report's ``reconfig``
(``ReconfigReport``) and the per-epoch timeline in ``windows``
(``metrics.windowed_metrics`` at the epoch boundaries), so a search over
{best static} ∪ {epoch schedules} compares like with like — and can
return an honest negative result when switching doesn't pay.

Schedule constructors cover the three controller policies:
``EpochSchedule.static`` / explicit epochs (oracle), ``reactive_schedule``
(trailing-epoch arrival rate with a causal lag), and ``fault_schedule``
(fall back to a degraded-mode plan inside fault windows — PR 9's
``FaultSchedule.windows``).
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .batching import BatchingPolicy, RequestRecord
from .metrics import SimulationReport, request_metrics, windowed_metrics
from .trace import Request


# ---------------------------------------------------------------------------
# epoch schedules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EpochSchedule:
    """A piecewise-constant plan timetable: ``epochs[k] = (start_s, plan)``
    activates candidate index ``plan`` from ``start_s`` until the next
    epoch's start (the last epoch runs to the end of the trace).  The
    first epoch must start at 0; consecutive epochs with the same plan
    are collapsed (a no-op switch costs nothing and is not a switch)."""

    epochs: Tuple[Tuple[float, int], ...]

    def __post_init__(self):
        eps = tuple((float(t), int(p)) for t, p in self.epochs)
        if not eps:
            raise ValueError("EpochSchedule needs at least one epoch")
        if eps[0][0] != 0.0:
            raise ValueError(
                f"first epoch must start at t=0, got {eps[0][0]}")
        for (a, _), (b, _) in zip(eps, eps[1:]):
            if b <= a:
                raise ValueError(
                    f"epoch starts must be strictly increasing "
                    f"({a} then {b})")
        for t, p in eps:
            if p < 0:
                raise ValueError(f"plan index must be >= 0, got {p}")
        # collapse consecutive same-plan epochs
        merged = [eps[0]]
        for t, p in eps[1:]:
            if p != merged[-1][1]:
                merged.append((t, p))
        object.__setattr__(self, "epochs", tuple(merged))

    @classmethod
    def static(cls, plan: int = 0) -> "EpochSchedule":
        """The degenerate one-epoch schedule: plan ``plan`` forever."""
        return cls(epochs=((0.0, plan),))

    @property
    def starts(self) -> List[float]:
        return [t for t, _ in self.epochs]

    @property
    def plans(self) -> List[int]:
        return [p for _, p in self.epochs]

    @property
    def num_switches(self) -> int:
        return len(self.epochs) - 1

    @property
    def is_static(self) -> bool:
        return len(self.epochs) == 1

    def plan_at(self, t: float) -> int:
        idx = bisect.bisect_right(self.starts, t) - 1
        return self.epochs[max(idx, 0)][1]

    def label(self) -> str:
        if self.is_static:
            return f"static(plan {self.epochs[0][1]})"
        return " | ".join(f"{t:g}s→p{p}" for t, p in self.epochs)


def reactive_schedule(requests: Sequence[Request], epoch_s: float,
                      horizon_s: float, lo_plan: int, hi_plan: int,
                      threshold_rps: Optional[float] = None,
                      lag: int = 1) -> EpochSchedule:
    """Load-watermark controller: epoch ``k`` runs ``hi_plan`` when the
    REALIZED arrival rate of epoch ``k - lag`` exceeded the threshold,
    ``lo_plan`` otherwise.  ``lag >= 1`` keeps the controller causal (it
    reacts to rates it has already observed — the first ``lag`` epochs
    default to ``lo_plan``); ``threshold_rps=None`` uses the trace's mean
    rate over the horizon."""
    if epoch_s <= 0:
        raise ValueError(f"epoch_s must be positive, got {epoch_s}")
    if horizon_s <= 0:
        raise ValueError(f"horizon_s must be positive, got {horizon_s}")
    if lag < 1:
        raise ValueError(f"lag must be >= 1 (causal), got {lag}")
    n = max(1, int(math.ceil(horizon_s / epoch_s)))
    counts = [0] * n
    for r in requests:
        k = min(int(r.arrival / epoch_s), n - 1)
        counts[k] += 1
    if threshold_rps is None:
        threshold_rps = len(requests) / horizon_s
    epochs = []
    for k in range(n):
        if k < lag:
            plan = lo_plan
        else:
            plan = hi_plan if counts[k - lag] / epoch_s > threshold_rps \
                else lo_plan
        epochs.append((k * epoch_s, plan))
    return EpochSchedule(epochs=tuple(epochs))


def fault_schedule(faults, horizon_s: float, primary: int,
                   fallback: int) -> EpochSchedule:
    """Fault-triggered controller: run ``fallback`` inside the schedule's
    merged degraded windows (``FaultSchedule.windows``), ``primary``
    everywhere else.  Window edges become the epoch boundaries."""
    if horizon_s <= 0:
        raise ValueError(f"horizon_s must be positive, got {horizon_s}")
    epochs: List[Tuple[float, int]] = [(0.0, primary)]
    for a, b in faults.windows(horizon_s):
        if a <= 0.0:
            epochs[0] = (0.0, fallback)
        else:
            epochs.append((a, fallback))
        if b < horizon_s:
            epochs.append((b, primary))
    return EpochSchedule(epochs=tuple(epochs))


# ---------------------------------------------------------------------------
# search integration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DynamicSpec:
    """What ``ApexSearch.search(dynamic=...)`` should try beyond the best
    static plan.  Plan indices in ``schedules`` are RANKS into the static
    search's top-``top_k`` plans (0 = static winner), not raw candidate
    indices — so a spec is portable across searches.  An empty spec (no
    ``schedules``, no ``epoch_s``) makes the search return the static
    result unchanged."""

    epoch_s: Optional[float] = None      # reactive controller's epoch grid
    top_k: int = 3                       # static finalists schedules draw on
    mechanism: str = "drain"             # "drain" | "migrate"
    schedules: Tuple[EpochSchedule, ...] = ()   # explicit (oracle) schedules
    threshold_rps: Optional[float] = None       # reactive watermark
    lag: int = 1                                # reactive causal lag (epochs)

    def __post_init__(self):
        object.__setattr__(self, "schedules", tuple(self.schedules))
        if self.top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {self.top_k}")
        if self.mechanism not in ("drain", "migrate"):
            raise ValueError(f"unknown mechanism {self.mechanism!r}")
        if self.epoch_s is not None and self.epoch_s <= 0:
            raise ValueError(f"epoch_s must be positive, got {self.epoch_s}")

    @property
    def is_empty(self) -> bool:
        return not self.schedules and self.epoch_s is None


def build_schedules(spec: DynamicSpec, requests: Sequence[Request],
                    horizon_s: float, k: int) -> List[EpochSchedule]:
    """The schedules a search evaluates for ``spec`` over ``k`` available
    finalist plans: the explicit (oracle) ones, plus — when ``epoch_s``
    is set — one reactive load-watermark schedule per ordered (lo, hi)
    finalist pair.  Degenerate (static, no-switch) schedules are dropped:
    the static sweep already covers them."""
    out: List[EpochSchedule] = []
    seen = set()
    for s in spec.schedules:
        if max(s.plans) >= k:
            raise ValueError(
                f"schedule {s.label()!r} references rank {max(s.plans)} "
                f"but only {k} finalist plans are available")
        if not s.is_static and s.epochs not in seen:
            seen.add(s.epochs)
            out.append(s)
    if spec.epoch_s is not None and horizon_s > 0:
        for lo in range(k):
            for hi in range(k):
                if lo == hi:
                    continue
                s = reactive_schedule(
                    requests, spec.epoch_s, horizon_s, lo_plan=lo,
                    hi_plan=hi, threshold_rps=spec.threshold_rps,
                    lag=spec.lag)
                if not s.is_static and s.epochs not in seen:
                    seen.add(s.epochs)
                    out.append(s)
    return out


# ---------------------------------------------------------------------------
# reconfiguration accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SwitchCost:
    """The itemized bill for one plan switch."""

    at_s: float                  # epoch boundary
    from_plan: str               # outgoing plan label
    to_plan: str                 # incoming plan label
    reshard_s: float             # weight re-shard time
    reshard_bytes: float         # weight bytes moved
    migrate_s: float = 0.0       # in-flight KV migration time (migrate)
    migrate_bytes: float = 0.0   # KV bytes moved
    migrated: int = 0            # in-flight requests carried across
    drain_s: float = 0.0         # old-plan overrun past the boundary (drain)
    drained: int = 0             # requests the old plan finished late
    energy_j: float = 0.0        # re-shard + migration transfer energy

    @property
    def stall_s(self) -> float:
        """Time past the boundary before the new plan starts serving."""
        return self.drain_s + self.reshard_s + self.migrate_s


@dataclasses.dataclass
class ReconfigReport:
    """All of a dynamic run's switches plus mechanism-level totals."""

    mechanism: str                       # "drain" | "migrate"
    switches: List[SwitchCost] = dataclasses.field(default_factory=list)

    @property
    def num_switches(self) -> int:
        return len(self.switches)

    @property
    def total_stall_s(self) -> float:
        return sum(s.stall_s for s in self.switches)

    @property
    def total_reshard_s(self) -> float:
        return sum(s.reshard_s for s in self.switches)

    @property
    def total_migrate_bytes(self) -> float:
        return sum(s.migrate_bytes for s in self.switches)

    @property
    def total_energy_j(self) -> float:
        return sum(s.energy_j for s in self.switches)

    def summary(self) -> str:
        if not self.switches:
            return f"reconfig({self.mechanism}): no switches"
        moved = sum(s.migrated for s in self.switches)
        drained = sum(s.drained for s in self.switches)
        parts = [f"{self.num_switches} switches",
                 f"stall={self.total_stall_s:.2f}s",
                 f"reshard={self.total_reshard_s:.2f}s"]
        if moved:
            parts.append(f"migrated={moved} "
                         f"({self.total_migrate_bytes / 1e9:.2f} GB)")
        if drained:
            parts.append(f"drained={drained}")
        parts.append(f"energy={self.total_energy_j:.0f}J")
        return f"reconfig({self.mechanism}): " + ", ".join(parts)


# ---------------------------------------------------------------------------
# the dynamic simulator
# ---------------------------------------------------------------------------

class DynamicPlanSimulator:
    """Runs one ``EpochSchedule`` over a shared candidate list.

    ``search`` is an ``ApexSearch`` (cost models + plan mapping);
    ``candidates`` the ``(family, scheme, pools)`` tuples the schedule's
    plan indices select from (``ApexSearch.candidates()`` order, or any
    explicit list); ``kv_model`` prices disaggregated hand-off inside a
    segment (as in the static path) — migration across switches is always
    priced blocking (the whole cache ships before resumption).
    """

    def __init__(self, search, candidates: Sequence, schedule: EpochSchedule,
                 kv_model=None, mechanism: str = "drain"):
        if mechanism not in ("drain", "migrate"):
            raise ValueError(f"unknown mechanism {mechanism!r} "
                             f"(expected 'drain' or 'migrate')")
        if not candidates:
            raise ValueError("DynamicPlanSimulator needs candidates")
        for _, p in schedule.epochs:
            if p >= len(candidates):
                raise ValueError(
                    f"schedule references plan {p} but only "
                    f"{len(candidates)} candidates were given")
        if mechanism == "migrate":
            bad = [p for p in schedule.plans
                   if candidates[p][0] != "colocated"]
            if bad:
                raise ValueError(
                    "migrate mechanism requires colocated plans "
                    f"(schedule uses disaggregated plan(s) {sorted(set(bad))}"
                    "); use mechanism='drain'")
        self.search = search
        self.candidates = list(candidates)
        self.schedule = schedule
        self.kv_model = kv_model
        self.mechanism = mechanism
        from ..disagg.kv_transfer import KVTransferModel
        self._ktm = KVTransferModel(search.coll, mode="blocking")

    # -- pricing ------------------------------------------------------------

    def _scheme(self, idx: int):
        return self.candidates[idx][1]

    def _reshard_cost(self, idx: int) -> Tuple[float, float, float]:
        """(time_s, bytes, energy_j) to lay the incoming plan's weights
        out: every device pulls its shard over the cluster interconnect.
        Disaggregated plans re-shard both pools concurrently (max time,
        summed bytes/energy)."""
        family, scheme, _ = self.candidates[idx]
        coll = self.search.coll
        span = self.search.cluster.num_devices
        schemes = [scheme] if family == "colocated" \
            else [scheme.prefill, scheme.decode]
        t = b = e = 0.0
        for s in schemes:
            nbytes = s.weight_bytes_per_device()
            dt, de = coll.query("p2p", nbytes, span)
            t = max(t, dt)
            b += nbytes
            e += de
        return t, b, e

    def _migrate_cost(self, carry: dict, old_idx: int, new_idx: int
                      ) -> Tuple[float, float, float, int]:
        """(time_s, bytes, energy_j, n_moved) to ship every in-flight KV
        cache to the new layout.  Transfers share the wire (serial sum);
        each runs ``lanes`` parallel per-device streams — the narrower of
        the two replica widths bounds the pairing."""
        old = self._scheme(old_idx)
        new = self._scheme(new_idx)
        lanes = max(1, min(old.devices_per_replica, new.devices_per_replica))
        span = self.search.cluster.num_devices
        t = b = e = 0.0
        moved = 0
        for _, snap, _ in carry.values():
            if snap is None:
                continue
            kv_tokens = int(snap[0]) + int(snap[1])
            if kv_tokens <= 0:
                continue
            est = self._ktm.estimate(old.model, kv_tokens, old.quant,
                                     span, lanes=lanes)
            t += est.delay_s
            b += est.nbytes
            e += est.energy_j
            moved += 1
        return t, b, e, moved

    # -- record merge -------------------------------------------------------

    @staticmethod
    def _merge_into(merged: Dict[int, RequestRecord], rec, orig: Request
                    ) -> None:
        m = merged.get(rec.rid)
        if m is None:
            m = RequestRecord(rid=rec.rid, arrival=orig.arrival,
                              context_len=orig.context_len,
                              gen_len=orig.gen_len,
                              slo_class=rec.slo_class)
            merged[rec.rid] = m
        if m.first_token_time == 0.0 and rec.first_token_time > 0.0:
            m.first_token_time = rec.first_token_time
        if rec.finish_time > 0.0:
            m.finish_time = rec.finish_time
        m.preemptions += rec.preemptions
        m.refetch_s += rec.refetch_s
        m.swaps += rec.swaps
        m.swap_s += rec.swap_s

    # -- simulation ---------------------------------------------------------

    def simulate(self, requests: Sequence[Request],
                 policy: Optional[BatchingPolicy] = None,
                 keep_records: bool = False,
                 preemption=None,
                 slo_classes=None,
                 faults=None) -> SimulationReport:
        """Run the schedule over ``requests`` and return one merged
        ``SimulationReport``: whole-run aggregates, per-epoch ``windows``,
        and the itemized ``reconfig`` bill.  ``faults`` passes through to
        every drain-mode segment (absolute fault times line up with the
        shared clock); migrate mode rejects faults — stopping an engine
        inside a fault window would double-count the disruption."""
        sched = self.schedule
        if faults is not None and not faults.empty \
                and self.mechanism == "migrate":
            raise ValueError("faults are not supported with "
                             "mechanism='migrate'; use 'drain'")
        orig: Dict[int, Request] = {r.rid: r for r in requests}
        starts = sched.starts
        seg_reqs: List[List[Request]] = [[] for _ in sched.epochs]
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            k = bisect.bisect_right(starts, r.arrival) - 1
            seg_reqs[max(k, 0)].append(r)

        reconfig = ReconfigReport(mechanism=self.mechanism)
        merged: Dict[int, RequestRecord] = {}
        carry: dict = {}              # rid -> (req, snapshot, partial_record)
        ready = 0.0                   # when the active plan can serve
        prev_idx: Optional[int] = None
        prev_end = 0.0                # previous segment's absolute end time
        total_energy = 0.0
        iterations = preemptions = 0
        peak_kv = peak_batch = 0
        swap_outs = swap_ins = 0
        kv_swap_s = kv_refetch_s = 0.0
        adm_rej = adm_def = 0
        end_time = 0.0
        util = []                     # (weight_s, mfu, mbu) per segment
        labels = []

        for k, (start, pidx) in enumerate(sched.epochs):
            nxt = starts[k + 1] if k + 1 < len(sched.epochs) else None
            scheme = self._scheme(pidx)
            labels.append((start, scheme.label()))

            # -- reconfiguration bill at this boundary --
            if prev_idx is not None:
                rs_t, rs_b, rs_e = self._reshard_cost(pidx)
                mig_t = mig_b = mig_e = 0.0
                moved = 0
                drain_s = 0.0
                drained = 0
                if self.mechanism == "migrate":
                    mig_t, mig_b, mig_e, moved = self._migrate_cost(
                        carry, prev_idx, pidx)
                    ready = start + rs_t + mig_t
                else:
                    drain_s = max(0.0, prev_end - start)
                    drained = sum(
                        1 for m in merged.values()
                        if m.finish_time > start and m.arrival < start)
                    ready = max(start, prev_end) + rs_t
                reconfig.switches.append(SwitchCost(
                    at_s=start,
                    from_plan=self._scheme(prev_idx).label(),
                    to_plan=scheme.label(),
                    reshard_s=rs_t, reshard_bytes=rs_b,
                    migrate_s=mig_t, migrate_bytes=mig_b, migrated=moved,
                    drain_s=drain_s, drained=drained,
                    energy_j=rs_e + mig_e))
                total_energy += rs_e + mig_e

            # -- assemble the segment's request set --
            seg = list(seg_reqs[k])
            carry_in = None
            if carry:
                seg = [req for req, _, _ in carry.values()] + seg
                carry_in = {rid: snap for rid, (_, snap, _) in carry.items()
                            if snap is not None}
            if not seg:
                carry = {}
                prev_idx = pidx
                continue
            bumped = [dataclasses.replace(r, arrival=max(r.arrival, ready))
                      for r in seg]

            _, sim = self.search.make_simulator(self.candidates[pidx],
                                                self.kv_model)
            kwargs = dict(policy=policy, keep_records=True,
                          preemption=preemption, slo_classes=slo_classes)
            if self.mechanism == "migrate":
                rep = sim.simulate(bumped, stop_at=nxt,
                                   carry_in=carry_in or None, **kwargs)
                carry = dict(sim.carryover or {})
            else:
                rep = sim.simulate(bumped, faults=faults, **kwargs)
                carry = {}
            if not rep.feasible:
                return SimulationReport.infeasible(self._dyn_label(labels))

            # -- merge the segment into the whole-run view --
            for rec in rep.records or []:
                self._merge_into(merged, rec, orig[rec.rid])
            for rid, (_, _, prec) in carry.items():
                # partial progress of requests still in flight at the stop
                if prec is not None:
                    self._merge_into(merged, prec, orig[rid])
            total_energy += rep.total_energy
            iterations += rep.iterations
            preemptions += rep.preemptions
            peak_kv = max(peak_kv, rep.peak_kv_tokens)
            peak_batch = max(peak_batch, rep.peak_batch)
            swap_outs += rep.swap_outs
            swap_ins += rep.swap_ins
            kv_swap_s += rep.kv_swap_s
            kv_refetch_s += rep.kv_refetch_s
            adm_rej += rep.admission_rejected
            adm_def += rep.admission_deferred
            prev_end = rep.e2e_latency
            end_time = max(end_time, rep.e2e_latency)
            util.append((max(rep.e2e_latency - start, 0.0),
                         rep.mfu, rep.mbu))
            prev_idx = pidx

        # requests still unfinished after the final segment (migrate mode
        # never stops the last segment, so this is empty there; defensive)
        records = [m for m in merged.values() if m.finish_time > 0.0]
        records.sort(key=lambda r: r.rid)
        total_time = max([end_time] + [r.finish_time for r in records]) \
            if records or end_time else 0.0
        gen_tokens = sum(r.gen_len for r in records)
        wsum = sum(w for w, _, _ in util)
        mfu = sum(w * m for w, m, _ in util) / wsum if wsum > 0 else 0.0
        mbu = sum(w * b for w, _, b in util) / wsum if wsum > 0 else 0.0

        return SimulationReport(
            plan_label=self._dyn_label(labels),
            e2e_latency=total_time,
            total_energy=total_energy,
            throughput_tok_s=gen_tokens / total_time if total_time else 0.0,
            mfu=mfu, mbu=mbu,
            iterations=iterations,
            preemptions=preemptions,
            peak_kv_tokens=peak_kv,
            peak_batch=peak_batch,
            feasible=True,
            records=records if keep_records else None,
            swap_outs=swap_outs, swap_ins=swap_ins,
            kv_swap_s=kv_swap_s, kv_refetch_s=kv_refetch_s,
            admission_rejected=adm_rej,
            admission_deferred=adm_def,
            reconfig=reconfig,
            windows=windowed_metrics(records, boundaries=starts,
                                     horizon=total_time),
            **request_metrics(records, total_time))

    def _dyn_label(self, labels: List[Tuple[float, str]]) -> str:
        if len(labels) == 1:
            return f"dyn-{self.mechanism}[{labels[0][1]}]"
        return (f"dyn-{self.mechanism}["
                + " | ".join(f"{t:g}s:{lab}" for t, lab in labels) + "]")
