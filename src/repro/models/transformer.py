"""Unified decoder model covering the assigned LM-family architectures.

Design notes
------------
* **Layer-stacked scan**: parameters for the repeating block are stacked on
  a leading ``block_repeat`` axis and iterated with ``jax.lax.scan``.  The
  lowered HLO is O(1) in depth — a 48-layer Gemma3 and a synthetic
  trillion-parameter model compile in the same time (the XLA-level mirror
  of APEX's Transformer-IR block extrapolation).
* **Pure functions over dict pytrees** — no framework.  ``init_params``,
  ``forward`` (training / prefill), ``prefill`` (forward + KV-cache
  population) and ``decode_step`` (one token vs. cache) are the entire
  public surface, shared by the trainer, the serving engine, and the
  multi-pod dry-run.
* **Heterogeneous blocks**: the block pattern interleaves attention and SSD
  layers (Gemma3 local:global, Zamba2 hybrid); Zamba2's shared attention
  block has ONE weight set applied once per repeat (weights live outside
  the scanned pytree).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.layers import (gqa_attention, gqa_decode_step, init_attention,
                          init_mamba2, init_mla, init_mlp, init_moe,
                          mamba2_decode_step, mamba2_forward, mla_attention,
                          mla_decode_step, mlp_forward, moe_forward,
                          rms_norm)
from repro.layers.attention import blockwise_attention
from .config import LayerSpec, ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def ring_size(window: int, multiple: int = 16) -> int:
    """Sliding-window ring-cache size: window+1 rounded up for sharding."""
    return -(-(window + 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(rng, cfg: ModelConfig, spec: LayerSpec,
                dense_ffn: bool = False) -> dict:
    dt = _dtype(cfg)
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {"norm1": jnp.ones((cfg.d_model,), dt)}
    if spec.kind == "ssm":
        p["mixer"] = init_mamba2(k1, cfg.d_model, cfg.d_inner, cfg.d_state,
                                 cfg.n_ssd_heads, cfg.d_conv,
                                 cfg.n_ssm_groups, dtype=dt)
        return p
    if cfg.attn_kind == "mla":
        p["attn"] = init_mla(k1, cfg.d_model, cfg.n_heads, cfg.kv_lora_rank,
                             cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                             cfg.v_head_dim, dtype=dt)
    else:
        p["attn"] = init_attention(k1, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.resolved_head_dim,
                                   cfg.qkv_bias, dtype=dt)
    if cfg.cross_attn:
        p["xattn"] = init_attention(k2, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.resolved_head_dim,
                                    dtype=dt)
        p["norm_x"] = jnp.ones((cfg.d_model,), dt)
    if cfg.ffn_kind != "none":
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        if cfg.ffn_kind == "moe" and not dense_ffn:
            p["ffn"] = init_moe(k3, cfg.d_model, cfg.d_ff_expert,
                                cfg.n_routed, cfg.top_k, cfg.n_shared,
                                cfg.ffn_gated, dtype=dt)
        else:
            d_ff = cfg.d_ff_dense_first if dense_ffn and \
                cfg.d_ff_dense_first else cfg.d_ff
            p["ffn"] = init_mlp(k3, cfg.d_model, d_ff, cfg.ffn_gated,
                                dtype=dt)
    return p


def init_params(rng, cfg: ModelConfig) -> dict:
    """Build the full parameter pytree.  Block params are stacked on a
    leading ``block_repeat`` axis for lax.scan."""
    cfg.validate()
    dt = _dtype(cfg)
    k_emb, k_blocks, k_shared, k_head, k_pre = jax.random.split(rng, 5)
    params: dict = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model))
                  * (1.0 / math.sqrt(cfg.d_model))).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab_size))
            * (1.0 / math.sqrt(cfg.d_model))).astype(dt)

    def init_block(rng_b, dense_ffn=False):
        keys = jax.random.split(rng_b, len(cfg.block_pattern))
        return {f"l{i}": _init_layer(keys[i], cfg, spec, dense_ffn)
                for i, spec in enumerate(cfg.block_pattern)}

    # prefix blocks (DeepSeek first-k-dense) are NOT scanned
    n_prefix = cfg.first_k_dense
    if n_prefix:
        pk = jax.random.split(k_pre, n_prefix)
        params["prefix"] = [init_block(pk[i], dense_ffn=True)
                            for i in range(n_prefix)]

    n_scan = cfg.block_repeat - n_prefix
    if n_scan <= 0:
        raise ValueError("first_k_dense must be < block_repeat")
    bkeys = jax.random.split(k_blocks, n_scan)
    blocks = [init_block(bkeys[i]) for i in range(n_scan)]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)

    if cfg.shared_attn:
        s1, s2 = jax.random.split(k_shared)
        params["shared"] = {
            "norm1": jnp.ones((cfg.d_model,), dt),
            "attn": init_attention(s1, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.resolved_head_dim,
                                   dtype=dt),
            "norm2": jnp.ones((cfg.d_model,), dt),
            "mlp": init_mlp(s2, cfg.d_model, cfg.shared_d_ff or cfg.d_ff,
                            cfg.ffn_gated, dtype=dt),
        }
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# forward (training / prefill math)
# ---------------------------------------------------------------------------

def _ffn_apply(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if "router" in p:          # MoE params
        return moe_forward(p, x, cfg.top_k)
    return mlp_forward(p, x)


def _layer_apply(cfg: ModelConfig, spec: LayerSpec, p: dict, x: jnp.ndarray,
                 positions: jnp.ndarray,
                 enc_memory: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    if spec.kind == "ssm":
        return x + mamba2_forward(p["mixer"], rms_norm(x, p["norm1"]),
                                  d_inner=cfg.d_inner, d_state=cfg.d_state,
                                  n_heads=cfg.n_ssd_heads,
                                  n_groups=cfg.n_ssm_groups)
    h = rms_norm(x, p["norm1"])
    # Archs whose head count doesn't divide the TP axis (qwen2-0.5b: 14,
    # qwen1.5-32b: 40, qwen2-vl: 28) keep attention projections replicated;
    # distribute the attention compute by resharding the BATCH over
    # ("data","model") instead (no-op off-mesh / when indivisible).
    from repro.layers.hints import data_axis_names, mesh_axis_size, \
        shard_hint
    m_sz = mesh_axis_size("model")
    reshard = m_sz > 1 and cfg.n_heads % m_sz != 0
    if reshard:
        daxes = data_axis_names()
        h = shard_hint(h, daxes + ("model",), None, None)
    if cfg.attn_kind == "mla":
        attn = mla_attention(p["attn"], h, positions,
                             n_heads=cfg.n_heads,
                             kv_lora_rank=cfg.kv_lora_rank,
                             qk_nope_head_dim=cfg.qk_nope_head_dim,
                             qk_rope_head_dim=cfg.qk_rope_head_dim,
                             v_head_dim=cfg.v_head_dim,
                             rope_theta=cfg.rope_theta)
    else:
        attn = gqa_attention(p["attn"], h, positions,
                             n_heads=cfg.n_heads,
                             n_kv_heads=cfg.n_kv_heads,
                             head_dim=cfg.resolved_head_dim,
                             window=spec.window, rope=cfg.rope,
                             rope_theta=cfg.rope_theta)
    if reshard:
        attn = shard_hint(attn, data_axis_names() or None, None, None)
    x = x + attn
    if cfg.cross_attn and enc_memory is not None:
        hx = rms_norm(x, p["norm_x"])
        B, S, _ = hx.shape
        hd = cfg.resolved_head_dim
        q = (hx @ p["xattn"]["wq"]).reshape(B, S, cfg.n_heads, hd)
        Se = enc_memory.shape[1]
        k = (enc_memory @ p["xattn"]["wk"]).reshape(B, Se, cfg.n_kv_heads, hd)
        v = (enc_memory @ p["xattn"]["wv"]).reshape(B, Se, cfg.n_kv_heads, hd)
        out = blockwise_attention(q, k, v, causal=False)
        x = x + out.reshape(B, S, cfg.n_heads * hd) @ p["xattn"]["wo"]
    if cfg.ffn_kind != "none":
        x = x + _ffn_apply(cfg, p["ffn"], rms_norm(x, p["norm2"]))
    return x


def _shared_apply(cfg: ModelConfig, shared: dict,
                  x: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(x, shared["norm1"])
    x = x + gqa_attention(shared["attn"], h, positions,
                          n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                          head_dim=cfg.resolved_head_dim, rope=cfg.rope,
                          rope_theta=cfg.rope_theta)
    return x + mlp_forward(shared["mlp"], rms_norm(x, shared["norm2"]))


def forward(params: dict, cfg: ModelConfig,
            tokens: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            enc_memory: Optional[jnp.ndarray] = None,
            remat: bool = False,
            return_hidden: bool = False) -> jnp.ndarray:
    """Full-sequence forward -> logits (B, S, vocab).

    ``tokens``: (B, S) int32 — or ``embeds``: (B, S, d_model) for stubbed
    modality frontends (VLM patches / audio frames).
    ``positions``: (B, S) or (B, S, 3) for M-RoPE; defaults to arange.
    ``remat``: activation-checkpoint each block (training memory policy).
    ``return_hidden``: return final-norm hidden states instead of logits
    (lets the trainer chunk the LM-head matmul + loss over the sequence).
    """
    if embeds is None:
        x = params["embed"][tokens]
    else:
        x = embeds.astype(_dtype(cfg))
    B, S = x.shape[:2]
    if positions is None:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        if cfg.rope == "mrope":
            pos = jnp.broadcast_to(pos[..., None], (B, S, 3))
        positions = pos

    for blk in params.get("prefix", []):
        for i, spec in enumerate(cfg.block_pattern):
            x = _layer_apply(cfg, spec, blk[f"l{i}"], x, positions,
                             enc_memory)

    shared = params.get("shared")

    # nested per-layer checkpoints only pay off for multi-layer blocks
    # (gemma3's 6-deep pattern): with a single-layer block they re-remat
    # the identical region, re-running every TP collective a third time
    # (~+50% all-reduce traffic, measured on mixtral train_4k — §Perf).
    nest_remat = remat and len(cfg.block_pattern) > 1

    def block_body(x, blk):
        for i, spec in enumerate(cfg.block_pattern):
            if nest_remat:
                layer_fn = jax.checkpoint(
                    functools.partial(_layer_apply, cfg, spec))
                x = layer_fn(blk[f"l{i}"], x, positions, enc_memory)
            else:
                x = _layer_apply(cfg, spec, blk[f"l{i}"], x, positions,
                                 enc_memory)
        if shared is not None:
            x = _shared_apply(cfg, shared, x, positions)
        return x, None

    body = jax.checkpoint(block_body) if remat else block_body
    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = rms_norm(x, params["final_norm"])
    if return_hidden:
        return x
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return x @ head


# ---------------------------------------------------------------------------
# KV / state cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               source_len: int = 0, cache_dtype=None) -> dict:
    """All-zero cache pytree.  Layout per scanned repeat (leading R axis):
    attention -> k/v (R, B, Smax, Hkv, D); MLA -> latent + rope-key; SSM ->
    fp32 state + conv window.  ``len``: (B,) valid lengths.

    ``cache_dtype``: KV storage dtype — e.g. jnp.float8_e4m3fn for the
    fp8-KV-cache serving mode (paper §2.5's KV quantization; required for
    qwen1.5-32b decode_32k to fit a 256-chip v5e pod, see EXPERIMENTS.md).
    """
    dt = jnp.dtype(cache_dtype) if cache_dtype is not None else _dtype(cfg)
    R = cfg.block_repeat - cfg.first_k_dense
    hd = cfg.resolved_head_dim

    def layer_cache(spec: LayerSpec, lead=(R,)) -> dict:
        if spec.kind == "ssm":
            P = cfg.d_inner // cfg.n_ssd_heads
            gn = cfg.n_ssm_groups * cfg.d_state
            return {
                "ssm": jnp.zeros(lead + (batch, cfg.n_ssd_heads, P,
                                         cfg.d_state), jnp.float32),
                "conv_x": jnp.zeros(lead + (batch, cfg.d_conv - 1,
                                            cfg.d_inner), dt),
                "conv_bc": jnp.zeros(lead + (batch, cfg.d_conv - 1, 2 * gn),
                                     dt),
            }
        if cfg.attn_kind == "mla":
            c = {
                "c_kv": jnp.zeros(lead + (batch, max_len, cfg.kv_lora_rank),
                                  dt),
                "k_pe": jnp.zeros(lead + (batch, max_len,
                                          cfg.qk_rope_head_dim), dt),
            }
        else:
            # ring caches are rounded up to a multiple of 16 so the
            # sequence dim shards cleanly over the model axis (a 4097-slot
            # ring would replicate: measured as the dominant collective
            # term of the mixtral decode cells). The ring then retains up
            # to ring-1 >= window past tokens — a window enlarged by < 16
            # tokens, documented in DESIGN.md.
            kv_len = max_len if spec.window is None \
                else min(max_len, ring_size(spec.window))
            c = {
                "k": jnp.zeros(lead + (batch, kv_len, cfg.n_kv_heads, hd),
                               dt),
                "v": jnp.zeros(lead + (batch, kv_len, cfg.n_kv_heads, hd),
                               dt),
            }
        if cfg.cross_attn:
            c["xk"] = jnp.zeros(lead + (batch, source_len, cfg.n_kv_heads,
                                        hd), dt)
            c["xv"] = jnp.zeros(lead + (batch, source_len, cfg.n_kv_heads,
                                        hd), dt)
        return c

    cache = {
        "blocks": {f"l{i}": layer_cache(spec)
                   for i, spec in enumerate(cfg.block_pattern)},
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if cfg.first_k_dense:
        cache["prefix"] = [
            {f"l{i}": layer_cache(spec, lead=())
             for i, spec in enumerate(cfg.block_pattern)}
            for _ in range(cfg.first_k_dense)]
    if cfg.shared_attn:
        cache["shared"] = {
            "k": jnp.zeros((cfg.block_repeat, batch, max_len,
                            cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((cfg.block_repeat, batch, max_len,
                            cfg.n_kv_heads, hd), dt),
        }
    return cache


# ---------------------------------------------------------------------------
# decode step (serving)
# ---------------------------------------------------------------------------

def _layer_decode(cfg: ModelConfig, spec: LayerSpec, p: dict, x: jnp.ndarray,
                  lc: dict, cache_len: jnp.ndarray) -> Tuple[jnp.ndarray,
                                                             dict]:
    new_lc = dict(lc)
    if spec.kind == "ssm":
        h = rms_norm(x, p["norm1"])
        y, st, cv = mamba2_decode_step(
            p["mixer"], h, lc["ssm"],
            {"x": lc["conv_x"], "bc": lc["conv_bc"]},
            d_inner=cfg.d_inner, d_state=cfg.d_state,
            n_heads=cfg.n_ssd_heads, n_groups=cfg.n_ssm_groups)
        new_lc["ssm"] = st
        new_lc["conv_x"], new_lc["conv_bc"] = cv["x"], cv["bc"]
        return x + y, new_lc
    h = rms_norm(x, p["norm1"])
    if cfg.attn_kind == "mla":
        y, cc, ck = mla_decode_step(p["attn"], h, lc["c_kv"], lc["k_pe"],
                                    cache_len, n_heads=cfg.n_heads,
                                    kv_lora_rank=cfg.kv_lora_rank,
                                    qk_nope_head_dim=cfg.qk_nope_head_dim,
                                    qk_rope_head_dim=cfg.qk_rope_head_dim,
                                    v_head_dim=cfg.v_head_dim,
                                    rope_theta=cfg.rope_theta)
        new_lc["c_kv"], new_lc["k_pe"] = cc, ck
    else:
        # sliding-window caches are ring buffers (see gqa_decode_step)
        y, ck, cv = gqa_decode_step(
            p["attn"], h, lc["k"], lc["v"], cache_len,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, window=spec.window,
            rope=cfg.rope, rope_theta=cfg.rope_theta)
        new_lc["k"], new_lc["v"] = ck, cv
    x = x + y
    if cfg.cross_attn and "xk" in lc:
        hx = rms_norm(x, p["norm_x"])
        B = hx.shape[0]
        hd = cfg.resolved_head_dim
        rep = cfg.n_heads // cfg.n_kv_heads
        q = (hx @ p["xattn"]["wq"]).reshape(B, 1, cfg.n_heads, hd)
        kr = jnp.repeat(lc["xk"], rep, axis=2)
        vr = jnp.repeat(lc["xv"], rep, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                       preferred_element_type=jnp.float32) \
            / math.sqrt(hd)
        pattn = jax.nn.softmax(s, axis=-1).astype(vr.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", pattn, vr)
        x = x + out.reshape(B, 1, cfg.n_heads * hd) @ p["xattn"]["wo"]
    if cfg.ffn_kind != "none":
        x = x + _ffn_apply(cfg, p["ffn"], rms_norm(x, p["norm2"]))
    return x, new_lc


def decode_step(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: dict,
                embeds: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, dict]:
    """One serving step: (B, 1) token ids (or embeds) + cache -> logits
    (B, vocab), updated cache."""
    if embeds is None:
        x = params["embed"][tokens]
    else:
        x = embeds.astype(_dtype(cfg))
    cache_len = cache["len"]
    new_cache = {"len": cache_len + 1}

    if "prefix" in cache:
        new_cache["prefix"] = []
        for blk, pc in zip(params["prefix"], cache["prefix"]):
            npc = {}
            for i, spec in enumerate(cfg.block_pattern):
                x, npc[f"l{i}"] = _layer_decode(cfg, spec, blk[f"l{i}"], x,
                                                pc[f"l{i}"], cache_len)
            new_cache["prefix"].append(npc)

    shared = params.get("shared")
    shared_cache = cache.get("shared")

    def block_body(carry, inp):
        x = carry
        if shared is not None:
            blk, cblk, sck, scv = inp
        else:
            blk, cblk = inp
        ncblk = {}
        for i, spec in enumerate(cfg.block_pattern):
            x, ncblk[f"l{i}"] = _layer_decode(cfg, spec, blk[f"l{i}"], x,
                                              cblk[f"l{i}"], cache_len)
        if shared is not None:
            h = rms_norm(x, shared["norm1"])
            y, nk, nv = gqa_decode_step(
                shared["attn"], h, sck, scv, cache_len,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope=cfg.rope,
                rope_theta=cfg.rope_theta)
            x = x + y
            x = x + mlp_forward(shared["mlp"], rms_norm(x, shared["norm2"]))
            return x, (ncblk, nk, nv)
        return x, ncblk

    if shared is not None:
        xs = (params["blocks"], cache["blocks"], shared_cache["k"],
              shared_cache["v"])
        x, (ncb, nk, nv) = jax.lax.scan(block_body, x, xs)
        new_cache["blocks"] = ncb
        new_cache["shared"] = {"k": nk, "v": nv}
    else:
        x, ncb = jax.lax.scan(block_body, x, (params["blocks"],
                                              cache["blocks"]))
        new_cache["blocks"] = ncb

    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return (x @ head)[:, 0, :], new_cache


# ---------------------------------------------------------------------------
# prefill: forward + cache population (serving engine)
# ---------------------------------------------------------------------------

def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            max_len: int, embeds: Optional[jnp.ndarray] = None,
            lengths: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, dict]:
    """Run the prompt through the model and build the cache by replaying
    tokens through ``decode_step`` via scan (token-parallel prefill is an
    optimization of the serving engine; correctness-first here, and the
    per-token path reuses the exact decode math the engine serves with).

    tokens: (B, S) right-padded; lengths: (B,) true lengths.
    Returns (last-token logits (B, vocab), populated cache).
    """
    if cfg.cross_attn:
        raise ValueError("encoder-decoder models prefill via "
                         "repro.models.encdec.encdec_prefill")
    B, S = tokens.shape[:2]
    cache = init_cache(cfg, B, max_len)
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)

    def step(cache, t):
        tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
        if embeds is not None:
            emb = jax.lax.dynamic_slice_in_dim(embeds, t, 1, axis=1)
            logits, cache = decode_step(params, cfg, tok, cache, embeds=emb)
        else:
            logits, cache = decode_step(params, cfg, tok, cache)
        return cache, logits

    cache, all_logits = jax.lax.scan(step, cache, jnp.arange(S))
    # cache["len"] advanced S times; clamp to true lengths
    cache["len"] = lengths
    last = jnp.take_along_axis(
        all_logits, (lengths - 1)[None, :, None], axis=0)[0]
    return last, cache
