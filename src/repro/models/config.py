"""Model configuration schema for the zoo.

One ``ModelConfig`` describes every assigned architecture: dense GQA
transformers, MoE (Mixtral / DeepSeek-MLA), sliding-window + local:global
patterns (Gemma3), M-RoPE VLM backbones (Qwen2-VL), pure SSM (Mamba2),
hybrid SSM+shared-attention (Zamba2), and encoder-decoder audio backbones
(Seamless-M4T).  The block pattern mirrors the Transformer IR's
block-of-cells structure (core/ir.py) — ``to_ir()`` is the IR converter for
zoo models.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer slot inside the repeating block."""
    kind: str = "attn"                 # "attn" | "ssm"
    window: Optional[int] = None       # sliding-window size for attn


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for encoder-decoder models (frontend is stubbed:
    inputs arrive as precomputed frame/patch embeddings)."""
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    gated: bool = False                # Seamless uses plain FFN


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    vocab_size: int
    # block structure: pattern of layers repeated `block_repeat` times
    block_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    block_repeat: int = 1
    # attention
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: Optional[int] = None     # default d_model // n_heads
    qkv_bias: bool = False
    rope: str = "rope"                 # "rope" | "mrope" | "none"
    rope_theta: float = 10000.0
    attn_kind: str = "gqa"             # "gqa" | "mla"
    # MLA dims (DeepSeek-V2)
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # FFN
    d_ff: int = 0
    ffn_gated: bool = True
    ffn_kind: str = "dense"            # "dense" | "moe" | "none"
    # MoE
    n_routed: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 0             # DeepSeek: first k layers use dense FFN
    d_ff_dense_first: int = 0
    # SSM (Mamba2)
    d_inner: int = 0
    d_state: int = 0
    n_ssd_heads: int = 0
    d_conv: int = 4
    n_ssm_groups: int = 1
    # Zamba2-style shared attention block (one weight set reused per repeat)
    shared_attn: bool = False
    shared_d_ff: int = 0
    # embeddings / head
    tie_embeddings: bool = False
    # encoder-decoder
    encoder: Optional[EncoderConfig] = None
    cross_attn: bool = False           # decoder layers attend to enc memory
    cross_source_len: int = 1024       # nominal encoder length for the IR
    # modality frontend stub: model consumes embeddings, not token ids
    embeds_input: bool = False
    dtype: str = "bfloat16"

    # -- derived -------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_layers(self) -> int:
        n = len(self.block_pattern) * self.block_repeat
        if self.shared_attn:
            n += self.block_repeat          # one shared block per repeat
        return n

    @property
    def windows(self) -> tuple:
        return tuple(sorted({s.window for s in self.block_pattern
                             if s.kind == "attn"},
                            key=lambda w: (w is None, w)))

    def validate(self) -> None:
        hd = self.resolved_head_dim
        if self.attn_kind == "gqa" and any(s.kind == "attn"
                                           for s in self.block_pattern):
            if self.n_heads % self.n_kv_heads:
                raise ValueError("n_heads must divide by n_kv_heads")
        if self.ffn_kind == "moe" and (not self.n_routed or not self.top_k):
            raise ValueError("moe config incomplete")
        if any(s.kind == "ssm" for s in self.block_pattern):
            if not (self.d_inner and self.d_state and self.n_ssd_heads):
                raise ValueError("ssm config incomplete")
            if self.d_inner % self.n_ssd_heads:
                raise ValueError("d_inner must divide n_ssd_heads")
        del hd

    # -- Transformer IR conversion (core/ir.py) ------------------------------

    def to_ir(self):
        """Convert to the APEX Transformer IR (the paper's §3.2.1)."""
        from repro.core import ir as IR
        cells = []
        for i, spec in enumerate(self.block_pattern):
            if spec.kind == "ssm":
                cells.append(IR.SSMCell(
                    name=f"ssm{i}", d_model=self.d_model,
                    d_inner=self.d_inner, d_state=self.d_state,
                    n_ssd_heads=self.n_ssd_heads, d_conv=self.d_conv,
                    n_groups=self.n_ssm_groups))
                continue
            if self.attn_kind == "mla":
                cells.append(IR.MLACell(
                    name=f"mla{i}", d_model=self.d_model,
                    n_heads=self.n_heads, kv_lora_rank=self.kv_lora_rank,
                    qk_nope_head_dim=self.qk_nope_head_dim,
                    qk_rope_head_dim=self.qk_rope_head_dim,
                    v_head_dim=self.v_head_dim))
            else:
                cells.append(IR.AttentionCell(
                    name=f"attn{i}", d_model=self.d_model,
                    n_heads=self.n_heads, n_kv_heads=self.n_kv_heads,
                    head_dim=self.resolved_head_dim,
                    qkv_bias=self.qkv_bias, window=spec.window,
                    rope=self.rope))
            if self.cross_attn:
                cells.append(IR.CrossAttentionCell(
                    name=f"xattn{i}", d_model=self.d_model,
                    n_heads=self.n_heads, n_kv_heads=self.n_kv_heads,
                    head_dim=self.resolved_head_dim,
                    source_len=self.cross_source_len))
            if self.ffn_kind == "moe":
                cells.append(IR.MoECell(
                    name=f"moe{i}", d_model=self.d_model,
                    d_ff_expert=self.d_ff_expert, n_routed=self.n_routed,
                    top_k=self.top_k, n_shared=self.n_shared,
                    gated=self.ffn_gated))
            elif self.ffn_kind == "dense":
                cells.append(IR.MLPCell(
                    name=f"mlp{i}", d_model=self.d_model, d_ff=self.d_ff,
                    gated=self.ffn_gated))
        if self.shared_attn:
            cells.append(IR.AttentionCell(
                name="shared_attn", d_model=self.d_model,
                n_heads=self.n_heads, n_kv_heads=self.n_kv_heads,
                head_dim=self.resolved_head_dim))
            cells.append(IR.MLPCell(
                name="shared_mlp", d_model=self.d_model,
                d_ff=self.shared_d_ff or self.d_ff, gated=self.ffn_gated))
        block = IR.Block(cells=tuple(cells), repeat=self.block_repeat)
        enc = None
        if self.encoder is not None:
            e = self.encoder
            enc = IR.Block(cells=(
                IR.AttentionCell(name="enc_attn", d_model=e.d_model,
                                 n_heads=e.n_heads, n_kv_heads=e.n_heads,
                                 head_dim=e.d_model // e.n_heads),
                IR.MLPCell(name="enc_mlp", d_model=e.d_model, d_ff=e.d_ff,
                           gated=e.gated),
            ), repeat=e.n_layers)
        return IR.ModelIR(name=self.name, d_model=self.d_model,
                          vocab_size=self.vocab_size, block=block,
                          tie_embeddings=self.tie_embeddings, encoder=enc)
