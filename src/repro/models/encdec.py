"""Encoder-decoder model (Seamless-M4T v2 backbone).

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_src, d_model).  The encoder is a
bidirectional transformer (self-attn + FFN); the decoder is the unified
TransformerLM with ``cross_attn=True`` so every decoder layer attends to
the encoder memory.

Serving flow:  encode() once per request -> encdec_prefill() populates the
decoder cache (incl. per-layer cross-K/V projected from the memory once —
the cross-attention cache is computed exactly once, the enc-dec analogue of
prefix KV) -> decode_step() per output token.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.layers import init_attention, init_mlp, mlp_forward, rms_norm
from repro.layers.attention import blockwise_attention
from .config import EncoderConfig, ModelConfig
from . import transformer as T


def init_encoder(rng, enc: EncoderConfig, dtype=jnp.bfloat16) -> dict:
    k_blocks, = jax.random.split(rng, 1)
    keys = jax.random.split(k_blocks, enc.n_layers)

    def layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": jnp.ones((enc.d_model,), dtype),
            "attn": init_attention(k1, enc.d_model, enc.n_heads,
                                   enc.n_heads, enc.d_model // enc.n_heads,
                                   dtype=dtype),
            "norm2": jnp.ones((enc.d_model,), dtype),
            "mlp": init_mlp(k2, enc.d_model, enc.d_ff, gated=enc.gated,
                            dtype=dtype),
        }

    layers = [layer(keys[i]) for i in range(enc.n_layers)]
    return {
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
        "final_norm": jnp.ones((enc.d_model,), dtype),
    }


def init_encdec_params(rng, cfg: ModelConfig) -> dict:
    if cfg.encoder is None or not cfg.cross_attn:
        raise ValueError("encdec model needs cfg.encoder and cfg.cross_attn")
    k_enc, k_dec = jax.random.split(rng)
    params = T.init_params(k_dec, cfg)
    params["encoder"] = init_encoder(k_enc, cfg.encoder,
                                     dtype=jnp.dtype(cfg.dtype))
    return params


def encode(params: dict, cfg: ModelConfig, frames: jnp.ndarray,
           remat: bool = False) -> jnp.ndarray:
    """Bidirectional encoder over stubbed frame embeddings.
    frames: (B, S_src, d_model) -> memory (B, S_src, d_model)."""
    enc = cfg.encoder
    hd = enc.d_model // enc.n_heads
    x = frames.astype(jnp.dtype(cfg.dtype))

    def body(x, lp):
        h = rms_norm(x, lp["norm1"])
        B, S, _ = h.shape
        q = (h @ lp["attn"]["wq"]).reshape(B, S, enc.n_heads, hd)
        k = (h @ lp["attn"]["wk"]).reshape(B, S, enc.n_heads, hd)
        v = (h @ lp["attn"]["wv"]).reshape(B, S, enc.n_heads, hd)
        out = blockwise_attention(q, k, v, causal=False)
        x = x + out.reshape(B, S, enc.n_heads * hd) @ lp["attn"]["wo"]
        x = x + mlp_forward(lp["mlp"], rms_norm(x, lp["norm2"]))
        return x, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["encoder"]["layers"])
    return rms_norm(x, params["encoder"]["final_norm"])


def encdec_forward(params: dict, cfg: ModelConfig, frames: jnp.ndarray,
                   tokens: jnp.ndarray) -> jnp.ndarray:
    """Training forward: encode frames, decode target tokens -> logits."""
    memory = encode(params, cfg, frames)
    return T.forward(params, cfg, tokens=tokens, enc_memory=memory)


def _project_cross_kv(params: dict, cfg: ModelConfig,
                      memory: jnp.ndarray) -> Tuple:
    """Per-layer cross K/V from the encoder memory, computed ONCE."""
    hd = cfg.resolved_head_dim
    B, Se, _ = memory.shape

    def per_block(blk):
        out = {}
        for i in range(len(cfg.block_pattern)):
            xp = blk[f"l{i}"]["xattn"]
            out[f"l{i}"] = {
                "xk": (memory @ xp["wk"]).reshape(B, Se, cfg.n_kv_heads, hd),
                "xv": (memory @ xp["wv"]).reshape(B, Se, cfg.n_kv_heads, hd),
            }
        return out

    # vmap over the stacked repeat axis of the decoder blocks
    return jax.vmap(per_block)(params["blocks"])


def encdec_prefill(params: dict, cfg: ModelConfig, frames: jnp.ndarray,
                   bos_tokens: jnp.ndarray, max_len: int
                   ) -> Tuple[jnp.ndarray, dict, jnp.ndarray]:
    """Serve-side prefill: encode + build decoder cache with cross-K/V.

    bos_tokens: (B, 1) decoder start tokens.
    Returns (first logits (B, vocab), cache, memory)."""
    memory = encode(params, cfg, frames)
    B = frames.shape[0]
    cache = T.init_cache(cfg, B, max_len, source_len=memory.shape[1])
    cross = _project_cross_kv(params, cfg, memory)
    for i in range(len(cfg.block_pattern)):
        cache["blocks"][f"l{i}"]["xk"] = cross[f"l{i}"]["xk"]
        cache["blocks"][f"l{i}"]["xv"] = cross[f"l{i}"]["xv"]
    logits, cache = T.decode_step(params, cfg, bos_tokens, cache)
    return logits, cache, memory


def encdec_decode_step(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                       cache: dict) -> Tuple[jnp.ndarray, dict]:
    """One decoder step (cross-K/V already in the cache)."""
    return T.decode_step(params, cfg, tokens, cache)
