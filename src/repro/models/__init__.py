"""Model zoo: unified decoder LM + encoder-decoder, configured per arch."""

from .config import EncoderConfig, LayerSpec, ModelConfig
from .transformer import (decode_step, forward, init_cache, init_params,
                          param_count, prefill)
from .encdec import (encdec_decode_step, encdec_forward, encdec_prefill,
                     encode, init_encdec_params)

__all__ = [
    "EncoderConfig", "LayerSpec", "ModelConfig", "decode_step",
    "encdec_decode_step", "encdec_forward", "encdec_prefill", "encode",
    "forward", "init_cache", "init_encdec_params", "init_params",
    "param_count", "prefill",
]
