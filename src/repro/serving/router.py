"""Multi-replica request routing: decayed shortest-queue dispatch.

Model-level DP in serving = independent replicas; the router spreads
arrivals by estimated backlog (queued prompt+gen tokens), the simple and
robust straggler-mitigation policy at fleet scale: a slow replica
naturally accumulates backlog and stops receiving work.

Backlog is *decayed* with arrival-time gaps: each replica drains work at an
estimated rate while the clock advances, so a request arriving after a long
quiet period sees near-empty queues instead of the sum of everything ever
routed (the old monotonic-accumulation bug, which effectively degraded this
router to round-robin-by-token-count for late arrivals).

``PoolRouter`` extends the same policy to disaggregated prefill/decode
deployments: each request is dispatched twice — its prompt to a prefill
replica (cost = prompt tokens) and its generation to a decode replica
(cost = gen tokens) — with independently decayed backlogs per pool.  The
disaggregated simulator (repro/disagg/simulate.py) uses the same balancer
for pool-internal replica routing, so simulated and real dispatch agree.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple

if TYPE_CHECKING:  # real-engine types only; keeps this module jax-free
    from .engine import EngineReport, ServingEngine


def derive_drain_rate(tokens_per_iter: float, iter_seconds: float,
                      fallback: float) -> float:
    """Tokens/s one replica retires, from a measured (or simulated)
    iteration: the principled way to size a ``BacklogBalancer``'s decay.
    The disaggregated simulator derives each pool's rate from its own
    iteration cost on a trace-representative workload (replacing the old
    hard-coded 4096/512 constants); ``fallback`` covers degenerate
    measurements (zero/negative duration)."""
    if iter_seconds > 0.0 and tokens_per_iter > 0.0:
        return tokens_per_iter / iter_seconds
    return fallback


class BacklogBalancer:
    """Least-estimated-backlog assignment with time-based drain decay.

    ``drain_rate`` is the estimated tokens/s one replica retires; between
    consecutive dispatches the recorded backlog of every replica decays by
    ``elapsed * drain_rate`` (floored at zero).  The default is deliberately
    conservative — underestimating drain only makes the balancer more
    eager to spread load, never starves a replica: prefer a measured rate
    via ``derive_drain_rate`` when an iteration-cost model is at hand.
    """

    def __init__(self, num_replicas: int, drain_rate: float = 512.0):
        if num_replicas < 1:
            raise ValueError("need at least one replica")
        self.backlog = [0.0] * num_replicas
        self.last_time = 0.0
        self.drain_rate = drain_rate

    def assign(self, arrival: float, cost: float) -> int:
        """Route one request of ``cost`` tokens arriving at ``arrival``."""
        dt = max(0.0, arrival - self.last_time)
        if dt > 0.0:
            drained = dt * self.drain_rate
            self.backlog = [max(0.0, b - drained) for b in self.backlog]
            self.last_time = arrival
        i = min(range(len(self.backlog)), key=lambda j: self.backlog[j])
        self.backlog[i] += cost
        return i


def _req_fields(r) -> Tuple[float, float, float]:
    """(arrival, prompt_tokens, gen_tokens) from a dict or Request."""
    if isinstance(r, dict):
        return r["arrival"], float(len(r["prompt"])), float(r["gen_len"])
    return r.arrival, float(r.context_len), float(r.gen_len)


class ReplicaRouter:
    def __init__(self, engines: List["ServingEngine"],
                 drain_rate: float = 512.0):
        if not engines:
            raise ValueError("need at least one replica")
        self.engines = engines
        self.drain_rate = drain_rate

    def split(self, requests: Sequence) -> List[List]:
        """Assign requests (sorted by arrival) to replicas by least
        decayed-estimated backlog."""
        bal = BacklogBalancer(len(self.engines), self.drain_rate)
        buckets: List[List] = [[] for _ in self.engines]
        for r in sorted(requests, key=lambda r: _req_fields(r)[0]):
            arrival, prompt, gen = _req_fields(r)
            buckets[bal.assign(arrival, prompt + gen)].append(r)
        return buckets

    def run(self, requests: List[dict],
            time_scale: float = 1.0) -> List["EngineReport"]:
        return [eng.run(bucket, time_scale=time_scale)
                for eng, bucket in zip(self.engines, self.split(requests))]


class PoolRouter:
    """Pool-aware dispatch for disaggregated prefill/decode serving.

    Splits a replica fleet into a prefill pool and a decode pool and
    routes each request twice: prompt work to the prefill pool, generation
    work to the decode pool.  Pools are sized in *replicas*; the physical
    pool split (devices, parallel schemes, KV handoff) is modeled by
    repro/disagg — this class only decides who runs what.
    """

    def __init__(self, num_prefill: int, num_decode: int,
                 prefill_drain_rate: float = 4096.0,
                 decode_drain_rate: float = 512.0):
        if num_prefill < 1 or num_decode < 1:
            raise ValueError("each pool needs at least one replica")
        self.num_prefill = num_prefill
        self.num_decode = num_decode
        self.prefill_drain_rate = prefill_drain_rate
        self.decode_drain_rate = decode_drain_rate

    def split(self, requests: Sequence
              ) -> Tuple[List[List], List[List]]:
        """(prefill_buckets, decode_buckets): per-replica request lists.

        The same request object appears once in each pool — prefill
        replicas run its prompt, decode replicas its generation.
        """
        pre = BacklogBalancer(self.num_prefill, self.prefill_drain_rate)
        dec = BacklogBalancer(self.num_decode, self.decode_drain_rate)
        pre_buckets: List[List] = [[] for _ in range(self.num_prefill)]
        dec_buckets: List[List] = [[] for _ in range(self.num_decode)]
        for r in sorted(requests, key=lambda r: _req_fields(r)[0]):
            arrival, prompt, gen = _req_fields(r)
            pre_buckets[pre.assign(arrival, prompt)].append(r)
            dec_buckets[dec.assign(arrival, gen)].append(r)
        return pre_buckets, dec_buckets
