"""Multi-replica request router: shortest-queue dispatch.

Model-level DP in serving = independent replicas; the router spreads
arrivals by estimated backlog (queued prompt+gen tokens), the simple and
robust straggler-mitigation policy at fleet scale: a slow replica
naturally accumulates backlog and stops receiving work.
"""

from __future__ import annotations

from typing import List

from .engine import EngineReport, ServingEngine


class ReplicaRouter:
    def __init__(self, engines: List[ServingEngine]):
        if not engines:
            raise ValueError("need at least one replica")
        self.engines = engines

    def split(self, requests: List[dict]) -> List[List[dict]]:
        """Assign requests (sorted by arrival) to replicas by least
        estimated backlog."""
        backlog = [0.0] * len(self.engines)
        buckets: List[List[dict]] = [[] for _ in self.engines]
        for r in sorted(requests, key=lambda r: r["arrival"]):
            i = min(range(len(backlog)), key=lambda j: backlog[j])
            buckets[i].append(r)
            backlog[i] += len(r["prompt"]) + r["gen_len"]
        return buckets

    def run(self, requests: List[dict],
            time_scale: float = 1.0) -> List[EngineReport]:
        return [eng.run(bucket, time_scale=time_scale)
                for eng, bucket in zip(self.engines, self.split(requests))]
