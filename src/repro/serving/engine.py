"""Continuous-batching serving engine (real execution, any backend).

Implements iteration-level batching over a slot-based KV cache:

  * ``max_batch`` slots share one cache pytree; each slot holds one active
    request (its KV rows + length counter);
  * admission is greedy on free slots AND free KV-token budget — exactly
    the Batching Module's policy (core/batching.py), including preemption
    of the most-recently-admitted request when the token budget overflows;
  * each engine iteration runs ONE jitted decode step over all slots
    (inactive slots are masked); prefill populates a request's slot via the
    token-replay prefill;
  * arrivals are honored in VIRTUAL time: the clock advances by measured
    step wall-times, and a request joins the queue once the virtual clock
    passes its arrival stamp.  This makes CPU-scale fidelity runs directly
    comparable with the simulator's virtual-clock results (Fig. 6/7).

Checkpointable: ``snapshot()``/``restore()`` capture queued + in-flight
request state so a restarted replica replays its work (fault tolerance).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass
class _Slot:
    rid: int = -1
    prompt: Optional[np.ndarray] = None
    gen_len: int = 0
    generated: int = 0
    order: int = -1
    arrival: float = 0.0
    first_token_t: Optional[float] = None
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def active(self) -> bool:
        return self.rid >= 0

    @property
    def kv_tokens(self) -> int:
        if not self.active:
            return 0
        return len(self.prompt) + self.generated


@dataclasses.dataclass
class RequestResult:
    rid: int
    arrival: float
    ttft: float
    tpot: float
    e2e: float
    tokens: List[int]
    preemptions: int = 0


@dataclasses.dataclass
class EngineReport:
    results: List[RequestResult]
    total_time: float
    iterations: int
    preemptions: int

    @property
    def ttft_mean(self) -> float:
        return float(np.mean([r.ttft for r in self.results]))

    @property
    def tpot_mean(self) -> float:
        ts = [r.tpot for r in self.results if r.tpot > 0]
        return float(np.mean(ts)) if ts else 0.0

    @property
    def throughput(self) -> float:
        toks = sum(len(r.tokens) for r in self.results)
        return toks / self.total_time if self.total_time else 0.0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 512, kv_token_budget: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.kv_budget = kv_token_budget or (max_batch * max_len)
        self.slots = [_Slot() for _ in range(max_batch)]
        self.cache = T.init_cache(cfg, max_batch, max_len)
        self.queue: List[dict] = []
        self._order = 0
        self.preemptions = 0
        def _step(p, t, c):
            logits, c2 = T.decode_step(p, cfg, t, c)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), c2

        self._decode = jax.jit(_step)

    # -- fault tolerance -------------------------------------------------------

    def snapshot(self) -> dict:
        """Scheduler state for checkpoint/restart: queued + in-flight
        requests (in-flight ones will re-prefill after restore)."""
        inflight = [dict(rid=s.rid, prompt=s.prompt, gen_len=s.gen_len,
                         arrival=s.arrival)
                    for s in self.slots if s.active]
        return {"queue": list(self.queue), "inflight": inflight}

    def restore(self, snap: dict) -> None:
        self.queue = list(snap["queue"]) + list(snap["inflight"])
        self.queue.sort(key=lambda r: r["arrival"])
        self.slots = [_Slot() for _ in range(self.max_batch)]
        self.cache = T.init_cache(self.cfg, self.max_batch, self.max_len)

    # -- scheduling ------------------------------------------------------------

    def _kv_used(self) -> int:
        return sum(s.kv_tokens for s in self.slots)

    def _admit(self, now: float, records: Dict[int, RequestResult]) -> None:
        while self.queue and self.queue[0]["arrival"] <= now:
            req = self.queue[0]
            free = [i for i, s in enumerate(self.slots) if not s.active]
            if not free:
                break
            if self._kv_used() + len(req["prompt"]) > self.kv_budget:
                break
            self.queue.pop(0)
            i = free[0]
            self.slots[i] = _Slot(rid=req["rid"],
                                  prompt=np.asarray(req["prompt"]),
                                  gen_len=req["gen_len"], order=self._order,
                                  arrival=req["arrival"])
            self._order += 1
            self._prefill_slot(i)

    def _prefill_slot(self, i: int) -> None:
        """Replay the prompt through the jitted decode step (correctness-
        first prefill; the whole batch's other slots ride along masked)."""
        s = self.slots[i]
        lens = np.array(jax.device_get(self.cache["len"]))
        lens[i] = 0
        self.cache["len"] = jnp.asarray(lens)
        for t in range(len(s.prompt)):
            toks = np.zeros((self.max_batch, 1), np.int32)
            toks[i, 0] = s.prompt[t]
            logits_tok, cache = self._decode(self.params,
                                             jnp.asarray(toks), self.cache)
            # only slot i's length may advance
            new_len = np.array(jax.device_get(cache["len"]))
            keep = np.array(jax.device_get(self.cache["len"]))
            keep[i] = new_len[i]
            cache["len"] = jnp.asarray(keep)
            self.cache = cache
        s.generated = 1
        first = int(jax.device_get(logits_tok)[i])
        s.tokens.append(first)

    def _evict_most_recent(self) -> None:
        cand = [s for s in self.slots if s.active]
        if not cand:
            return
        victim = max(cand, key=lambda s: s.order)
        idx = self.slots.index(victim)
        self.queue.insert(0, dict(rid=victim.rid, prompt=victim.prompt,
                                  gen_len=victim.gen_len,
                                  arrival=victim.arrival))
        self.preemptions += 1
        self.slots[idx] = _Slot()

    # -- main loop -------------------------------------------------------------

    def run(self, requests: List[dict],
            time_scale: float = 1.0) -> EngineReport:
        """Serve ``requests`` (dicts: rid, arrival, prompt, gen_len).

        ``time_scale`` compresses arrival stamps (CPU runs are slow; the
        fidelity benchmark scales both simulator and engine identically).
        """
        self.queue = sorted(
            (dict(r, arrival=r["arrival"] * time_scale) for r in requests),
            key=lambda r: r["arrival"])
        records: Dict[int, RequestResult] = {}
        meta = {r["rid"]: dict(arrival=r["arrival"] * time_scale,
                               first=None, start=None) for r in requests}
        now = 0.0
        iters = 0
        while self.queue or any(s.active for s in self.slots):
            t0 = time.perf_counter()
            self._admit(now, records)
            active = [i for i, s in enumerate(self.slots) if s.active]
            if not active:
                if self.queue:
                    now = max(now, self.queue[0]["arrival"])
                    continue
                break
            # mark TTFT for freshly prefilled requests
            for i in active:
                s = self.slots[i]
                if s.first_token_t is None and s.generated >= 1:
                    s.first_token_t = now

            toks = np.zeros((self.max_batch, 1), np.int32)
            for i in active:
                toks[i, 0] = self.slots[i].tokens[-1]
            nxt, cache = self._decode(self.params, jnp.asarray(toks),
                                      self.cache)
            nxt = np.array(jax.device_get(nxt))
            # inactive slots must not advance their length counters
            new_len = np.array(jax.device_get(cache["len"]))
            old_len = np.array(jax.device_get(self.cache["len"]))
            mask = np.zeros(self.max_batch, bool)
            mask[active] = True
            new_len = np.where(mask, new_len, old_len)
            cache["len"] = jnp.asarray(new_len)
            self.cache = cache
            step_t = time.perf_counter() - t0
            now += step_t
            iters += 1

            for i in active:
                s = self.slots[i]
                s.tokens.append(int(nxt[i]))
                s.generated += 1
                if s.generated >= s.gen_len or s.kv_tokens >= self.max_len - 1:
                    ttft = (s.first_token_t or now) - s.arrival
                    denom = max(s.generated - 1, 1)
                    records[s.rid] = RequestResult(
                        rid=s.rid, arrival=s.arrival, ttft=ttft,
                        tpot=(now - (s.first_token_t or now)) / denom,
                        e2e=now - s.arrival, tokens=list(s.tokens))
                    self.slots[i] = _Slot()
            # KV budget enforcement (greedy batching can overshoot)
            while self._kv_used() > self.kv_budget:
                self._evict_most_recent()

        return EngineReport(results=list(records.values()), total_time=now,
                            iterations=iters, preemptions=self.preemptions)
