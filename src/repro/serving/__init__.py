"""Real JAX serving engine: continuous batching over slot-based KV caches.

Mirrors the APEX Batching Module's semantics (core/batching.py) so
prediction-vs-reality fidelity experiments (paper Fig. 6/7) compare like
for like.
"""

from .engine import EngineReport, ServingEngine
from .router import ReplicaRouter

__all__ = ["EngineReport", "ReplicaRouter", "ServingEngine"]
