"""Real JAX serving engine: continuous batching over slot-based KV caches.

Mirrors the APEX Batching Module's semantics (core/batching.py) so
prediction-vs-reality fidelity experiments (paper Fig. 6/7) compare like
for like.

``ServingEngine``/``EngineReport`` are imported lazily (PEP 562): the
router and the disaggregated simulator only need the jax-free dispatch
logic, so importing this package must not pay the JAX startup cost.
"""

from .router import BacklogBalancer, PoolRouter, ReplicaRouter

__all__ = ["BacklogBalancer", "EngineReport", "PoolRouter", "ReplicaRouter",
           "ServingEngine"]


def __getattr__(name):
    if name in ("EngineReport", "ServingEngine"):
        from . import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
