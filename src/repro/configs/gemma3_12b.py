"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt scaled; unverified tier]

Block = 5 local (sliding-window 1024) + 1 global layer, repeated 8x (the
smallest non-repetitive cell chain — the Transformer-IR block).  long_500k
RUNS for this arch: 5/6 of layers have window-bounded KV (ring caches), so
decode memory is sub-quadratic-dominated; the global layers' KV is
mesh-sharded (see DESIGN.md §long_500k).
"""

from repro.models.config import LayerSpec, ModelConfig

_LOCAL = LayerSpec(kind="attn", window=1024)
_GLOBAL = LayerSpec(kind="attn", window=None)

FULL = ModelConfig(
    name="gemma3-12b",
    d_model=3840,
    vocab_size=262144,
    block_pattern=(_LOCAL,) * 5 + (_GLOBAL,),
    block_repeat=8,                       # 48 layers
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    ffn_gated=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="gemma3-reduced",
    d_model=96,
    vocab_size=512,
    block_pattern=(LayerSpec("attn", window=16),) * 2
    + (LayerSpec("attn", None),),
    block_repeat=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=24,
    d_ff=256,
    tie_embeddings=True,
)
