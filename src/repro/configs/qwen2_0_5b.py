"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936, QKV bias, tied embeddings.  [arXiv:2407.10671; hf]

Pure full attention -> long_500k SKIPPED.  Note the awkward head count
(14 heads, kv=2): TP degrees are restricted to divisors of 14 for the
attention cell — the planner handles this via cell-level DP (DESIGN.md
§Arch-applicability).
"""

from repro.models.config import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="qwen2-0.5b",
    d_model=896,
    vocab_size=151936,
    block_pattern=(LayerSpec("attn"),),
    block_repeat=24,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    qkv_bias=True,
    d_ff=4864,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="qwen2-0.5b-reduced",
    d_model=56,
    vocab_size=512,
    block_pattern=(LayerSpec("attn"),),
    block_repeat=2,
    n_heads=7,
    n_kv_heads=1,
    head_dim=8,
    qkv_bias=True,
    d_ff=128,
    tie_embeddings=True,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md rule)"}
