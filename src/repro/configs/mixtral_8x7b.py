"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff(expert)=14336,
8 experts top-2, sliding-window attention (4096), vocab=32000.
[arXiv:2401.04088; hf]

SWA bounds every layer's KV to the window -> long_500k RUNS (ring caches).
This arch is the paper's own EP-vs-TP study vehicle (Fig. 6).
"""

from repro.models.config import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="mixtral-8x7b",
    d_model=4096,
    vocab_size=32000,
    block_pattern=(LayerSpec("attn", window=4096),),
    block_repeat=32,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    ffn_kind="moe",
    n_routed=8,
    top_k=2,
    d_ff_expert=14336,
    d_ff=14336,
)

REDUCED = ModelConfig(
    name="mixtral-reduced",
    d_model=64,
    vocab_size=512,
    block_pattern=(LayerSpec("attn", window=16),),
    block_repeat=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    ffn_kind="moe",
    n_routed=4,
    top_k=2,
    d_ff_expert=96,
    d_ff=96,
)
