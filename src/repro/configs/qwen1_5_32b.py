"""qwen1.5-32b [dense] — 64L d_model=5120 40H (GQA kv=40 => MHA)
d_ff=27392 vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-32B; hf tier]

Pure full attention -> long_500k SKIPPED.
"""

from repro.models.config import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="qwen1.5-32b",
    d_model=5120,
    vocab_size=152064,
    block_pattern=(LayerSpec("attn"),),
    block_repeat=64,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    qkv_bias=True,
    d_ff=27392,
)

REDUCED = ModelConfig(
    name="qwen1.5-32b-reduced",
    d_model=80,
    vocab_size=512,
    block_pattern=(LayerSpec("attn"),),
    block_repeat=2,
    n_heads=5,
    n_kv_heads=5,
    head_dim=16,
    qkv_bias=True,
    d_ff=256,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md rule)"}
