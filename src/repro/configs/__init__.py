"""Assigned-architecture registry: one module per architecture, each
exporting ``FULL`` (the exact assigned dims) and ``REDUCED`` (a same-family
miniature for CPU smoke tests), plus optional shape-skip notes.

Input-shape cells (applied per arch; see launch/shapes.py):
    train_4k     seq 4096  x global_batch 256   (train_step)
    prefill_32k  seq 32768 x global_batch 32    (prefill)
    decode_32k   seq 32768 x global_batch 128   (serve_step, 1 new token)
    long_500k    seq 524288 x global_batch 1    (serve_step, sub-quadratic
                                                 archs only — see DESIGN.md)
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCHS: List[str] = [
    "gemma3_12b",
    "internlm2_1_8b",
    "qwen2_0_5b",
    "qwen1_5_32b",
    "deepseek_v2_lite_16b",
    "mixtral_8x7b",
    "qwen2_vl_7b",
    "mamba2_2_7b",
    "zamba2_7b",
    "seamless_m4t_large_v2",
]

# canonical ids as given in the assignment -> module names
ALIASES = {
    "gemma3-12b": "gemma3_12b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen1.5-32b": "qwen1_5_32b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "zamba2-7b": "zamba2_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}


def _module(name: str):
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    return _module(name).FULL


def get_reduced(name: str) -> ModelConfig:
    return _module(name).REDUCED


def shape_skips(name: str) -> Dict[str, str]:
    """shape id -> reason, for cells this arch skips (DESIGN.md rules)."""
    return getattr(_module(name), "SKIP_SHAPES", {})


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
