"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H, MLA (kv_lora=512),
MoE: 64 routed experts top-6 + 2 shared, expert d_ff=1408, vocab=102400.
First layer uses a dense FFN (d_ff=10944), per the released config.
[arXiv:2405.04434; hf]

MLA's latent KV cache is NOT head-sharded: the TP template shards query
heads / up-projections and replicates the 512-rank latent (DESIGN.md
§Arch-applicability).  MLA is still full attention over the sequence ->
long_500k SKIPPED.
"""

from repro.models.config import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-lite-16b",
    d_model=2048,
    vocab_size=102400,
    block_pattern=(LayerSpec("attn"),),
    block_repeat=27,
    n_heads=16,
    attn_kind="mla",
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    ffn_kind="moe",
    n_routed=64,
    top_k=6,
    n_shared=2,
    d_ff_expert=1408,
    first_k_dense=1,
    d_ff_dense_first=10944,
    d_ff=1408,
)

REDUCED = ModelConfig(
    name="deepseek-v2-lite-reduced",
    d_model=64,
    vocab_size=512,
    block_pattern=(LayerSpec("attn"),),
    block_repeat=3,
    n_heads=4,
    attn_kind="mla",
    kv_lora_rank=32,
    qk_nope_head_dim=16,
    qk_rope_head_dim=8,
    v_head_dim=16,
    ffn_kind="moe",
    n_routed=8,
    top_k=2,
    n_shared=1,
    d_ff_expert=48,
    first_k_dense=1,
    d_ff_dense_first=96,
    d_ff=48,
)

SKIP_SHAPES = {"long_500k":
               "MLA latent cache is compressed but attention is still full "
               "(DESIGN.md rule)"}
