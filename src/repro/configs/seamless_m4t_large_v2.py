"""seamless-m4t-large-v2 [audio] — enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.  [arXiv:2308.11596; hf]

Transformer BACKBONE only: the speech frontend is a stub — input_specs()
provides precomputed frame embeddings for the encoder.  Decoder layers are
self-attn + cross-attn + FFN (plain, non-gated).  Full attention + enc-dec
audio operating regime -> long_500k SKIPPED (DESIGN.md).
"""

from repro.models.config import EncoderConfig, LayerSpec, ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-large-v2",
    d_model=1024,
    vocab_size=256206,
    block_pattern=(LayerSpec("attn"),),
    block_repeat=24,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    ffn_gated=False,
    cross_attn=True,
    cross_source_len=1024,
    encoder=EncoderConfig(n_layers=24, d_model=1024, n_heads=16, d_ff=8192),
    embeds_input=False,
)

REDUCED = ModelConfig(
    name="seamless-reduced",
    d_model=64,
    vocab_size=512,
    block_pattern=(LayerSpec("attn"),),
    block_repeat=2,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    ffn_gated=False,
    cross_attn=True,
    cross_source_len=32,
    encoder=EncoderConfig(n_layers=2, d_model=64, n_heads=4, d_ff=128),
)

SKIP_SHAPES = {
    "long_500k": "enc-dec audio model, full attention; 500k-token target "
                 "decode is outside its operating regime (DESIGN.md rule)",
}
