"""mamba2-2.7b [ssm] — 64L d_model=2560 attention-free, d_inner=5120,
ssm_state=128, 80 SSD heads (head_dim 64), vocab=50280.
[arXiv:2405.21060; unverified tier]

Attention-free: the APEX attention templates are inert; TP shards the SSD
inner dimension/heads, the KV memory model is replaced by the O(1) SSM
state model.  long_500k RUNS (the flagship case for SSM serving).
"""

from repro.models.config import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="mamba2-2.7b",
    d_model=2560,
    vocab_size=50280,
    block_pattern=(LayerSpec("ssm"),),
    block_repeat=64,
    d_inner=5120,
    d_state=128,
    n_ssd_heads=80,
    d_conv=4,
    ffn_kind="none",
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="mamba2-reduced",
    d_model=64,
    vocab_size=512,
    block_pattern=(LayerSpec("ssm"),),
    block_repeat=3,
    d_inner=128,
    d_state=16,
    n_ssd_heads=4,
    d_conv=4,
    ffn_kind="none",
    tie_embeddings=True,
)
