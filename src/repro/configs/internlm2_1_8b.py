"""internlm2-1.8b [dense] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544.  [arXiv:2403.17297; hf]

Pure full attention -> long_500k is SKIPPED (quadratic-regime artifact;
see DESIGN.md §long_500k).
"""

from repro.models.config import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="internlm2-1.8b",
    d_model=2048,
    vocab_size=92544,
    block_pattern=(LayerSpec("attn"),),
    block_repeat=24,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
)

REDUCED = ModelConfig(
    name="internlm2-reduced",
    d_model=64,
    vocab_size=512,
    block_pattern=(LayerSpec("attn"),),
    block_repeat=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=192,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md rule)"}
