"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064, M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

Transformer BACKBONE only: the vision frontend is a stub — input_specs()
provides precomputed patch embeddings (B, S, d_model) plus (t, h, w)
M-RoPE position ids.  Pure full attention -> long_500k SKIPPED.
"""

from repro.models.config import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-7b",
    d_model=3584,
    vocab_size=152064,
    block_pattern=(LayerSpec("attn"),),
    block_repeat=28,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    qkv_bias=True,
    d_ff=18944,
    rope="mrope",
    embeds_input=True,
)

REDUCED = ModelConfig(
    name="qwen2-vl-reduced",
    d_model=56,
    vocab_size=512,
    block_pattern=(LayerSpec("attn"),),
    block_repeat=2,
    n_heads=7,
    n_kv_heads=1,
    head_dim=8,
    qkv_bias=True,
    d_ff=128,
    rope="mrope",
    embeds_input=True,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (DESIGN.md rule)"}
