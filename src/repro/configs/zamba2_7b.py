"""zamba2-7b [hybrid] — 81L d_model=3584, Mamba2 backbone (ssm_state=64)
with a SHARED GQA attention block (32H kv=32, d_ff=14336) applied once per
repeat.  [arXiv:2411.15242; unverified tier]

We model the 81 layers as 6 Mamba2 layers x 13 repeats (78) + 13
applications of ONE shared attention+MLP block (weights tied across
repeats — the Zamba2 signature).  Cell-level DP is disabled for the shared
block: replicating it would break the weight tying (DESIGN.md
§Arch-applicability).  Hybrid -> long_500k RUNS.
"""

from repro.models.config import LayerSpec, ModelConfig

FULL = ModelConfig(
    name="zamba2-7b",
    d_model=3584,
    vocab_size=32000,
    block_pattern=(LayerSpec("ssm"),) * 6,
    block_repeat=13,
    d_inner=7168,
    d_state=64,
    n_ssd_heads=64,            # head_dim 112
    d_conv=4,
    ffn_kind="none",
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    shared_attn=True,
    shared_d_ff=14336,
    d_ff=14336,
)

REDUCED = ModelConfig(
    name="zamba2-reduced",
    d_model=64,
    vocab_size=512,
    block_pattern=(LayerSpec("ssm"),) * 2,
    block_repeat=2,
    d_inner=128,
    d_state=16,
    n_ssd_heads=4,
    d_conv=4,
    ffn_kind="none",
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    shared_attn=True,
    shared_d_ff=128,
    d_ff=128,
)
