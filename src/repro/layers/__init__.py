"""Pure-JAX neural net layers used by the model zoo.

All layers are pure functions over parameter pytrees (dicts of jnp arrays);
no framework (flax/haiku) dependency.  Shapes follow (batch, seq, dim)
unless stated.  Perf-critical inner loops (attention, SSD scan) have Pallas
TPU kernels in repro.kernels; these layers call the ops.py dispatchers,
which fall back to the pure-jnp reference on CPU.
"""

from .norms import layer_norm, rms_norm
from .rope import apply_mrope, apply_rope, rope_angles
from .attention import (gqa_attention, gqa_decode_step, init_attention,
                        init_mla, mla_attention, mla_decode_step)
from .mlp import init_mlp, mlp_forward
from .moe import init_moe, moe_forward
from .ssm import init_mamba2, mamba2_decode_step, mamba2_forward

__all__ = [
    "apply_mrope", "apply_rope", "gqa_attention", "gqa_decode_step",
    "init_attention", "init_mamba2", "init_mla", "init_moe", "init_mlp",
    "layer_norm", "mamba2_decode_step", "mamba2_forward", "mla_attention",
    "mla_decode_step", "mlp_forward", "moe_forward", "rms_norm",
    "rope_angles",
]
