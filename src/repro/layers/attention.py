"""Attention layers: GQA (w/ sliding window, QKV bias, RoPE/M-RoPE) and
DeepSeek-style MLA (multi-head latent attention).

Two execution paths:
  * ``*_attention``   — full-sequence (training / prefill).  Uses a
    blockwise online-softmax implementation (`blockwise_attention`) so the
    S x S score matrix is never materialized — mandatory for the 32k-prefill
    dry-run shapes, and the same tiling the Pallas kernel
    (repro/kernels/flash_attention) implements in VMEM.
  * ``*_decode_step`` — one new token against a KV cache (serving).

Parameters are plain dicts of jnp arrays; init fns take explicit dims.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .rope import apply_mrope, apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(rng, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qkv_bias: bool = False,
                   dtype=jnp.bfloat16) -> dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d_model)
    p = {
        "wq": (jax.random.normal(k1, (d_model, n_heads * head_dim)) * s
               ).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, n_kv_heads * head_dim)) * s
               ).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, n_kv_heads * head_dim)) * s
               ).astype(dtype),
        "wo": (jax.random.normal(k4, (n_heads * head_dim, d_model))
               * (1.0 / math.sqrt(n_heads * head_dim))).astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def init_mla(rng, d_model: int, n_heads: int, kv_lora_rank: int,
             qk_nope_head_dim: int = 128, qk_rope_head_dim: int = 64,
             v_head_dim: int = 128, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    qk_head = qk_nope_head_dim + qk_rope_head_dim
    s = 1.0 / math.sqrt(d_model)
    return {
        "wq": (jax.random.normal(k1, (d_model, n_heads * qk_head)) * s
               ).astype(dtype),
        "wdkv": (jax.random.normal(
            k2, (d_model, kv_lora_rank + qk_rope_head_dim)) * s
        ).astype(dtype),
        "wukv": (jax.random.normal(
            k3, (kv_lora_rank, n_heads * (qk_nope_head_dim + v_head_dim)))
            * (1.0 / math.sqrt(kv_lora_rank))).astype(dtype),
        "wo": (jax.random.normal(k4, (n_heads * v_head_dim, d_model))
               * (1.0 / math.sqrt(n_heads * v_head_dim))).astype(dtype),
    }


# ---------------------------------------------------------------------------
# blockwise (flash-pattern) attention — the scalable jnp path
# ---------------------------------------------------------------------------

def _tile_mask(q_pos, k_pos, causal: bool, window, Skv: int):
    """(qb, kb) mask for one tile; q_pos (qb,), k_pos (kb,)."""
    mask = (k_pos < Skv)[None, :]
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    return mask


def _blockwise_fwd_impl(q, k, v, causal, window, q_block, kv_block,
                        q_offset, skv_true):
    """Returns (out (B,Sq_p,Hq,Dv), lse (B,Hq,Sq_p)) on PADDED lengths."""
    B, Sq_p, Hq, D = q.shape
    _, Skv_p, Hkv, Dv = v.shape
    rep = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qb, kb = q_block, kv_block
    nq, nk = Sq_p // qb, Skv_p // kb

    qs = q.reshape(B, nq, qb, Hq, D).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kb, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    q_pos_base = jnp.arange(qb)
    k_pos_base = jnp.arange(kb)
    Skv_true = skv_true

    def q_block_body(args):
        qi, q_blk = args
        q_pos = q_offset + qi * qb + q_pos_base

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            k_pos = ki * kb + k_pos_base
            kr = jnp.repeat(k_blk, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, kr,
                           preferred_element_type=jnp.float32) * scale
            mask = _tile_mask(q_pos, k_pos, causal, window, Skv_true)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            vr = jnp.repeat(v_blk, rep, axis=2)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vr.dtype), vr)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hq, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hq, qb), jnp.float32)
        a0 = jnp.zeros((B, Hq, qb, Dv), v.dtype)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nk), ks, vs))
        l_safe = jnp.maximum(l, 1e-30)
        out = acc / l_safe[..., None].astype(acc.dtype)
        lse = m + jnp.log(l_safe)                         # (B,Hq,qb)
        return out.transpose(0, 2, 1, 3), lse

    outs, lses = jax.lax.map(q_block_body, (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq_p, Hq, Dv)
    lse = lses.transpose(1, 2, 0, 3).reshape(B, Hq, Sq_p)
    return out, lse


import functools as _ft


@_ft.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _blockwise_attention(q, k, v, causal, window, q_block, kv_block,
                         q_offset, skv_true):
    out, _ = _blockwise_fwd_impl(q, k, v, causal, window, q_block,
                                 kv_block, q_offset, skv_true)
    return out


def _bw_fwd(q, k, v, causal, window, q_block, kv_block, q_offset,
            skv_true):
    out, lse = _blockwise_fwd_impl(q, k, v, causal, window, q_block,
                                   kv_block, q_offset, skv_true)
    return out, (q, k, v, out, lse)


def _bw_bwd(causal, window, q_block, kv_block, q_offset, skv_true, res,
            dout):
    """Flash backward: recompute p per tile from the saved LSE — O(S)
    memory instead of autodiff-through-scan's O(S^2 / block) residuals."""
    q, k, v, out, lse = res
    B, Sq_p, Hq, D = q.shape
    _, Skv_p, Hkv, Dv = v.shape
    rep = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qb, kb = q_block, kv_block
    nq, nk = Sq_p // qb, Skv_p // kb
    Skv_true = skv_true

    # D_i = rowsum(dout * out): (B, Hq, Sq)
    Dsum = jnp.einsum("bqhd,bqhd->bhq", dout.astype(jnp.float32),
                      out.astype(jnp.float32))

    qs = q.reshape(B, nq, qb, Hq, D).transpose(1, 0, 2, 3, 4)
    dos = dout.reshape(B, nq, qb, Hq, Dv).transpose(1, 0, 2, 3, 4)
    lses = lse.reshape(B, Hq, nq, qb).transpose(2, 0, 1, 3)
    Dsums = Dsum.reshape(B, Hq, nq, qb).transpose(2, 0, 1, 3)
    ks = k.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kb, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    q_pos_base = jnp.arange(qb)
    k_pos_base = jnp.arange(kb)

    def kv_block_body(dq_acc, kv_in):
        ki, k_blk, v_blk = kv_in
        k_pos = ki * kb + k_pos_base
        kr = jnp.repeat(k_blk, rep, axis=2)               # (B,kb,Hq,D)
        vr = jnp.repeat(v_blk, rep, axis=2)

        def q_step(carry, q_in):
            dk_r, dv_r = carry
            qi, q_blk, do_blk, lse_blk, D_blk = q_in
            q_pos = q_offset + qi * qb + q_pos_base
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, kr,
                           preferred_element_type=jnp.float32) * scale
            mask = _tile_mask(q_pos, k_pos, causal, window, Skv_true)
            s = jnp.where(mask[None, None], s, NEG_INF)
            p = jnp.exp(s - lse_blk[..., None])           # (B,Hq,qb,kb)
            dv_r = dv_r + jnp.einsum("bhqk,bqhd->bkhd", p,
                                     do_blk.astype(jnp.float32))
            dp = jnp.einsum("bqhd,bkhd->bhqk",
                            do_blk.astype(jnp.float32),
                            vr.astype(jnp.float32))
            ds = p * (dp - D_blk[..., None]) * scale
            dq_blk = jnp.einsum("bhqk,bkhd->bqhd", ds,
                                kr.astype(jnp.float32))
            dk_r = dk_r + jnp.einsum("bhqk,bqhd->bkhd", ds,
                                     q_blk.astype(jnp.float32))
            return (dk_r, dv_r), dq_blk

        zero_k = jnp.zeros((B, kb, Hq, D), jnp.float32)
        zero_v = jnp.zeros((B, kb, Hq, Dv), jnp.float32)
        (dk_r, dv_r), dq_blocks = jax.lax.scan(
            q_step, (zero_k, zero_v),
            (jnp.arange(nq), qs, dos, lses, Dsums))
        # fold GQA reps back onto the kv heads
        dk_blk = dk_r.reshape(B, kb, Hkv, rep, D).sum(axis=3)
        dv_blk = dv_r.reshape(B, kb, Hkv, rep, Dv).sum(axis=3)
        dq_acc = dq_acc + dq_blocks
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((nq, B, qb, Hq, D), jnp.float32)
    dq_acc, (dk_blocks, dv_blocks) = jax.lax.scan(
        kv_block_body, dq0, (jnp.arange(nk), ks, vs))
    dq = dq_acc.transpose(1, 0, 2, 3, 4).reshape(B, Sq_p, Hq, D)
    dk = dk_blocks.transpose(1, 0, 2, 3, 4).reshape(B, Skv_p, Hkv, D)
    dv = dv_blocks.transpose(1, 0, 2, 3, 4).reshape(B, Skv_p, Hkv, Dv)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_blockwise_attention.defvjp(_bw_fwd, _bw_bwd)


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True,
                        window: Optional[int] = None,
                        q_block: int = 512, kv_block: int = 1024,
                        q_offset: int = 0) -> jnp.ndarray:
    """Online-softmax attention without materializing S_q x S_kv scores.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0 (GQA).
    ``q_offset``: absolute position of q[:, 0] relative to k[:, 0]
    (prefill: Skv - Sq when a prefix cache exists; 0 otherwise).
    Returns (B, Sq, Hq, Dv).

    Differentiable via a flash-style custom VJP (recompute-from-LSE), so
    training memory is O(S) — plain autodiff through the online-softmax
    scan would retain every (qb x kb) tile.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    qb = min(q_block, max(Sq, 1))
    kb = min(kv_block, max(Skv, 1))
    Sq_p = -(-Sq // qb) * qb
    Skv_p = -(-Skv // kb) * kb
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    if Skv_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    out = _blockwise_attention(q, k, v, causal, window, qb, kb, q_offset,
                               Skv)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def _project_qkv(params: dict, x: jnp.ndarray, n_heads: int,
                 n_kv_heads: int, head_dim: int):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return (q.reshape(B, S, n_heads, head_dim),
            k.reshape(B, S, n_kv_heads, head_dim),
            v.reshape(B, S, n_kv_heads, head_dim))


def gqa_attention(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
                  *, n_heads: int, n_kv_heads: int, head_dim: int,
                  window: Optional[int] = None, rope: str = "rope",
                  rope_theta: float = 10000.0,
                  attn_impl=blockwise_attention) -> jnp.ndarray:
    """Full-sequence GQA (training / prefill).  x: (B, S, d_model)."""
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    if rope == "rope":
        q, k = apply_rope(q, k, positions, rope_theta)
    elif rope == "mrope":
        q, k = apply_mrope(q, k, positions, theta=rope_theta)
    out = attn_impl(q, k, v, causal=True, window=window)
    B, S = x.shape[:2]
    return out.reshape(B, S, n_heads * head_dim) @ params["wo"]


def gqa_decode_step(params: dict, x: jnp.ndarray, cache_k: jnp.ndarray,
                    cache_v: jnp.ndarray, cache_len: jnp.ndarray,
                    *, n_heads: int, n_kv_heads: int, head_dim: int,
                    window: Optional[int] = None, rope: str = "rope",
                    rope_theta: float = 10000.0
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step.  x: (B, 1, d_model); cache_k/v: (B, Smax, Hkv, D);
    cache_len: (B,) ABSOLUTE sequence lengths so far.

    Sliding-window layers use a RING cache: allocate Smax == window + 1 and
    the ring then holds exactly the last `window`+1 tokens — K entries are
    RoPE-rotated at their absolute positions when written, attention scores
    need no position bookkeeping, and no further window mask is required.
    Full-attention layers use Smax == max_len (linear writes).
    Returns (y, new_k, new_v)."""
    B = x.shape[0]
    Smax = cache_k.shape[1]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    pos = cache_len[:, None]                               # (B, 1) absolute
    if rope == "rope":
        q, k = apply_rope(q, k, pos, rope_theta)
    elif rope == "mrope":
        pos3 = jnp.broadcast_to(pos[..., None], (B, 1, 3))
        q, k = apply_mrope(q, k, pos3, theta=rope_theta)
    # ring caches are allocated at window+1 rounded up to a shardable
    # multiple (models/transformer.ring_size); anything <= window+16 slots
    # is a ring. The ring retains the last Smax-1 >= window tokens.
    ring = window is not None and Smax <= window + 16
    idx = cache_len % Smax if ring else cache_len          # (B,) write slot
    cache_k = jax.vmap(
        lambda c, kk, i: jax.lax.dynamic_update_slice(
            c, kk.astype(c.dtype), (i, 0, 0))
    )(cache_k, k, idx)
    cache_v = jax.vmap(
        lambda c, vv, i: jax.lax.dynamic_update_slice(
            c, vv.astype(c.dtype), (i, 0, 0))
    )(cache_v, v, idx)

    rep = n_heads // n_kv_heads
    scale = 1.0 / math.sqrt(head_dim)
    # fp8 caches are upcast to the compute dtype on read.  The shard hints
    # pin the GQA repeat and the score matrix to the cache's SEQUENCE
    # sharding, making the softmax+readout a flash-decoding combine (psum
    # of small (B,H) stats + (B,H,D) partials) instead of a per-layer KV
    # all-gather — §Perf iteration 3 (no-ops off-mesh).
    from .hints import data_axis_names, shard_hint
    daxes = data_axis_names() or None
    kr = jnp.repeat(cache_k.astype(q.dtype), rep, axis=2)  # (B, Smax, Hq, D)
    vr = jnp.repeat(cache_v.astype(q.dtype), rep, axis=2)
    kr = shard_hint(kr, daxes, "model", None, None)
    vr = shard_hint(vr, daxes, "model", None, None)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                   preferred_element_type=jnp.float32) * scale
    s = shard_hint(s, daxes, None, None, "model")
    k_slot = jnp.arange(Smax)[None, :]                     # (1, Smax)
    n_valid = jnp.minimum(cache_len + 1, Smax)             # (B,)
    valid = k_slot < n_valid[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(vr.dtype)
    p = shard_hint(p, daxes, None, None, "model")
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr)
    y = out.reshape(B, 1, n_heads * head_dim) @ params["wo"]
    return y, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _mla_expand(params: dict, c_kv: jnp.ndarray, n_heads: int,
                qk_nope: int, v_dim: int):
    """Expand latent cache -> per-head K_nope and V.  c_kv: (B, S, r)."""
    B, S, _ = c_kv.shape
    u = c_kv @ params["wukv"]                              # (B,S,H*(dn+dv))
    u = u.reshape(B, S, n_heads, qk_nope + v_dim)
    return u[..., :qk_nope], u[..., qk_nope:]


def mla_attention(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
                  *, n_heads: int, kv_lora_rank: int,
                  qk_nope_head_dim: int = 128, qk_rope_head_dim: int = 64,
                  v_head_dim: int = 128, rope_theta: float = 10000.0,
                  attn_impl=blockwise_attention) -> jnp.ndarray:
    """Full-sequence MLA.  The latent c_kv is shared across heads; the RoPE
    key part k_pe is computed once and broadcast (DeepSeek-V2 §2.1)."""
    B, S, _ = x.shape
    qk_head = qk_nope_head_dim + qk_rope_head_dim
    q = (x @ params["wq"]).reshape(B, S, n_heads, qk_head)
    q_nope, q_pe = q[..., :qk_nope_head_dim], q[..., qk_nope_head_dim:]
    dkv = x @ params["wdkv"]                               # (B,S,r+dr)
    c_kv, k_pe = dkv[..., :kv_lora_rank], dkv[..., kv_lora_rank:]
    k_pe = k_pe[:, :, None, :]                             # (B,S,1,dr)
    q_pe, k_pe = apply_rope(q_pe, k_pe, positions, rope_theta)
    k_nope, v = _mla_expand(params, c_kv, n_heads, qk_nope_head_dim,
                            v_head_dim)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe, k_nope.shape[:3]
                                  + (qk_rope_head_dim,))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    out = attn_impl(q_full, k_full, v, causal=True, window=None)
    return out.reshape(B, S, n_heads * v_head_dim) @ params["wo"]


def mla_decode_step(params: dict, x: jnp.ndarray, cache_c: jnp.ndarray,
                    cache_kpe: jnp.ndarray, cache_len: jnp.ndarray,
                    *, n_heads: int, kv_lora_rank: int,
                    qk_nope_head_dim: int = 128, qk_rope_head_dim: int = 64,
                    v_head_dim: int = 128, rope_theta: float = 10000.0
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step with the COMPRESSED cache (the MLA memory win):
    cache_c: (B, Smax, r) latents; cache_kpe: (B, Smax, dr)."""
    B = x.shape[0]
    qk_head = qk_nope_head_dim + qk_rope_head_dim
    q = (x @ params["wq"]).reshape(B, 1, n_heads, qk_head)
    q_nope, q_pe = q[..., :qk_nope_head_dim], q[..., qk_nope_head_dim:]
    dkv = x @ params["wdkv"]
    c_new, kpe_new = dkv[..., :kv_lora_rank], dkv[..., kv_lora_rank:]
    pos = cache_len[:, None]
    q_pe, kpe_rot = apply_rope(q_pe, kpe_new[:, :, None, :], pos, rope_theta)
    cache_c = jax.vmap(
        lambda c, n, i: jax.lax.dynamic_update_slice(
            c, n.astype(c.dtype), (i, 0))
    )(cache_c, c_new, cache_len)
    cache_kpe = jax.vmap(
        lambda c, n, i: jax.lax.dynamic_update_slice(
            c, n.astype(c.dtype), (i, 0))
    )(cache_kpe, kpe_rot[:, :, 0, :], cache_len)

    # absorbed-style scoring: expand latents (simple variant; the Pallas
    # decode kernel implements the truly-absorbed matmul); fp8 caches are
    # upcast to the compute dtype on read
    k_nope, v = _mla_expand(params, cache_c.astype(x.dtype), n_heads,
                            qk_nope_head_dim, v_head_dim)  # (B,Smax,H,*)
    scale = 1.0 / math.sqrt(qk_head)
    s = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bqhd,bkd->bhqk", q_pe,
                      cache_kpe.astype(x.dtype),
                      preferred_element_type=jnp.float32)) * scale
    Smax = cache_c.shape[1]
    valid = jnp.arange(Smax)[None, :] <= cache_len[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    y = out.reshape(B, 1, n_heads * v_head_dim) @ params["wo"]
    return y, cache_c, cache_kpe
