"""Mamba2 SSD (state-space duality) mixer — attention-free sequence layer.

Implements the chunked SSD algorithm (arXiv:2405.21060): within a chunk the
computation is an attention-like matmul against a decay-masked score matrix
(the "duality"); across chunks a small recurrent state (H, P, N) is carried
by a scan.  This file is the pure-jnp reference; the Pallas TPU kernel in
repro/kernels/ssd_scan tiles the same chunk structure into VMEM.

Parameter layout is TP-friendly: the x/z input projections and the x-conv
are separate tensors column-shardable on d_inner (= SSD-head sharding, the
APEX template for SSM cells); the small B/C/dt projections and their conv
are replicated.  The output projection w_out is row-sharded -> one
all-reduce per layer, exactly the Megatron pattern.

Recurrence (per head, discretized):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t (outer) B_t
    y_t = h_t @ C_t + D * x_t
with x_t in R^P (head dim), B_t, C_t in R^N (state dim), A < 0 scalar/head.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def init_mamba2(rng, d_model: int, d_inner: int, d_state: int,
                n_heads: int, d_conv: int = 4, n_groups: int = 1,
                dtype=jnp.bfloat16) -> dict:
    if d_inner % n_heads:
        raise ValueError("d_inner must divide into n_heads")
    kx, kz, kbc, kcx, kcb, ko = jax.random.split(rng, 6)
    s = 1.0 / math.sqrt(d_model)
    gn = n_groups * d_state
    return {
        "w_x": (jax.random.normal(kx, (d_model, d_inner)) * s).astype(dtype),
        "w_z": (jax.random.normal(kz, (d_model, d_inner)) * s).astype(dtype),
        "w_bcdt": (jax.random.normal(kbc, (d_model, 2 * gn + n_heads)) * s
                   ).astype(dtype),
        "conv_x": (jax.random.normal(kcx, (d_conv, d_inner)) * 0.1
                   ).astype(dtype),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_bc": (jax.random.normal(kcb, (d_conv, 2 * gn)) * 0.1
                    ).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * gn,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), dtype),
        "w_out": (jax.random.normal(ko, (d_inner, d_model))
                  * (1.0 / math.sqrt(d_inner))).astype(dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv1d + SiLU.  x: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                b: jnp.ndarray, c: jnp.ndarray, d_skip: jnp.ndarray,
                chunk: int = 128,
                init_state: jnp.ndarray = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan (the duality algorithm).

    x : (B, S, H, P)   head inputs
    dt: (B, S, H)      softplus-activated step sizes (> 0)
    a_log: (H,)        A = -exp(a_log) < 0
    b, c: (B, S, N)    input/output projections (n_groups = 1)
    Returns (y: (B,S,H,P), final_state: (B,H,P,N) fp32).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    S_p = -(-S // Q) * Q
    pad = S_p - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nC = S_p // Q
    A = -jnp.exp(a_log)                                    # (H,) < 0

    xs = x.reshape(B, nC, Q, H, P).transpose(1, 0, 2, 3, 4)
    dts = dt.reshape(B, nC, Q, H).transpose(1, 0, 2, 3)
    bs = b.reshape(B, nC, Q, N).transpose(1, 0, 2, 3)
    cs = c.reshape(B, nC, Q, N).transpose(1, 0, 2, 3)

    def chunk_step(h0, inp):
        xc, dtc, bc, cc = inp                              # (B,Q,H,P) etc.
        la = dtc * A                                       # (B,Q,H) log-decay
        cum = jnp.cumsum(la, axis=1)                       # (B,Q,H)
        # intra-chunk duality: L[i,j] = exp(cum_i - cum_j) for j <= i.
        # Mask BEFORE the exp: exp of the (masked-out) upper triangle can
        # overflow to inf, and where(mask, inf, 0) back-propagates
        # inf * 0 = NaN into dt/A gradients.
        diff = cum[:, :, None, :] - cum[:, None, :, :]     # (B,Q,Q,H)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.exp(jnp.where(mask[None, :, :, None], diff, -1e30))
        scores = jnp.einsum("bin,bjn->bij", cc, bc)        # (B,Q,Q)
        w = scores[..., None] * L                          # (B,Q,Q,H)
        xdt = xc * dtc[..., None]                          # (B,Q,H,P)
        y_intra = jnp.einsum("bijh,bjhp->bihp",
                             w.astype(xc.dtype), xdt.astype(xc.dtype))
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bin,bhpn->bihp",
                             cc, h0.astype(cc.dtype)) \
            * jnp.exp(cum)[..., None].astype(xc.dtype)
        # new state: decayed old + chunk's own contribution
        rem = cum[:, -1:, :] - cum                         # decay i..end
        contrib = jnp.einsum(
            "bihp,bin->bhpn",
            (xdt * jnp.exp(rem)[..., None]).astype(xc.dtype), bc)
        h1 = h0 * jnp.exp(cum[:, -1, :])[:, :, None, None] + \
            contrib.astype(jnp.float32)
        return h1, y_intra + y_inter

    h_init = (jnp.zeros((B, H, P, N), jnp.float32)
              if init_state is None else init_state)
    # checkpoint the chunk body: backward keeps only the (B,H,P,N) carry
    # per chunk and recomputes the (Q,Q) duality tiles — without this the
    # scan's saved residuals are ~10x the model activations.
    h_final, ys = jax.lax.scan(jax.checkpoint(chunk_step), h_init,
                               (xs, dts, bs, cs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S_p, H, P)[:, :S]
    y = y + x[:, :S] * d_skip[None, None, :, None].astype(x.dtype)
    return y.astype(x.dtype), h_final


def _project(params: dict, x: jnp.ndarray, d_state: int, n_groups: int,
             n_heads: int):
    """Shared input projections + convs -> (z, xi, b, c, dt)."""
    gn = n_groups * d_state
    z = x @ params["w_z"]
    xi = x @ params["w_x"]
    bcdt = x @ params["w_bcdt"]
    bc, dt = bcdt[..., :2 * gn], bcdt[..., 2 * gn:]
    return z, xi, bc, dt


def mamba2_forward(params: dict, x: jnp.ndarray, *, d_inner: int,
                   d_state: int, n_heads: int, n_groups: int = 1,
                   chunk: int = 128) -> jnp.ndarray:
    """Full-sequence Mamba2 block.  x: (B, S, d_model)."""
    from .norms import rms_norm
    B, S, _ = x.shape
    P = d_inner // n_heads
    gn = n_groups * d_state
    z, xi, bc, dt = _project(params, x, d_state, n_groups, n_heads)
    xi = _causal_conv(xi, params["conv_x"], params["conv_x_b"])
    bc = _causal_conv(bc, params["conv_bc"], params["conv_bc_b"])
    b, c = bc[..., :gn], bc[..., gn:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])              # (B,S,H)
    xh = xi.reshape(B, S, n_heads, P)
    y, _ = ssd_chunked(xh, dt, params["a_log"], b, c, params["d_skip"],
                       chunk=chunk)
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"])
    return (y @ params["w_out"]).astype(x.dtype)


def mamba2_decode_step(params: dict, x: jnp.ndarray,
                       ssm_state: jnp.ndarray, conv_state: dict,
                       *, d_inner: int, d_state: int, n_heads: int,
                       n_groups: int = 1
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, dict]:
    """One decode step — O(1) in context length (the SSM serving win).

    x: (B, 1, d_model); ssm_state: (B, H, P, N) fp32;
    conv_state: {"x": (B, K-1, d_inner), "bc": (B, K-1, 2*G*N)}.
    """
    from .norms import rms_norm
    B = x.shape[0]
    P = d_inner // n_heads
    gn = n_groups * d_state
    K = params["conv_x"].shape[0]
    z, xi, bc, dt = _project(params, x, d_state, n_groups, n_heads)

    def conv_step(state, new, w, bias):
        win = jnp.concatenate([state, new], axis=1)        # (B, K, C)
        out = sum(win[:, i, :] * w[i] for i in range(K))
        return jax.nn.silu(out + bias)[:, None, :], win[:, 1:, :]

    xi, ncx = conv_step(conv_state["x"], xi, params["conv_x"],
                        params["conv_x_b"])
    bc, ncb = conv_step(conv_state["bc"], bc, params["conv_bc"],
                        params["conv_bc_b"])
    b, c = bc[..., :gn], bc[..., gn:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])
    a = jnp.exp(dt[:, 0, :] * A)                           # (B,H)
    xh = xi.reshape(B, n_heads, P)
    upd = (dt[:, 0, :, None, None]
           * xh[..., None].astype(jnp.float32)
           * b[:, 0, None, None, :].astype(jnp.float32))   # (B,H,P,N)
    new_state = ssm_state * a[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state,
                   c[:, 0].astype(jnp.float32))            # (B,H,P)
    y = y + xh.astype(jnp.float32) * params["d_skip"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"])
    return ((y @ params["w_out"]).astype(x.dtype), new_state,
            {"x": ncx, "bc": ncb})
