"""Feed-forward layers: SwiGLU (gated) and GELU (plain)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def init_mlp(rng, d_model: int, d_ff: int, gated: bool = True,
             dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {
        "w_up": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k2, (d_ff, d_model)) * s_out
                   ).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(k3, (d_model, d_ff)) * s_in
                       ).astype(dtype)
    return p


def mlp_forward(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d_model)."""
    up = x @ params["w_up"]
    if "w_gate" in params:
        h = jax.nn.silu(x @ params["w_gate"]) * up
    else:
        h = jax.nn.gelu(up)
    return h @ params["w_down"]
