"""Sharding hints usable from inside model code.

``shard_hint(x, *spec)`` applies a with_sharding_constraint iff an abstract
mesh is active (jax.sharding.set_mesh context — the launchers set it) AND
the constraint is valid for x's shape; otherwise it is the identity.  Model
code stays mesh-agnostic: on a single CPU device every hint is a no-op.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def mesh_axis_size(name: str) -> int:
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or am.empty:
            return 1
        return dict(am.shape).get(name, 1)
    except Exception:   # noqa: BLE001 — any mesh-introspection failure
        return 1


def data_axis_names() -> tuple:
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or am.empty:
            return ()
        return tuple(a for a in ("pod", "data") if a in am.shape)
    except Exception:   # noqa: BLE001
        return ()


def _sanitize(x, entries) -> P:
    """Drop PER-DIM any axis entry whose size doesn't divide the dim
    (e.g. batch=1 at long_500k must not veto the sequence sharding)."""
    out = []
    for dim, entry in zip(x.shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= mesh_axis_size(a)
        out.append(entry if (total <= 1 or dim % total == 0) else None)
    return P(*out)


def shard_hint(x, *spec_entries):
    """Best-effort with_sharding_constraint; identity when no mesh, with
    per-dimension divisibility fallback."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or am.empty:
            return x
        spec = _sanitize(x, spec_entries)
        if all(e is None for e in spec):
            return x
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:   # noqa: BLE001
        return x
