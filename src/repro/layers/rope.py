"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE.

M-RoPE (arXiv:2409.12191) splits the head dimension into three sections
rotated by (temporal, height, width) position ids.  The vision frontend is
stubbed in this repo, so position ids arrive precomputed alongside the
patch embeddings; text tokens use t == h == w (which makes M-RoPE collapse
to standard RoPE — the property tests rely on this identity).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp


def rope_angles(positions: jnp.ndarray, head_dim: int,
                theta: float = 10000.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(cos, sin) tables of shape positions.shape + (head_dim // 2,)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x: jnp.ndarray, cos: jnp.ndarray,
            sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs (x_even, x_odd) by the angle tables.

    x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim//2) —
    broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_rope(q: jnp.ndarray, k: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Standard RoPE.  q: (B, S, Hq, D), k: (B, S, Hk, D),
    positions: (B, S) absolute token positions."""
    cos, sin = rope_angles(positions, q.shape[-1], theta)
    return _rotate(q, cos, sin), _rotate(k, cos, sin)


def apply_mrope(q: jnp.ndarray, k: jnp.ndarray, positions: jnp.ndarray,
                sections: Sequence[int] = None,
                theta: float = 10000.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multimodal RoPE.  positions: (B, S, 3) = (t, h, w) ids.

    ``sections`` gives the per-axis share of head_dim//2 frequency slots
    (sums to head_dim // 2).  Default follows Qwen2-VL's 1:1.5:1.5 split
    (16, 24, 24 at head_dim 128), scaled to the actual head_dim.
    """
    head_dim = q.shape[-1]
    half = head_dim // 2
    if sections is None:
        t = half // 4
        h = (half - t) // 2
        sections = (t, h, half - t - h)
    if sum(sections) != half:
        raise ValueError(f"M-RoPE sections {sections} must sum to {half}")
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # section s of the frequency slots uses position axis s
    axis_of_slot = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections),
        total_repeat_length=half)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(axis_of_slot[None, None, :],
                         positions.shape[:2] + (half,)).astype(jnp.int32),
        axis=-1)                                   # (B, S, half)
    ang = pos * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    return _rotate(q, cos, sin), _rotate(k, cos, sin)
