"""Normalization layers (pure jnp).

RMSNorm is the serving hot path's glue op; a fused Pallas kernel lives in
repro/kernels/rmsnorm/ — this module is the canonical math used both as the
model default and as the kernel oracle.
"""

from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm over the last axis; compute in fp32, cast back."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * (var + eps) ** -0.5
    return (y * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * (var + eps) ** -0.5
    return (y * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)
