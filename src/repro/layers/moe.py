"""Mixture-of-Experts FFN: top-k routing + optional shared experts.

Dense-dispatch formulation: every expert processes every token, masked by
the routing weights.  O(E/topk) more FLOPs than a gathered implementation,
but it is fully shardable with a single einsum (experts on the "model" mesh
axis = expert parallelism under pjit) and exactly matches the gathered
result — the right trade for smoke tests, training at modest expert counts,
and the dry-run (where only the sharded HLO matters; XLA's SPMD partitioner
turns the expert einsum + masked routing into the standard EP all-to-all
pattern).  A token-dropping capacity-based gathered path is in
repro/parallel/ep.py for the serving engine.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# MoE dense-dispatch layout for non-EP-shardable expert counts; see the
# measured trade-off in moe_forward (scan-over-experts wins forward-only
# serving, chunk-major wins training backward traffic).
CHUNK_MAJOR = False


def init_moe(rng, d_model: int, d_ff_expert: int, n_routed: int,
             top_k: int, n_shared: int = 0, gated: bool = True,
             dtype=jnp.bfloat16) -> dict:
    kr, ke1, ke2, ke3, ks = jax.random.split(rng, 5)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff_expert)
    p = {
        "router": (jax.random.normal(kr, (d_model, n_routed)) * s_in
                   ).astype(jnp.float32),
        "w_up": (jax.random.normal(ke1, (n_routed, d_model, d_ff_expert))
                 * s_in).astype(dtype),
        "w_down": (jax.random.normal(ke2, (n_routed, d_ff_expert, d_model))
                   * s_out).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ke3, (n_routed, d_model, d_ff_expert))
                       * s_in).astype(dtype)
    if n_shared:
        from .mlp import init_mlp
        p["shared"] = init_mlp(ks, d_model, d_ff_expert * n_shared,
                               gated=gated, dtype=dtype)
    return p


def moe_forward(params: dict, x: jnp.ndarray, top_k: int,
                router_noise: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x: (B, S, d_model) -> (B, S, d_model).

    Routing weights are renormalized over the top-k (Mixtral convention).

    Two dense-dispatch layouts (both exact; gathered EP dispatch lives in
    parallel/ep.py):
      * expert-sharded einsum when n_routed divides the "model" mesh axis
        (DeepSeek's 64 experts / 16): one big (E,B,S,f) einsum, E sharded;
      * scan-over-experts otherwise (Mixtral's 8 experts can't shard over
        16): one expert's (B,S,f) intermediate live at a time — the einsum
        layout would put the FULL (E,B,S,f) tensor on every device.
    """
    from .hints import mesh_axis_size
    B, S, d = x.shape
    n_routed = params["router"].shape[1]
    logits = (x.astype(jnp.float32) @ params["router"])     # (B,S,E)
    if router_noise is not None:
        logits = logits + router_noise
    top_vals, top_idx = jax.lax.top_k(logits, top_k)        # (B,S,k)
    gates = jax.nn.softmax(top_vals, axis=-1)               # renormalized
    # dense dispatch mask: (B,S,E) combine weights
    combine = jnp.zeros((B, S, n_routed), jnp.float32)
    combine = jax.vmap(jax.vmap(
        lambda c, i, g: c.at[i].add(g)))(combine, top_idx, gates)

    m = mesh_axis_size("model")
    gated = "w_gate" in params
    if m > 1 and n_routed % m == 0:
        # expert-sharded einsum: (E,B,S,f) with E over "model"
        up = jnp.einsum("bsd,edf->ebsf", x, params["w_up"])
        if gated:
            gate = jnp.einsum("bsd,edf->ebsf", x, params["w_gate"])
            h = jax.nn.silu(gate) * up
        else:
            h = jax.nn.gelu(up)
        y = jnp.einsum("ebsf,efd->ebsd", h, params["w_down"])
        out = jnp.einsum("ebsd,bse->bsd", y, combine.astype(y.dtype))
    elif CHUNK_MAJOR:
        # chunk-major dense dispatch: for each TOKEN chunk, run ALL experts
        # in one stacked einsum and contract (expert, d_ff) in one step —
        # one TP all-reduce per chunk.  Measured (§Perf iteration 2a/2e):
        # 16% LESS all-reduce than scan-over-experts for TRAINING (the
        # backward can't defer per-expert psums) but 3.65x MORE for
        # forward-only prefill (XLA defers the scan layout's psums to one
        # per layer).  Serving is this system's primary regime, so
        # scan-over-experts is the default; flip CHUNK_MAJOR for
        # training-heavy deployments.
        comb_t = combine.transpose(2, 0, 1).astype(x.dtype)  # (E,B,S)
        T_tok = B * S
        ck = min(4096, T_tok)
        T_pad = -(-T_tok // ck) * ck
        xf = x.reshape(T_tok, d)
        cf = comb_t.reshape(n_routed, T_tok)
        if T_pad != T_tok:
            xf = jnp.pad(xf, ((0, T_pad - T_tok), (0, 0)))
            cf = jnp.pad(cf, ((0, 0), (0, T_pad - T_tok)))
        xc = xf.reshape(T_pad // ck, ck, d)
        cc = cf.reshape(n_routed, T_pad // ck, ck).transpose(1, 0, 2)

        w_up, w_down = params["w_up"], params["w_down"]
        w_gate = params.get("w_gate")

        def chunk_step(carry, inp):
            xk, ce = inp                        # (ck, d), (E, ck)
            up = jnp.einsum("cd,edf->ecf", xk, w_up)
            if gated:
                gt = jnp.einsum("cd,edf->ecf", xk, w_gate)
                h = jax.nn.silu(gt) * up
            else:
                h = jax.nn.gelu(up)
            h = h * ce[:, :, None]              # fold combine weights
            yk = jnp.einsum("ecf,efd->cd", h, w_down)  # ONE reduce
            return carry, yk

        _, ys = jax.lax.scan(chunk_step, 0.0, (xc, cc))
        out = ys.reshape(T_pad, d)[:T_tok].reshape(B, S, d)
    else:
        # scan-over-experts (default): one expert's WHOLE-TENSOR
        # intermediates at a time (with the d_ff dim TP-sharded these are
        # ~tokens x d_ff/16 — small), accumulated into a full-tensor carry.
        # Keeping the expert body a straight-line matmul chain (no inner
        # token-chunk loop!) lets XLA defer the per-expert partial
        # reductions to ONE all-reduce per layer in forward-only programs —
        # measured 16x less AR than a chunked body (§Perf iteration 2e).
        comb_t = combine.transpose(2, 0, 1).astype(x.dtype)  # (E,B,S)

        def expert_step(y, inp):
            if gated:
                wu, wg, wd, ce = inp
            else:
                wu, wd, ce = inp
            up = x @ wu
            h = jax.nn.silu(x @ wg) * up if gated else jax.nn.gelu(up)
            return y + (h @ wd) * ce[..., None], None

        xs = ((params["w_up"], params["w_gate"], params["w_down"], comb_t)
              if gated else (params["w_up"], params["w_down"], comb_t))
        out, _ = jax.lax.scan(expert_step, jnp.zeros_like(x), xs)
    if "shared" in params:
        from .mlp import mlp_forward
        out = out + mlp_forward(params["shared"], x)
    return out
