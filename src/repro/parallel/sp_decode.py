"""Sequence-parallel decode attention (flash-decoding combine, shard_map).

The baseline decode path stores KV caches sequence-sharded over the
"model" axis and lets GSPMD all-gather each layer's cache to compute
attention — gigabytes per step (measured in the §Roofline baseline; it is
the dominant collective term of the decode cells).  This module is the
optimized path: each shard computes a PARTIAL online-softmax over its own
KV slice and the shards combine with a log-sum-exp reduction —

    m* = pmax(m_i),  out = sum_i(acc_i * e^{m_i - m*}) / sum_i(l_i * e^{m_i - m*})

turning per-layer collective traffic from O(S * kv_dim) gathered bytes
into O(B * Hq * D) psum bytes (~4 orders of magnitude at 32k context).
TPU-native: this is the mesh-level analogue of the split-K flash-decoding
kernel; the per-shard inner loop is decode_attention's tiling.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

NEG_INF = -1e30


def _partial_softmax(q, k, v, valid):
    """Per-shard partial attention.  q: (B,Hq,D); k/v: (B,Sl,Hkv,D);
    valid: (B,Sl) bool.  Returns (m (B,Hq), l (B,Hq), acc (B,Hq,Dv))."""
    B, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    kr = jnp.repeat(k.astype(q.dtype), rep, axis=2)
    vr = jnp.repeat(v.astype(q.dtype), rep, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q, kr,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m = s.max(axis=-1)                                   # (B,Hq)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhk,bkhd->bhd", p,
                     vr.astype(jnp.float32))
    return m, l, acc


def sp_decode_attention(q: jnp.ndarray, cache_k: jnp.ndarray,
                        cache_v: jnp.ndarray, lengths: jnp.ndarray,
                        mesh: Mesh, axis: str = "model") -> jnp.ndarray:
    """q: (B, Hq, D) one token/sequence; cache_k/v: (B, Smax, Hkv, D)
    sequence-sharded over ``axis``; lengths: (B,) valid lengths.
    Returns (B, Hq, Dv)."""
    B, Hq, D = q.shape
    Smax = cache_k.shape[1]
    tp = mesh.shape[axis]
    if Smax % tp:
        raise ValueError(f"cache len {Smax} not divisible by {axis}={tp}")
    s_local = Smax // tp

    def local(q_l, k_l, v_l, lens):
        me = jax.lax.axis_index(axis)
        base = me * s_local
        slots = base + jnp.arange(s_local)[None, :]       # (1, Sl)
        valid = slots < lens[:, None]
        m, l, acc = _partial_softmax(q_l, k_l, v_l, valid)
        m_star = jax.lax.pmax(m, axis)
        alpha = jnp.exp(m - m_star)
        num = jax.lax.psum(acc * alpha[..., None], axis)
        den = jax.lax.psum(l * alpha, axis)
        out = num / jnp.maximum(den, 1e-30)[..., None]
        return out.astype(q_l.dtype)

    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dspec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(dspec, None, None), P(dspec, axis, None, None),
                  P(dspec, axis, None, None), P(dspec)),
        out_specs=P(dspec, None, None),
        check_rep=False)
    return fn(q, cache_k, cache_v, lengths)
