"""Attention-head padding: deploy-time TP alignment transform.

Several assigned archs have head counts that do not divide the 16-wide
"model" mesh axis (qwen1.5-32b: 40 q/kv heads; qwen2-vl: 28; qwen2-0.5b:
14) — their attention projections fall back to replication (see
parallel/sharding.py), costing replicated weights AND 16x-redundant
attention compute.  Padding the head count up to the next multiple of the
axis (40 -> 48) with ZERO output rows is mathematically exact:

    out = concat(head_0..head_39, pad_heads) @ [wo_real; 0] == original

(the padded heads' attention outputs are annihilated by the zero rows of
wo; q/k/v pad weights are zero so padded heads attend uniformly — finite,
no NaN).  The price is n_pad/n_heads extra attention FLOPs and KV bytes —
20% for qwen1.5 versus 1500% redundant compute without it.  Same trick
Megatron applies to vocab padding.

Used by the §Perf hillclimb and available to the launchers via
``pad_model_heads``.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _pad_dim(x: jnp.ndarray, dim: int, new: int) -> jnp.ndarray:
    pad = [(0, 0)] * x.ndim
    pad[dim] = (0, new - x.shape[dim])
    return jnp.pad(x, pad)


def pad_attention_heads(params: dict, cfg: ModelConfig, multiple: int = 16
                        ) -> Tuple[dict, ModelConfig]:
    """Zero-pad attention heads to the next multiple of ``multiple``.

    Returns (padded params, padded cfg).  No-op when already aligned.
    """
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    hq_p = -(-hq // multiple) * multiple
    hkv_p = -(-hkv // multiple) * multiple
    if hq_p == hq and hkv_p == hkv:
        return params, cfg
    if cfg.attn_kind == "mla":
        raise NotImplementedError("MLA archs are already head-aligned")

    def pad_leaf(path: str, x):
        leaf = path.rsplit("/", 1)[-1]
        stacked = path.startswith("blocks") or "/layers/" in f"/{path}/"
        off = 1 if stacked else 0
        if leaf in ("wq",):
            return _pad_dim(x, off + 1, hq_p * hd)
        if leaf in ("wk", "wv"):
            return _pad_dim(x, off + 1, hkv_p * hd)
        if leaf == "wo":
            return _pad_dim(x, off + 0, hq_p * hd)   # zero rows: exactness
        if leaf in ("bq",):
            return _pad_dim(x, off, hq_p * hd)
        if leaf in ("bk", "bv"):
            return _pad_dim(x, off, hkv_p * hd)
        return x

    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        # only pad attention-module leaves (mixer/mlp share leaf names? no)
        if "/attn/" in f"/{pstr}/" or "/xattn/" in f"/{pstr}/":
            out.append(pad_leaf(pstr, leaf))
        else:
            out.append(leaf)
    new_params = jax.tree_util.tree_unflatten(tdef, out)
    new_cfg = dataclasses.replace(cfg, n_heads=hq_p, n_kv_heads=hkv_p,
                                  head_dim=hd)
    return new_params, new_cfg


def padded_config(cfg: ModelConfig, multiple: int = 16) -> ModelConfig:
    """Config-only variant (for ShapeDtypeStruct dry-runs)."""
    hd = cfg.resolved_head_dim
    hq_p = -(-cfg.n_heads // multiple) * multiple
    hkv_p = -(-cfg.n_kv_heads // multiple) * multiple
    if hq_p == cfg.n_heads and hkv_p == cfg.n_kv_heads:
        return cfg
    return dataclasses.replace(cfg, n_heads=hq_p, n_kv_heads=hkv_p,
                               head_dim=hd)
