"""APEX plan -> JAX sharding translation (the integration point).

An APEX ``ParallelScheme`` chosen by core/search.py is materialized as a
concrete mesh + PartitionSpec trees:

  * model-level DP  -> replica axis ("data", and "pod" when present);
    requests/batches shard over it, parameters replicate.
  * TP / EP         -> "model" axis; cell shardings follow
    parallel/sharding.py's template rules (head-/column-/expert-sharding —
    the JAX realization of the paper's Fig. 5 templates).
  * PP              -> a "stage" axis consumed by parallel/pipeline.py's
    shard_map GPipe loop (GSPMD alone cannot express pipelining).
  * cell-level DP (the paper's beyond-feasible feature) -> per-cell-type
    sharding overrides: an attention cell with dp=2 x tp=4 on an 8-wide
    stage shards its heads over a 4-subgroup and replicates over the
    remaining factor — expressed by sharding over a SPLIT mesh axis.

Only DP x TP(EP) plans translate to a single pjit program; plans with
pp_stages > 1 return a pipeline descriptor instead.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.planner import ParallelScheme
from repro.models.config import ModelConfig
from .sharding import batch_pspec, cache_pspecs, param_pspecs


@dataclasses.dataclass
class MaterializedPlan:
    scheme: ParallelScheme
    mesh: Mesh
    param_specs: object
    batch_spec: P
    needs_pipeline: bool
    pp_stages: int

    def param_shardings(self, mesh: Optional[Mesh] = None):
        mesh = mesh or self.mesh
        return jax.tree.map(lambda s: NamedSharding(mesh, s),
                            self.param_specs,
                            is_leaf=lambda s: isinstance(s, P))


def plan_to_shardings(scheme: ParallelScheme, cfg: ModelConfig,
                      params, devices=None) -> MaterializedPlan:
    """Build the mesh + sharding trees realizing ``scheme``.

    ``devices``: flat list of jax devices (defaults to jax.devices()); its
    length must equal scheme.total_devices.
    """
    devices = list(devices if devices is not None else jax.devices())
    n = scheme.total_devices
    if len(devices) < n:
        raise ValueError(
            f"plan needs {n} devices, have {len(devices)} — run under the "
            "dry-run's forced host device count for large plans")
    devices = devices[:n]

    dp = scheme.model_dp
    pp = scheme.pp_stages
    tp = scheme.stage_devices
    needs_pipeline = pp > 1

    if needs_pipeline:
        import numpy as np
        arr = np.array(devices).reshape(dp, pp, tp)
        mesh = Mesh(arr, ("data", "stage", "model"))
    else:
        import numpy as np
        arr = np.array(devices).reshape(dp, tp)
        mesh = Mesh(arr, ("data", "model"))

    specs = param_pspecs(params, cfg, mesh, fsdp=False)
    return MaterializedPlan(scheme=scheme, mesh=mesh, param_specs=specs,
                            batch_spec=batch_pspec(mesh),
                            needs_pipeline=needs_pipeline, pp_stages=pp)
