"""Sharding rules: model parameter / activation / cache PartitionSpecs.

The default production layout (what the heuristic baseline plan and the
dry-run use):

  * batch        -> ("pod", "data")   (model-level DP; the pod axis is DP)
  * TP           -> "model": attention heads / MLP d_ff columns / expert
                    axis (EP-style) or expert-ff (TP-style) for MoE / SSD
                    heads; vocab for embedding + LM head.
  * FSDP (train) -> "data" additionally shards every parameter's largest
                    replicated dim; optimizer state follows parameters.
  * KV caches    -> batch over "data", SEQUENCE over "model".  Sequence-
                    sharding (not head-sharding) is deliberate: several
                    assigned archs have fewer KV heads than the 16-wide
                    model axis (gemma3 kv=8, qwen2-vl kv=4, ...), and a
                    padded head-sharding wastes up to 4x cache memory.
                    Under plain GSPMD this costs a per-layer KV all-gather
                    at decode — the §Perf hillclimb replaces it with a
                    shard_map flash-decoding combine (parallel/sp_decode).

Rules are path-based over the parameter pytree; anything unmatched is
replicated.  Divisibility is checked and falls back to replication rather
than failing — the dry-run prints fallbacks so silent inefficiency can't
hide (DESIGN.md "no silent caps").
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def batch_pspec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))


def _div(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0


def param_pspecs(params, cfg: ModelConfig, mesh: Mesh,
                 fsdp: bool = False, log_fallbacks: bool = False):
    """PartitionSpec pytree matching ``params``."""
    m = _axis_size(mesh, "model")
    d = _axis_size(mesh, "data")
    ep_moe = cfg.ffn_kind == "moe" and _div(cfg.n_routed, m)
    # Head-aligned TP only: sharding a flat (H*hd) projection column dim
    # across more shards than there are heads makes GSPMD's reshape to
    # (H, hd) cut head boundaries and fall back to full replication INSIDE
    # the attention loops (measured: +10 GB/device). Non-dividing head
    # counts (qwen2-0.5b's 14 q/2 kv heads, qwen1.5's 40, ...) replicate
    # the projection instead; the layer then reshards the batch over
    # ("data","model") around attention where divisible (layers/hints.py).
    q_ok = _div(cfg.n_heads, m)
    kv_ok = _div(cfg.n_kv_heads, m)
    if cfg.attn_kind == "mla":
        kv_ok = q_ok
    ssm_ok = cfg.n_ssd_heads == 0 or _div(cfg.n_ssd_heads, m)

    def spec_for(path: str, x) -> P:
        ndim = x.ndim
        leaf = path.rsplit("/", 1)[-1]
        # params under a stacked block carry a leading repeat axis — all
        # rules run on the EFFECTIVE (unstacked) shape, then shift.
        stacked = "/blocks/" in f"/{path}/" or path.startswith("blocks/") \
            or "/layers/" in f"/{path}/" or path.startswith("layers/")
        off = 1 if stacked else 0
        shape = x.shape[off:]
        nd = ndim - off
        col = None   # effective dim to shard over "model"

        # encoder layers always have head-aligned dims (n_heads == n_kv)
        enc = path.startswith("encoder")
        q_al = True if enc else q_ok
        kv_al = True if enc else kv_ok

        if leaf == "embed":
            col = 0 if _div(shape[0], m) else None
        elif leaf == "head":
            col = 1 if _div(shape[1], m) else None
        elif leaf in ("wq", "wukv", "bq"):
            dim = 1 if nd >= 2 else 0
            col = dim if (q_al and _div(shape[dim], m)) else None
        elif leaf in ("wk", "wv", "bk", "bv"):
            dim = 1 if nd >= 2 else 0
            col = dim if (kv_al and _div(shape[dim], m)) else None
        elif leaf == "wdkv":
            col = None                           # MLA latent proj: replicated
        elif leaf == "wo":
            col = 0 if (q_al and _div(shape[0], m)) else None
        elif leaf in ("w_up", "w_gate"):
            if nd == 3:                          # MoE expert stacks (E,d,f)
                col = 0 if ep_moe else (2 if _div(shape[2], m) else None)
            else:
                col = 1 if _div(shape[1], m) else None
        elif leaf in ("w_down",):
            if nd == 3:                          # MoE (E,f,d)
                col = 0 if ep_moe else (1 if _div(shape[1], m) else None)
            else:
                col = 0 if _div(shape[0], m) else None
        elif leaf in ("w_x", "w_z"):
            col = 1 if (ssm_ok and _div(shape[1], m)) else None
        elif leaf == "w_out":
            col = 0 if (ssm_ok and _div(shape[0], m)) else None
        elif leaf == "conv_x":
            col = 1 if (ssm_ok and _div(shape[1], m)) else None
        elif leaf in ("conv_x_b", "norm_w"):
            col = 0 if (ssm_ok and _div(shape[0], m)) else None
        elif leaf in ("a_log", "dt_bias", "d_skip"):
            col = 0 if (ssm_ok and _div(shape[0], m)) else None
        elif leaf == "router":
            col = None

        spec = [None] * ndim
        if col is not None and m > 1:
            spec[col + off] = "model"
        # The embedding table stays vocab-sharded ONLY: a 2D-sharded table
        # makes GSPMD replicate the gather/scatter-add (token lookup and its
        # gradient), costing ~10 GB/device at 4k seq — measured, see
        # EXPERIMENTS.md §Perf iteration log.
        if fsdp and d > 1 and leaf != "embed":
            # shard the largest still-unsharded effective dim over "data"
            best, best_size = None, 0
            for i in range(off, ndim):
                if spec[i] is None and _div(x.shape[i], d) \
                        and x.shape[i] > best_size:
                    best, best_size = i, x.shape[i]
            if best is not None and best_size >= d:
                spec[best] = "data"
        if log_fallbacks and col is None and nd >= 2 and max(shape) >= 1024:
            print(f"  [sharding] replicated (no divisible dim): {path} "
                  f"{x.shape}")
        return P(*spec)

    return jax.tree_util.tree_map_with_path(
        lambda path, x: spec_for(_path_str(path), x), params)


def cache_pspecs(cache, cfg: ModelConfig, mesh: Mesh):
    """Cache layout: batch over data axes, sequence over "model"."""
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    d_total = 1
    for a in daxes:
        d_total *= mesh.shape[a]
    m = _axis_size(mesh, "model")

    def spec_for(path: str, x) -> P:
        leaf = path.rsplit("/", 1)[-1]
        if leaf == "len":
            return P(dax if x.shape[0] % max(d_total, 1) == 0 else None)
        stacked = not path.startswith("prefix")
        off = 1 if stacked else 0           # leading repeat axis
        ndim = x.ndim
        spec = [None] * ndim
        if ndim > off and x.shape[off] % max(d_total, 1) == 0:
            spec[off] = dax                  # batch dim (replicate if < mesh)
        if leaf in ("k", "v", "xk", "xv", "c_kv", "k_pe"):
            seq_dim = off + 1
            if _div(x.shape[seq_dim], m) and m > 1:
                spec[seq_dim] = "model"
        elif leaf == "ssm":
            # layout lead + (B, H, P, N): shard SSD heads over "model"
            h_at = off + 1
            if x.ndim > h_at and m > 1 and _div(x.shape[h_at], m):
                spec[h_at] = "model"
        elif leaf == "conv_x":
            ch = ndim - 1
            if m > 1 and _div(x.shape[ch], m):
                spec[ch] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(
        lambda path, x: spec_for(_path_str(path), x), cache)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
