"""Expert parallelism: capacity-based all-to-all MoE dispatch (shard_map).

The model's default MoE path (layers/moe.py) is a dense-dispatch einsum —
exact but computing every expert on every token (E/top_k x FLOP waste;
visible in the roofline MODEL_FLOPS/HLO_FLOPs ratio).  This module is the
optimized path the APEX planner's "ep" template maps to:

  * tokens are sharded over the "model" axis (sequence-split), experts are
    sharded over the same axis (E_local = E / tp per device),
  * each device routes its T/tp tokens and buckets them per expert with a
    fixed CAPACITY (cap_factor * T_local * top_k / E), dropping overflow
    (GShard/DeepSpeed-MoE semantics — drops are counted and returned,
    never silent),
  * one all-to-all sends buckets to expert owners, experts run dense GEMMs
    once per bucket, a second all-to-all returns outputs, combine weights
    rescale them.

Exact top-k FLOPs (no dense-dispatch waste) and the paper's EP
communication pattern (2 all-to-alls vs TP's all-reduce) — the §Perf
hillclimb swaps this in for the MoE cells and measures the delta.
Correctness is asserted against the dense oracle in tests/test_ep.py
(with capacity high enough that nothing drops).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _bucket_by_expert(x, idx, n_exp: int, cap: int):
    """Bucket token-assignments into (n_exp, cap, d) buffers, dropping
    overflow.  x: (T, d); idx: (T, k) expert ids.
    Returns (buffers, (tok_of_assign, e_idx, s_idx, kept), n_dropped)."""
    T, d = x.shape
    k = idx.shape[1]
    flat_e = idx.reshape(-1)                          # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank within each equal-expert run of the sorted list
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_run = jnp.arange(T * k) - first
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(T * k))
    slot = pos_in_run[inv]                            # (T*k,)
    kept = slot < cap
    drops = jnp.sum(~kept)
    tok_of_assign = jnp.repeat(jnp.arange(T), k)
    e_idx = jnp.where(kept, flat_e, 0)
    s_idx = jnp.where(kept, slot, cap - 1)
    buffers = jnp.zeros((n_exp, cap, d), x.dtype).at[e_idx, s_idx].add(
        jnp.where(kept[:, None], x[tok_of_assign], 0))
    return buffers, (tok_of_assign, e_idx, s_idx, kept), drops


def moe_ep_forward(params: dict, x: jnp.ndarray, top_k: int, mesh: Mesh,
                   axis: str = "model", cap_factor: float = 1.25
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """EP MoE over ``axis``.  x: (B, S, d); S must divide mesh[axis].
    Returns (y (B,S,d), dropped_fraction scalar)."""
    n_exp = params["w_up"].shape[0]
    tp = mesh.shape[axis]
    if n_exp % tp:
        raise ValueError(f"{n_exp} experts not divisible by axis {tp}")
    e_local = n_exp // tp
    B, S, d = x.shape
    if S % tp:
        raise ValueError(f"seq {S} not divisible by EP axis {tp}")
    gated = "w_gate" in params

    def local(x_l, router, w_up, w_gate, w_down):
        # x_l: (B_l, S/tp, d) — this device's token slice
        Bl, Sl, _ = x_l.shape
        T = Bl * Sl
        xt = x_l.reshape(T, d)
        logits = xt.astype(jnp.float32) @ router           # router replicated
        top_vals, top_idx = jax.lax.top_k(logits, top_k)
        gates = jax.nn.softmax(top_vals, axis=-1)
        cap = max(1, int(cap_factor * T * top_k / n_exp))
        buffers, (tok_a, e_idx, s_idx, kept), drops = _bucket_by_expert(
            xt, top_idx, n_exp, cap)
        # dispatch: (tp, e_local, cap, d) -> expert owners
        bufs = buffers.reshape(tp, e_local, cap, d)
        recv = jax.lax.all_to_all(bufs, axis, split_axis=0, concat_axis=0,
                                  tiled=False)             # (tp, e_l, cap, d)
        h = recv.reshape(tp, e_local, cap, d)
        eids = jnp.arange(e_local)
        up = jnp.einsum("secd,edf->secf", h, w_up[eids])
        if gated:
            gt = jnp.einsum("secd,edf->secf", h, w_gate[eids])
            up = jax.nn.silu(gt) * up
        else:
            up = jax.nn.gelu(up)
        yv = jnp.einsum("secf,efd->secd", up, w_down[eids])
        # combine: return buckets to their source devices
        back = jax.lax.all_to_all(yv, axis, split_axis=0, concat_axis=0,
                                  tiled=False)             # (tp, e_l, cap, d)
        yb = back.reshape(n_exp, cap, d)                   # expert-major
        vals = yb[e_idx, s_idx]                            # (T*k, d)
        gflat = gates.reshape(-1)
        vals = vals * (gflat * kept).astype(vals.dtype)[:, None]
        y = jnp.zeros((T, d), vals.dtype).at[tok_a].add(vals)
        drop_frac = drops.astype(jnp.float32) / (T * top_k)
        drop_frac = jax.lax.pmean(drop_frac, axis)
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                drop_frac = jax.lax.pmean(drop_frac, ax)
        return y.reshape(Bl, Sl, d).astype(x_l.dtype), drop_frac

    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dspec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(dspec, axis, None), P(), P(axis), P(axis) if gated
                  else P(), P(axis)),
        out_specs=(P(dspec, axis, None), P()),
        check_rep=False)
    y, drop = fn(x, params["router"], params["w_up"],
                 params.get("w_gate", jnp.zeros((), x.dtype)),
                 params["w_down"])
    if "shared" in params:
        from repro.layers.mlp import mlp_forward
        y = y + mlp_forward(params["shared"], x)
    return y, drop
