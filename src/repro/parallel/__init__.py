"""Distribution layer: APEX plan -> JAX shardings, plus the explicitly
scheduled parallel patterns (pipeline, expert-parallel dispatch,
sequence-parallel flash-decoding)."""

from .sharding import batch_pspec, cache_pspecs, param_pspecs
from .plan_sharding import plan_to_shardings

__all__ = ["batch_pspec", "cache_pspecs", "param_pspecs",
           "plan_to_shardings"]
