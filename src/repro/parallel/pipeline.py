"""GPipe-style pipeline parallelism via shard_map + lax.ppermute.

GSPMD cannot express pipelining (it has no notion of time), so PP plans
from the APEX planner are realized here: the layer stack is sharded over a
"stage" mesh axis (each device group holds block_repeat / n_stages blocks),
microbatches stream through stages with collective-permute handoffs, and
the classic GPipe schedule (n_micro + n_stages - 1 ticks) is driven by a
lax.scan whose body runs ONE tick on every stage simultaneously.

This module implements the pattern for the dense-transformer family (the
demo + tests target); the same skeleton drives PP for the other families
by swapping the stage function.

Cross-pod use: placing the "stage" axis on the pod boundary turns the
stage handoff into the only inter-pod traffic (activations once per
microbatch) — the paper's §2.4 PP-across-slow-links guidance; combine with
training/compress.py to quantize the handoff.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(stage_fn: Callable, params_stacked, x_micro,
                     mesh: Mesh, n_stages: int,
                     stage_axis: str = "stage") -> jnp.ndarray:
    """Run microbatches through the pipeline.

    stage_fn(stage_params, x) -> x   (one stage's layers, shard-local)
    params_stacked: pytree with leading dim n_stages (sharded over stage).
    x_micro: (n_micro, mb, S, d) microbatched inputs (replicated).
    Returns (n_micro, mb, S, d) outputs.
    """
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def per_stage(params_local, xs):
        # params_local: leading dim 1 (this stage's slice); xs replicated
        stage_params = jax.tree.map(lambda a: a[0], params_local)
        idx = jax.lax.axis_index(stage_axis)
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)     # in-flight microbatch
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            state, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            incoming = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), keepdims=False)
            state = jnp.where((idx == 0) & (t < n_micro), incoming, state)
            y = stage_fn(stage_params, state)
            # last stage retires microbatch t - (n_stages - 1)
            done_t = t - (n_stages - 1)
            write = (idx == n_stages - 1) & (done_t >= 0)
            outs = jax.lax.cond(
                write,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(done_t, 0, n_micro - 1), 0),
                lambda o: o, outs)
            # hand off to the next stage (ring permute; stage 0 receives
            # garbage from the last stage and overwrites it on ingest)
            y_next = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (y_next, outs), None

        (_, outs), _ = jax.lax.scan(tick, (state, outs),
                                    jnp.arange(ticks))
        # every stage holds `outs`; only the last stage's is real — share it
        outs = jax.lax.psum(
            jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    pp = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_rep=False)
    return pp(params_stacked, x_micro)


def make_pp_mesh(n_stages: int, tp: int = 1):
    """A (stage, model) mesh from the available devices."""
    return jax.make_mesh((n_stages, tp), ("stage", "model"))
