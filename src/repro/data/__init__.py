"""Data pipeline: deterministic synthetic token streams + request traces."""

from .pipeline import TokenPipeline
from .requests import make_serving_requests

__all__ = ["TokenPipeline", "make_serving_requests"]
