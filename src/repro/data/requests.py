"""Serving-request synthesis for the REAL engine (mirrors core/trace.py's
simulator traces so fidelity experiments compare like for like)."""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.trace import Request, TRACE_SPECS, synthesize_trace


def make_serving_requests(trace: str, arrival_rate: float, n: int,
                          vocab_size: int, seed: int = 0,
                          max_len: int = 2048) -> List[dict]:
    """Concrete requests: APEX trace metadata + actual prompt token ids."""
    reqs = synthesize_trace(TRACE_SPECS[trace], arrival_rate, seed=seed,
                            num_requests=n, max_len=max_len)
    rng = np.random.RandomState(seed + 1)
    out = []
    for r in reqs:
        out.append({
            "rid": r.rid,
            "arrival": r.arrival,
            "prompt": rng.randint(1, vocab_size,
                                  size=(r.context_len,)).astype(np.int32),
            "gen_len": r.gen_len,
        })
    return out
