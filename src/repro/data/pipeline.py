"""Deterministic sharded synthetic-token pipeline.

Every (step, shard) batch is a pure function of (seed, step, shard) via
key folding — any host can recompute any shard (straggler mitigation /
elastic resume without data-state checkpoints; the checkpoint manifest
only needs the step counter).  Token statistics follow a Zipfian unigram
distribution so the loss curve is non-degenerate for the training example.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_shards: int = 1
    seed: int = 0
    zipf_a: float = 1.2

    def __post_init__(self):
        if self.global_batch % self.num_shards:
            raise ValueError("global_batch must divide num_shards")
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-self.zipf_a)
        self._probs = jnp.asarray(probs / probs.sum(), jnp.float32)

    @property
    def shard_batch(self) -> int:
        return self.global_batch // self.num_shards

    def batch(self, step: int, shard: int = 0) -> dict:
        """{tokens, labels}: labels are next-token shifted."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), shard)
        toks = jax.random.choice(
            key, self.vocab_size, (self.shard_batch, self.seq_len + 1),
            p=self._probs)
        return {"tokens": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32)}

    def global_batch_at(self, step: int) -> dict:
        shards = [self.batch(step, s) for s in range(self.num_shards)]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs), *shards)
