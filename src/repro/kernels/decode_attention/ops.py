"""Public decode-attention op with kernel/ref dispatch."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_pallas
from .ref import decode_attention_ref


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lengths: jnp.ndarray,
                     force_kernel: bool = False) -> jnp.ndarray:
    if jax.default_backend() == "tpu":
        return decode_attention_pallas(q, k, v, lengths, interpret=False)
    if force_kernel or os.environ.get("REPRO_KERNELS") == "1":
        return decode_attention_pallas(q, k, v, lengths, interpret=True)
    return decode_attention_ref(q, k, v, lengths)
