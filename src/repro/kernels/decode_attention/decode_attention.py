"""Decode attention (one new token vs. a long KV cache) — Pallas TPU kernel.

The serving decode hot spot is MEMORY-bound: the kernel's job is to stream
the KV cache through VMEM exactly once at full HBM bandwidth.  Tiling:

  * grid = (batch, kv_heads, kv_blocks) — kv_blocks minor, so the online
    softmax state for one (batch, kv_head) persists in VMEM scratch across
    KV tiles (flash-decoding's split-K, laid out for the TPU's sequential
    grid instead of CUDA thread blocks).
  * All ``group`` query heads of a kv head are processed TOGETHER as a
    (group, D) panel: GQA turns the q·K product into a small (group x D)
    x (D x block_kv) matmul — enough arithmetic intensity to keep the MXU
    from starving while staying bandwidth-limited (this is the TPU
    adaptation; a CUDA kernel would instead parallelize across warps).
  * per-sequence valid length masks ring/linear caches uniformly.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   block_kv: int, group: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    n_valid = len_ref[0]
    k_lo = ki * block_kv

    @pl.when(k_lo < n_valid)
    def _compute():
        q = q_ref[...].astype(jnp.float32)               # (group, D)
        k = k_ref[...].astype(jnp.float32)               # (bk, D)
        v = v_ref[...].astype(jnp.float32)               # (bk, Dv)
        scale = 1.0 / math.sqrt(q.shape[-1])
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (group, bk)
        slot = k_lo + jax.lax.broadcasted_iota(jnp.int32, (1, block_kv), 1)
        s = jnp.where(slot < n_valid, s, NEG_INF)
        m_prev = m_scr[...]                               # (group,)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        m_scr[...] = m_new
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_kv", "interpret"))
def decode_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            lengths: jnp.ndarray, *, block_kv: int = 512,
                            interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, D) one new token per sequence; k/v: (B, Smax, Hkv, D)
    caches; lengths: (B,) valid entries per sequence.
    Returns (B, Hq, Dv)."""
    B, Hq, D = q.shape
    _, Smax, Hkv, Dv = v.shape
    group = Hq // Hkv
    bk = min(block_kv, Smax)
    S_p = -(-Smax // bk) * bk
    if S_p != Smax:
        k = jnp.pad(k, ((0, 0), (0, S_p - Smax), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, S_p - Smax), (0, 0), (0, 0)))
    # group query heads by kv head: (B, Hkv, group, D)
    qg = q.reshape(B, Hkv, group, D)

    grid = (B, Hkv, S_p // bk)
    kernel = functools.partial(_decode_kernel, block_kv=bk, group=group)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, None, group, D),
                         lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((None, bk, None, D),
                         lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((None, bk, None, Dv),
                         lambda b, h, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, group, Dv),
                               lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(B, Hq, Dv)
