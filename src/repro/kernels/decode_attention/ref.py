"""Pure-jnp oracle for decode attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         lengths: jnp.ndarray) -> jnp.ndarray:
    """q: (B, Hq, D); k/v: (B, Smax, Hkv, Dv); lengths: (B,).
    Returns (B, Hq, Dv)."""
    B, Hq, D = q.shape
    _, Smax, Hkv, Dv = v.shape
    rep = Hq // Hkv
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / math.sqrt(D)
    valid = jnp.arange(Smax)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
