"""Flash attention (prefill) as a Pallas TPU kernel.

TPU-native tiling, not a CUDA port:
  * grid = (batch, q_heads, q_blocks, kv_blocks) — the kv axis is the
    MINOR grid dimension, so on TPU its iterations run sequentially per
    (b, h, qi) and the online-softmax running state (m, l, acc) lives in
    VMEM scratch that persists across kv steps.
  * BlockSpecs pull one (block_q, head_dim) Q tile and one
    (block_kv, head_dim) K/V tile into VMEM per step; block sizes default
    to 128 — MXU-aligned.
  * GQA is handled in the K/V index_map (q head h reads kv head
    h // group) — no repeated K/V materialization in HBM.
  * causal + sliding-window masking is applied per tile; fully-masked
    tiles are skipped with pl.when.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, block_q: int, block_kv: int,
                 seq_kv: int, causal: bool, window, q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions: (bq, 1) query, (1, bk) key (2-D iota for TPU)
    q_pos = (q_offset + qi * block_q
             + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0))
    k_pos = (ki * block_kv
             + jax.lax.broadcasted_iota(jnp.int32, (1, block_kv), 1))

    # tile-level skip: many tiles are fully masked under causal/window
    q_hi = q_offset + qi * block_q + block_q - 1
    q_lo = q_offset + qi * block_q
    k_lo = ki * block_kv
    k_hi = k_lo + block_kv - 1
    live = k_lo < seq_kv
    if causal:
        live = jnp.logical_and(live, k_lo <= q_hi)
    if window is not None:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32)              # (bq, D)
        k = k_ref[...].astype(jnp.float32)              # (bk, D)
        v = v_ref[...].astype(jnp.float32)              # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        mask = k_pos < seq_kv
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                             # (bq,)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        m_scr[...] = m_new
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv",
                     "q_offset", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, window=None,
                           block_q: int = 128, block_kv: int = 128,
                           q_offset: int = 0,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D).  Returns (B, Sq, Hq, Dv).

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container has no TPU); on a real TPU pass interpret=False.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    if Hq % Hkv:
        raise ValueError("Hq must be a multiple of Hkv")
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    bk = min(block_kv, Skv)
    Sq_p = -(-Sq // bq) * bq
    Skv_p = -(-Skv // bk) * bk
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    if Skv_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))

    grid = (B, Hq, Sq_p // bq, Skv_p // bk)
    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=bq, block_kv=bk,
        seq_kv=Skv, causal=causal, window=window, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, None, D),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((None, bk, None, D),
                         lambda b, h, qi, ki: (b, ki, h // group, 0)),
            pl.BlockSpec((None, bk, None, Dv),
                         lambda b, h, qi, ki: (b, ki, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, None, Dv),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq_p, Hq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
