"""Public flash-attention op: kernel on TPU, blockwise-jnp elsewhere.

The dispatch ladder:
  * TPU backend            -> Pallas kernel, compiled (interpret=False)
  * CPU + REPRO_KERNELS=1  -> Pallas kernel, interpret mode (tests)
  * otherwise              -> repro.layers.attention.blockwise_attention
                              (same math, plain XLA — what the dry-run
                              lowers so the HLO reflects TPU-lowerable ops)
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.layers.attention import blockwise_attention
from .flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0,
                    force_kernel: bool = False) -> jnp.ndarray:
    if _on_tpu():
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset, interpret=False)
    if force_kernel or os.environ.get("REPRO_KERNELS") == "1":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset, interpret=True)
    return blockwise_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
