"""Pure-jnp oracle for the flash-attention kernel: materializes the full
score matrix.  Small-shape tests only."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True, window: Optional[int] = None,
                  q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, Dv) -> (B, Sq, Hq, Dv)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    rep = Hq // Hkv
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / math.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
