"""Pallas TPU kernels for the serving hot spots.

Each kernel package ships three files:
  * ``<name>.py`` — the pl.pallas_call kernel with explicit BlockSpec VMEM
    tiling (TPU is the TARGET; validated with interpret=True on CPU),
  * ``ops.py``    — the jit'd public wrapper that dispatches kernel vs.
    pure-jnp fallback,
  * ``ref.py``    — the pure-jnp oracle the tests assert_allclose against.

Kernels: flash_attention (prefill), decode_attention (one token vs KV
cache, flash-decoding tiling), ssd_scan (Mamba2 chunked SSD), rmsnorm.
"""

from .flash_attention.ops import flash_attention
from .decode_attention.ops import decode_attention
from .ssd_scan.ops import ssd_scan
from .rmsnorm.ops import fused_rms_norm

__all__ = ["decode_attention", "flash_attention", "fused_rms_norm",
           "ssd_scan"]
