"""Mamba2 SSD chunked scan — Pallas TPU kernel.

The SSD duality (arXiv:2405.21060) splits the scan into (a) an intra-chunk
attention-like matmul and (b) a tiny cross-chunk recurrence.  TPU mapping:

  * grid = (batch, heads, chunks) — chunks minor, so the (P, N) recurrent
    state for one (batch, head) lives in VMEM scratch across chunk steps
    (the cross-chunk recurrence costs no HBM round-trips).
  * per chunk the kernel runs three small matmuls on the MXU:
    scores = C B^T (Q x Q), y_intra = (scores * decay-mask) @ (dt * x),
    state update = (decayed dt*x)^T B — all on (Q, N)/(Q, P) tiles with
    Q = 128 (MXU-aligned).
  * decays are cumulative-sum log-space scalars (Q-vectors) — VPU work
    that overlaps with the MXU matmuls.

The kernel computes one head's chunk at a time; B/C are shared across the
heads of a group (n_groups = 1 in all assigned configs), selected by the
index map — no broadcast materialization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[...].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[...].astype(jnp.float32)        # (Q,)
    a = a_ref[0]                                # scalar A (negative)
    b = b_ref[...].astype(jnp.float32)          # (Q, N)
    c = c_ref[...].astype(jnp.float32)          # (Q, N)

    la = dt * a                                 # (Q,) log-decay per step
    cum = jnp.cumsum(la)                        # (Q,)
    # intra-chunk decay mask: L[i, j] = exp(cum_i - cum_j), j <= i
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (Q, Q)
    w = scores * L
    xdt = x * dt[:, None]                       # (Q, P)
    y = jax.lax.dot_general(w, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: h carries (P, N); y += exp(cum) * (C @ h^T)
    h = h_scr[...]
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (Q, P)
    # state update: h' = exp(cum_last) h + sum_i exp(cum_last - cum_i)
    #               (dt_i x_i) outer B_i
    rem = cum[-1] - cum                         # (Q,)
    xw = xdt * jnp.exp(rem)[:, None]            # (Q, P)
    h_scr[...] = (h * jnp.exp(cum[-1])
                  + jax.lax.dot_general(
                      xw, b, (((0,), (0,)), ((), ())),
                      preferred_element_type=jnp.float32))  # (P, N)
    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                    b: jnp.ndarray, c: jnp.ndarray, *, chunk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """x: (B, S, H, P); dt: (B, S, H) (softplus-activated); a_log: (H,);
    b, c: (B, S, N) (n_groups=1).  Returns y: (B, S, H, P) WITHOUT the
    D-skip term (ops.py adds it — keeps the kernel state-only)."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    S_p = -(-S // Q) * Q
    if S_p != S:
        x = jnp.pad(x, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, S_p - S), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, S_p - S), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, S_p - S), (0, 0)))
    A = -jnp.exp(a_log.astype(jnp.float32))     # (H,)

    grid = (B, H, S_p // Q)
    kernel = functools.partial(_ssd_kernel, chunk=Q)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, Q, None, P), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((None, Q, None), lambda bi, h, ci: (bi, ci, h)),
            pl.BlockSpec((1,), lambda bi, h, ci: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, Q, N), lambda bi, h, ci: (bi, ci, 0)),
            pl.BlockSpec((None, Q, N), lambda bi, h, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((None, Q, None, P),
                               lambda bi, h, ci: (bi, ci, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S_p, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, b, c)
    return y[:, :S]
