"""Public SSD-scan op with kernel/ref dispatch (adds the D-skip term)."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .ssd_scan import ssd_scan_pallas
from .ref import ssd_scan_ref


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, d_skip: jnp.ndarray,
             chunk: int = 128, force_kernel: bool = False) -> jnp.ndarray:
    if jax.default_backend() == "tpu":
        y = ssd_scan_pallas(x, dt, a_log, b, c, chunk=chunk,
                            interpret=False)
    elif force_kernel or os.environ.get("REPRO_KERNELS") == "1":
        y = ssd_scan_pallas(x, dt, a_log, b, c, chunk=chunk,
                            interpret=True)
    else:
        y = ssd_scan_ref(x, dt, a_log, b, c)
    return y + x * d_skip[None, None, :, None].astype(x.dtype)
