"""Pure-jnp oracle for the SSD scan kernel: the sequential recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                 b: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Sequential scan: x (B,S,H,P), dt (B,S,H), a_log (H,), b/c (B,S,N).
    Returns y (B,S,H,P) without the D-skip term."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    A = -jnp.exp(a_log.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P),(B,H),(B,N),(B,N)
        a = jnp.exp(dtt * A)                        # (B,H)
        upd = (dtt[..., None, None] * xt[..., None]
               * bt[:, None, None, :])              # (B,H,P,N)
        h = h * a[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          b.transpose(1, 0, 2).astype(jnp.float32),
          c.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
