"""Fused RMSNorm — Pallas TPU kernel.

Trivial compute, pure bandwidth: one pass over the rows, fp32 accumulation
on the VPU, scale by the weight vector, cast back.  Grid tiles rows into
(block_rows, d) VMEM panels; d stays whole (norm axis must be resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                   # (rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rms_norm_pallas(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6,
                    block_rows: int = 256,
                    interpret: bool = True) -> jnp.ndarray:
    """x: (..., d) -> same shape; w: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    rows_p = -(-rows // br) * br
    if rows_p != rows:
        x2 = jnp.pad(x2, ((0, rows_p - rows), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows_p // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_p, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(orig_shape)
