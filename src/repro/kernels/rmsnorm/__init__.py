from .ops import fused_rms_norm

__all__ = ["fused_rms_norm"]
