"""Public fused-RMSNorm op with kernel/ref dispatch."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .rmsnorm import rms_norm_pallas
from .ref import rms_norm_ref


def fused_rms_norm(x: jnp.ndarray, w: jnp.ndarray,
                   eps: float = 1e-6,
                   force_kernel: bool = False) -> jnp.ndarray:
    if jax.default_backend() == "tpu":
        return rms_norm_pallas(x, w, eps=eps, interpret=False)
    if force_kernel or os.environ.get("REPRO_KERNELS") == "1":
        return rms_norm_pallas(x, w, eps=eps, interpret=True)
    return rms_norm_ref(x, w, eps)
