"""Oracle: the canonical rms_norm from repro.layers.norms."""

from repro.layers.norms import rms_norm as rms_norm_ref

__all__ = ["rms_norm_ref"]
