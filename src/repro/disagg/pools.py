"""Pool partitioning for disaggregated prefill/decode serving.

A ``DisaggScheme`` splits one physical cluster into a *prefill pool* and a
*decode pool*, each carrying its own ``ParallelScheme`` (so each pool picks
its own DP/PP/TP/quant — the whole point of disaggregation: prefill wants
high TP for low TTFT, decode wants DP-heavy replication for token
throughput).  Pools occupy contiguous physical id ranges — prefill at
[0, P), decode at [P, N) — so the existing bottom-up Device Mapper places
each pool unchanged via its ``device_offset`` and the KV handoff crosses a
well-defined network level of the cluster tree.

Plan enumeration reuses Algorithm 1 per pool and prunes each pool's
candidates with the same static weight-memory pre-filter as the colocated
search path (``planner.prefilter_schemes``), so a pool split that overflows
either pool's HBM is rejected before any simulation.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from ..core.cluster import Cluster
from ..core.ir import ModelIR
from ..core.mapper import ExecutionPlan, map_scheme
from ..core.planner import (ParallelScheme, generate_schemes,
                            prefilter_schemes)


@dataclasses.dataclass(frozen=True)
class DisaggScheme:
    """A disaggregated plan: per-pool parallel schemes + transfer mode.

    ``transfer_mode``:
      * ``"layerwise"`` — KV blocks stream to the decode pool as each layer
        finishes prefill; only the last layer's chunk remains on the wire
        when prefill completes (the admission delay the decode pool sees).
      * ``"blocking"``  — the whole cache ships after prefill completes.
    """

    prefill: ParallelScheme
    decode: ParallelScheme
    transfer_mode: str = "layerwise"

    def __post_init__(self):
        if self.transfer_mode not in ("layerwise", "blocking"):
            raise ValueError(
                f"unknown transfer mode {self.transfer_mode!r}")
        if self.prefill.model is not self.decode.model:
            raise ValueError("pools must serve the same model IR")

    @property
    def model(self) -> ModelIR:
        return self.prefill.model

    @property
    def prefill_devices(self) -> int:
        return self.prefill.total_devices

    @property
    def decode_devices(self) -> int:
        return self.decode.total_devices

    @property
    def total_devices(self) -> int:
        return self.prefill_devices + self.decode_devices

    def label(self) -> str:
        return (f"disagg[{self.prefill_devices}P:{self.prefill.label()}"
                f" | {self.decode_devices}D:{self.decode.label()}]"
                f"@{self.transfer_mode}")


@dataclasses.dataclass(frozen=True)
class DisaggPlan:
    """A physically-mapped disaggregated plan: two pool ExecutionPlans plus
    the network span the KV handoff crosses."""

    scheme: DisaggScheme
    cluster: Cluster
    prefill_plan: ExecutionPlan
    decode_plan: ExecutionPlan
    transfer_span: int        # devices spanned by the cross-pool link

    def label(self) -> str:
        return self.scheme.label()

    def describe(self) -> str:
        lvl = self.cluster.level_for_group(self.transfer_span)
        return "\n".join([
            f"disagg plan on {self.cluster.name} "
            f"({self.scheme.prefill_devices} prefill + "
            f"{self.scheme.decode_devices} decode devices, "
            f"KV handoff over {lvl.name}, {self.scheme.transfer_mode})",
            self.prefill_plan.describe(),
            self.decode_plan.describe(),
        ])


def cross_pool_span(cluster: Cluster, prefill_devices: int) -> int:
    """Device span of the prefill->decode KV link, for level lookup.

    The pools abut at physical ids (P-1, P); the handoff crosses the
    smallest tree level whose group contains both ids.  Returns a span that
    ``Cluster.level_for_group`` maps back to exactly that level — this is
    the same level-selection rule the Device Mapper applies to collective
    groups, so KV-transfer traffic is costed with the cluster's own
    bandwidth/latency tables, never a hard-coded link speed.
    """
    src, dst = prefill_devices - 1, prefill_devices
    if dst >= cluster.num_devices:
        raise ValueError("decode pool is empty")
    for lvl in cluster.levels:
        if src // lvl.group_size == dst // lvl.group_size:
            return 2 if lvl is cluster.levels[0] else lvl.group_size
    return cluster.levels[-1].group_size


def map_disagg_scheme(scheme: DisaggScheme, cluster: Cluster) -> DisaggPlan:
    """Map both pools onto one cluster: prefill at offset 0, decode next."""
    if scheme.total_devices > cluster.num_devices:
        raise ValueError(
            f"disagg scheme needs {scheme.total_devices} devices; cluster "
            f"{cluster.name} has {cluster.num_devices}")
    p = scheme.prefill_devices
    return DisaggPlan(
        scheme=scheme, cluster=cluster,
        prefill_plan=map_scheme(scheme.prefill, cluster, device_offset=0),
        decode_plan=map_scheme(scheme.decode, cluster, device_offset=p),
        transfer_span=cross_pool_span(cluster, p))


def pool_splits(num_devices: int) -> List[Tuple[int, int]]:
    """All (prefill_devices, decode_devices) partitions of the cluster."""
    return [(p, num_devices - p) for p in range(1, num_devices)]


def generate_disagg_schemes(model: ModelIR, cluster: Cluster,
                            quant: str = "fp16",
                            decode_quant: Optional[str] = None,
                            feasible_only: bool = True,
                            transfer_mode: str = "layerwise",
                            max_model_dp: Optional[int] = None,
                            max_plans: int = 512) -> List[DisaggScheme]:
    """Enumerate disaggregated plans: pool split x per-pool Algorithm-1
    schemes, each pool pruned by the shared weight-memory pre-filter.

    ``decode_quant`` lets the decode pool run a different format (e.g. kv8
    to stretch decode KV capacity while prefill stays fp16).  The default
    ``feasible_only=True`` restricts pools to uniform DP/PP/TP schemes —
    the cross-product of two unconstrained cell-DP spaces is rarely worth
    simulating and real disaggregated stacks deploy uniform pools.
    """
    hbm = cluster.device.hbm_bytes
    out: List[DisaggScheme] = []
    per_pool_cache: dict = {}

    def pool_candidates(n: int, q: str) -> List[ParallelScheme]:
        key = (n, q)
        if key not in per_pool_cache:
            cands = generate_schemes(model, n, quant=q,
                                     allow_cell_dp=not feasible_only,
                                     max_model_dp=max_model_dp)
            if feasible_only:
                cands = [s for s in cands
                         if s.is_feasible_for_current_systems()]
            per_pool_cache[key] = prefilter_schemes(cands, hbm)
        return per_pool_cache[key]

    for p, d in pool_splits(cluster.num_devices):
        for pre in pool_candidates(p, quant):
            for dec in pool_candidates(d, decode_quant or quant):
                out.append(DisaggScheme(prefill=pre, decode=dec,
                                        transfer_mode=transfer_mode))
                if len(out) >= max_plans:
                    return out
    return out
