"""Pool partitioning for disaggregated prefill/decode serving.

A ``DisaggScheme`` splits one physical cluster into a *prefill pool* and a
*decode pool*, each carrying its own ``ParallelScheme`` (so each pool picks
its own DP/PP/TP/quant — the whole point of disaggregation: prefill wants
high TP for low TTFT, decode wants DP-heavy replication for token
throughput).  Pools occupy contiguous physical id ranges — prefill at
[0, P), decode at [P, N) — so the existing bottom-up Device Mapper places
each pool unchanged via its ``device_offset`` and the KV handoff crosses a
well-defined network level of the cluster tree.

Plan enumeration reuses Algorithm 1 per pool and prunes each pool's
candidates with the same static weight-memory pre-filter as the colocated
search path (``planner.prefilter_schemes``), so a pool split that overflows
either pool's HBM is rejected before any simulation.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from ..core.batching import BatchingPolicy
from ..core.cluster import Cluster, NetworkLevel, cross_pool_link
from ..core.ir import ModelIR
from ..core.mapper import ExecutionPlan, map_scheme
from ..core.planner import (ParallelScheme, generate_schemes,
                            prefilter_schemes)


@dataclasses.dataclass(frozen=True)
class DisaggScheme:
    """A disaggregated plan: per-pool parallel schemes + transfer mode.

    ``transfer_mode``:
      * ``"layerwise"`` — KV blocks stream to the decode pool as each layer
        finishes prefill; only the last layer's chunk remains on the wire
        when prefill completes (the admission delay the decode pool sees).
      * ``"blocking"``  — the whole cache ships after prefill completes.
    """

    prefill: ParallelScheme
    decode: ParallelScheme
    transfer_mode: str = "layerwise"

    def __post_init__(self):
        if self.transfer_mode not in ("layerwise", "blocking"):
            raise ValueError(
                f"unknown transfer mode {self.transfer_mode!r}")
        if self.prefill.model is not self.decode.model:
            raise ValueError("pools must serve the same model IR")

    @property
    def model(self) -> ModelIR:
        return self.prefill.model

    @property
    def prefill_devices(self) -> int:
        return self.prefill.total_devices

    @property
    def decode_devices(self) -> int:
        return self.decode.total_devices

    @property
    def total_devices(self) -> int:
        return self.prefill_devices + self.decode_devices

    def label(self) -> str:
        return (f"disagg[{self.prefill_devices}P:{self.prefill.label()}"
                f" | {self.decode_devices}D:{self.decode.label()}]"
                f"@{self.transfer_mode}")


@dataclasses.dataclass(frozen=True)
class DisaggPlan:
    """A physically-mapped disaggregated plan: per-pool clusters + per-pool
    ExecutionPlans, joined by the network the KV handoff crosses.

    Two substrates:

      * shared cluster (homogeneous) — both pools are contiguous id ranges
        of ONE cluster (``prefill_cluster is decode_cluster``); the handoff
        crosses the cluster-internal level at ``transfer_span`` and
        ``cross_level`` is None.  This is the PR-1 path, byte-identical.
      * per-pool clusters (heterogeneous) — each pool is its own cluster
        with its own ``DeviceSpec`` (prefill on compute-heavy parts, decode
        on HBM-bandwidth-heavy parts); the handoff crosses the explicit
        ``cross_level`` (default: ``core.cluster.cross_pool_link``).
    """

    scheme: DisaggScheme
    prefill_cluster: Cluster
    decode_cluster: Cluster
    prefill_plan: ExecutionPlan
    decode_plan: ExecutionPlan
    transfer_span: int        # devices spanned by the in-cluster link
    cross_level: Optional[NetworkLevel] = None   # explicit inter-pool link
    # per-pool batching policies (None = the simulation-wide policy);
    # e.g. chunked prefill only on the prefill pool, or a different
    # max_batch_size per pool — each pool's replicas are engine actors
    # driven by their own SchedulerPolicy, so the pools need not agree
    prefill_policy: Optional[BatchingPolicy] = None
    decode_policy: Optional[BatchingPolicy] = None

    @property
    def homogeneous(self) -> bool:
        return self.prefill_cluster is self.decode_cluster

    @property
    def cluster(self) -> Cluster:
        """The single shared cluster (homogeneous plans only)."""
        if not self.homogeneous:
            raise ValueError(
                "heterogeneous plan has per-pool clusters; use "
                ".prefill_cluster / .decode_cluster")
        return self.prefill_cluster

    def label(self) -> str:
        # per-pool-cluster plans are ALWAYS suffixed with their pool
        # devices — even a same-device island pair is different physics
        # (cross-pool link, separate fabrics) from splitting one shared
        # cluster, and downstream consumers classify families by label
        if self.cross_level is None:
            return self.scheme.label()
        return (f"{self.scheme.label()}"
                f"#{self.prefill_cluster.device.name}"
                f">{self.decode_cluster.device.name}")

    def describe(self) -> str:
        if self.cross_level is not None:
            lvl = self.cross_level
            where = (f"{self.prefill_cluster.name}+"
                     f"{self.decode_cluster.name}")
        else:
            lvl = self.prefill_cluster.level_for_group(self.transfer_span)
            where = self.prefill_cluster.name
        return "\n".join([
            f"disagg plan on {where} "
            f"({self.scheme.prefill_devices} prefill x "
            f"{self.prefill_cluster.device.name} + "
            f"{self.scheme.decode_devices} decode x "
            f"{self.decode_cluster.device.name}, "
            f"KV handoff over {lvl.name}, {self.scheme.transfer_mode})",
            self.prefill_plan.describe(),
            self.decode_plan.describe(),
        ])


def is_mixed_label(label: str) -> bool:
    """True when a plan label names DIFFERENT devices for its two pools.

    The single source of truth for the ``#pre>dec`` suffix
    ``DisaggPlan.label()`` emits — benchmarks and examples classify plan
    families through this helper instead of re-parsing the string.
    Same-device island pairs (``#H200-SXM>H200-SXM``) and unsuffixed
    shared-cluster plans both count as homogeneous.
    """
    if "#" not in label:
        return False
    pre, _, dec = label.rsplit("#", 1)[1].partition(">")
    return pre != dec


def cross_pool_span(cluster: Cluster, prefill_devices: int) -> int:
    """Device span of the prefill->decode KV link, for level lookup.

    The pools abut at physical ids (P-1, P); the handoff crosses the
    smallest tree level whose group contains both ids.  Returns a span that
    ``Cluster.level_for_group`` maps back to exactly that level — this is
    the same level-selection rule the Device Mapper applies to collective
    groups, so KV-transfer traffic is costed with the cluster's own
    bandwidth/latency tables, never a hard-coded link speed.
    """
    src, dst = prefill_devices - 1, prefill_devices
    if dst >= cluster.num_devices:
        raise ValueError("decode pool is empty")
    for lvl in cluster.levels:
        if src // lvl.group_size == dst // lvl.group_size:
            return 2 if lvl is cluster.levels[0] else lvl.group_size
    return cluster.levels[-1].group_size


def map_disagg_scheme(scheme: DisaggScheme, cluster: Optional[Cluster] = None,
                      *, prefill_cluster: Optional[Cluster] = None,
                      decode_cluster: Optional[Cluster] = None,
                      cross_level: Optional[NetworkLevel] = None
                      ) -> DisaggPlan:
    """Map both pools to physical devices.

    With ``cluster``, both pools share one physical cluster: prefill at
    offset 0, decode next (the homogeneous PR-1 path, unchanged).  With
    ``prefill_cluster``/``decode_cluster``, each pool maps onto its OWN
    cluster at offset 0 and the KV handoff crosses ``cross_level``
    (default: ``cross_pool_link`` of the two clusters).
    """
    if cluster is not None:
        if prefill_cluster is not None or decode_cluster is not None:
            raise ValueError(
                "pass either one shared cluster or per-pool clusters")
        if scheme.total_devices > cluster.num_devices:
            raise ValueError(
                f"disagg scheme needs {scheme.total_devices} devices; "
                f"cluster {cluster.name} has {cluster.num_devices}")
        p = scheme.prefill_devices
        return DisaggPlan(
            scheme=scheme, prefill_cluster=cluster, decode_cluster=cluster,
            prefill_plan=map_scheme(scheme.prefill, cluster,
                                    device_offset=0),
            decode_plan=map_scheme(scheme.decode, cluster, device_offset=p),
            transfer_span=cross_pool_span(cluster, p))
    if prefill_cluster is None or decode_cluster is None:
        raise ValueError("need a shared cluster or BOTH per-pool clusters")
    for pool, c, n in (("prefill", prefill_cluster, scheme.prefill_devices),
                       ("decode", decode_cluster, scheme.decode_devices)):
        if n > c.num_devices:
            raise ValueError(
                f"{pool} pool needs {n} devices; cluster {c.name} has "
                f"{c.num_devices}")
    return DisaggPlan(
        scheme=scheme, prefill_cluster=prefill_cluster,
        decode_cluster=decode_cluster,
        prefill_plan=map_scheme(scheme.prefill, prefill_cluster),
        decode_plan=map_scheme(scheme.decode, decode_cluster),
        transfer_span=2,
        cross_level=cross_level or cross_pool_link(prefill_cluster,
                                                   decode_cluster))


def pool_splits(num_devices: int) -> List[Tuple[int, int]]:
    """All (prefill_devices, decode_devices) partitions of the cluster."""
    return [(p, num_devices - p) for p in range(1, num_devices)]


def generate_disagg_schemes(model: ModelIR,
                            cluster: Optional[Cluster] = None,
                            quant: str = "fp16",
                            decode_quant: Optional[str] = None,
                            feasible_only: bool = True,
                            transfer_mode: str = "layerwise",
                            max_model_dp: Optional[int] = None,
                            max_plans: int = 512,
                            prefill_cluster: Optional[Cluster] = None,
                            decode_cluster: Optional[Cluster] = None
                            ) -> List[DisaggScheme]:
    """Enumerate disaggregated plans: pool split x per-pool Algorithm-1
    schemes, each pool pruned by ITS OWN device's weight-memory pre-filter.

    With one shared ``cluster``, every (prefill, decode) split of its
    devices is enumerated and both pools are filtered against the shared
    device HBM (the homogeneous PR-1 path).  With per-pool clusters, the
    split is fixed — each pool fills its own cluster — and each pool is
    filtered against its OWN HBM, so e.g. a decode pool of H200s admits
    schemes an H100 pool of the same width would reject.

    ``decode_quant`` lets the decode pool run a different format (e.g. kv8
    to stretch decode KV capacity while prefill stays fp16).  The default
    ``feasible_only=True`` restricts pools to uniform DP/PP/TP schemes —
    the cross-product of two unconstrained cell-DP spaces is rarely worth
    simulating and real disaggregated stacks deploy uniform pools.
    """
    if (prefill_cluster is None) != (decode_cluster is None):
        raise ValueError("need BOTH per-pool clusters (or neither)")
    if prefill_cluster is not None:
        if cluster is not None:
            raise ValueError(
                "pass either one shared cluster or per-pool clusters")
        splits = [(prefill_cluster.num_devices, decode_cluster.num_devices)]
        hbm_pre = prefill_cluster.device.hbm_bytes
        hbm_dec = decode_cluster.device.hbm_bytes
    else:
        if cluster is None:
            raise ValueError("need a shared cluster or per-pool clusters")
        splits = pool_splits(cluster.num_devices)
        hbm_pre = hbm_dec = cluster.device.hbm_bytes
    out: List[DisaggScheme] = []
    per_pool_cache: dict = {}

    def pool_candidates(n: int, q: str, hbm: float) -> List[ParallelScheme]:
        key = (n, q, hbm)
        if key not in per_pool_cache:
            cands = generate_schemes(model, n, quant=q,
                                     allow_cell_dp=not feasible_only,
                                     max_model_dp=max_model_dp)
            if feasible_only:
                cands = [s for s in cands
                         if s.is_feasible_for_current_systems()]
            per_pool_cache[key] = prefilter_schemes(cands, hbm)
        return per_pool_cache[key]

    for p, d in splits:
        for pre in pool_candidates(p, quant, hbm_pre):
            for dec in pool_candidates(d, decode_quant or quant, hbm_dec):
                out.append(DisaggScheme(prefill=pre, decode=dec,
                                        transfer_mode=transfer_mode))
                if len(out) >= max_plans:
                    return out
    return out
