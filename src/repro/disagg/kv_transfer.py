"""KV-cache handoff cost model for disaggregated serving.

When a prompt finishes prefill, its KV cache must move from the prefill
pool to the decode pool.  Payload size comes straight from the model IR:

    bytes = layers x 2(K,V) x kv_heads x head_dim x kv_bytes(quant) x ctx

(``ModelIR.kv_bytes_per_token`` already folds the per-cell structure —
GQA kv_heads, MLA latent width, sliding-window cells — so MLA ships its
compressed latent, exactly what real disagg stacks do.  Recurrent state
of SSM/hybrid cells rides along via ``state_bytes_per_seq``.)

Timing is routed through the existing ``CollectiveModel`` as p2p traffic
at the network level spanning the two pools (``pools.cross_pool_span`` —
the same level-selection rule the Device Mapper uses), so there are no
hard-coded bandwidths anywhere in this model.  Two modes:

  * ``blocking``  — decode admission waits for the full cache: the whole
    serialization time is exposed.
  * ``layerwise`` — layer i's KV streams while layer i+1 prefills (the
    overlap every production disagg system implements); only the *last*
    layer's chunk is still on the wire when prefill completes, so the
    exposed delay is one layer's transfer.  Wire time and energy are still
    charged in full.

Transfers fan out over the parallel links between the pools: one request's
cache is sharded across the source TP group and lands sharded on the
destination TP group, so ``lanes = min(prefill tp, decode tp)`` moves
concurrently.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from ..core.cluster import NetworkLevel
from ..core.ir import ModelIR
from ..core.profiles import CollectiveModel
from ..core.quant import get_format


@dataclasses.dataclass(frozen=True)
class TransferEstimate:
    """One request's KV handoff cost."""

    nbytes: float             # total payload (all layers, all heads)
    delay_s: float            # admission delay visible to the decode pool
    wire_s: float             # full serialization time (one lane's share)
    energy_j: float

    @property
    def effective_gbps(self) -> float:
        return (self.nbytes / self.wire_s / 1e9) if self.wire_s > 0 else 0.0

    @property
    def stream_lead_s(self) -> float:
        """How long before prefill completion the stream already occupied
        the wire (layerwise mode overlaps all but the exposed tail with
        the prefill itself; blocking mode has no lead)."""
        return max(0.0, self.wire_s - self.delay_s)


class KVTransferModel:
    """Per-request KV handoff: bytes from the IR, time from the cluster.

    Two costing modes for the wire itself:

      * shared-cluster (``link=None``) — both pools live in ONE physical
        cluster; the link is looked up in ``coll``'s cluster at the
        transfer ``span`` (pools.cross_pool_span), exactly the PR-1 path.
      * explicit link — heterogeneous pools are separate clusters joined by
        a ``NetworkLevel`` (core.cluster.cross_pool_link: min of the two
        pools' injection bandwidths); time follows the same p2p formula
        (bytes/bw + launch + latency) and energy charges one endpoint
        device per side through ``endpoint_powers`` (the prefill and
        decode pools' own PowerModels).
    """

    def __init__(self, coll: CollectiveModel, mode: str = "layerwise",
                 link: Optional[NetworkLevel] = None,
                 endpoint_powers: Optional[Sequence] = None):
        if mode not in ("layerwise", "blocking"):
            raise ValueError(f"unknown transfer mode {mode!r}")
        self.coll = coll
        self.mode = mode
        self.link = link
        self.endpoint_powers = tuple(endpoint_powers) if endpoint_powers \
            else (coll.power, coll.power)

    def _link_query(self, nbytes: float) -> tuple:
        """(time_s, energy_j) to move ``nbytes`` over the explicit link."""
        lvl = self.link
        t = nbytes / lvl.bw_per_device + lvl.launch_s + lvl.latency_s
        e = sum(p.energy(t, utilization=0.15) for p in self.endpoint_powers)
        return t, e

    def kv_bytes(self, model: ModelIR, ctx_len: int, quant: str) -> float:
        """Payload bytes for one request's cache at ``ctx_len`` tokens."""
        q = get_format(quant)
        per_tok = model.kv_bytes_per_token(q)
        state = model.state_bytes_per_seq(q)   # SSM/hybrid recurrent state
        return per_tok * ctx_len + state

    def estimate(self, model: ModelIR, ctx_len: int, quant: str,
                 span: int, lanes: int = 1) -> TransferEstimate:
        """Cost one request's handoff over the cross-pool link.

        ``span`` is the device span of the link (pools.cross_pool_span);
        ``lanes`` is how many links move shards concurrently.
        """
        nbytes = self.kv_bytes(model, ctx_len, quant)
        if nbytes <= 0:       # attention-free model: nothing to ship
            return TransferEstimate(0.0, 0.0, 0.0, 0.0)
        lanes = max(1, lanes)
        query = self._link_query if self.link is not None else \
            (lambda b: self.coll.query("p2p", b, span))
        wire, energy = query(nbytes / lanes)
        if self.mode == "blocking":
            delay = wire
        else:
            layers = max(1, model.block.repeat)
            delay, _ = query(nbytes / (lanes * layers))
        return TransferEstimate(nbytes=nbytes, delay_s=delay, wire_s=wire,
                                energy_j=energy)
