"""Coupled two-pool simulation of disaggregated prefill/decode serving.

Both pools run inside ONE event engine (core/engine.py) on a single
global clock: the prefill pool's replicas run prefill-only iterations
(requests truncated to their first token), finished prompts hand their KV
cache to the decode pool through the KV-transfer model, and the decode
pool runs decode-only continuous batching with *transfer-delayed
admissions* — a request becomes visible to a decode replica when its
transfer completes on the shared cross-pool wire.

Engine coupling (both on by default, switchable for A/B studies):

  * ``congestion=True`` — simultaneous prefill completions contend for
    the cross-pool link: transfers claim a ``SharedLink`` FIFO in
    completion order, each occupying the wire for its full serialization
    time (layerwise streams lead the completion by ``stream_lead_s``).
    With ``congestion=False`` (or a wire fast enough never to queue)
    every transfer takes its independent per-request time — the
    pre-engine behavior, kept as the golden baseline.
  * ``reprefill_occupancy=True`` — a decode-pool preemption routes the
    victim's re-fetch back through the engine as a REAL re-prefill job
    on the prefill pool (occupying it, delaying other prompts' TTFT)
    followed by a fresh transfer over the shared link.  With
    ``reprefill_occupancy=False`` the victim is only charged the
    full-cache wire delay (the pre-engine model: the delay was paid but
    the prefill pool never re-ran the prompt).

Per-pool policies: ``simulate(prefill_policy=..., decode_policy=...)``
(or the same fields on ``DisaggPlan``) drive each pool's replicas with
their own ``SchedulerPolicy`` — e.g. chunked prefill only on the prefill
pool — defaulting to the shared ``policy``.

Heterogeneous pools: when the plan carries per-pool clusters (different
``DeviceSpec`` per pool), each pool's iteration costs, KV capacity, and
energy come from its OWN cluster — per-pool ``ProfileStore`` /
``CollectiveModel`` (and therefore each pool's own ``PowerModel``) — and
the KV handoff is costed on the plan's explicit cross-pool network level.
With a shared cluster this degenerates to the homogeneous behavior.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from ..core.batching import BatchingPolicy, RequestRecord, SwapCost
from ..core.engine import Engine, SharedCostStore, SharedLink
from ..core.ir import Workload
from ..core.metrics import SimulationReport, request_metrics, \
    windowed_metrics
from ..core.profiles import AnalyticBackend, CollectiveModel, ProfileStore
from ..core.simulator import PlanSimulator, default_swap_cost
from ..core.trace import Request, retag_slo
from ..serving.router import BacklogBalancer, derive_drain_rate
from .kv_transfer import KVTransferModel
from .pools import DisaggPlan


class DisaggSimulator:
    """Costs one DisaggPlan by running its two pools against one trace.

    ``store``/``coll`` cost the prefill pool; ``decode_store``/
    ``decode_coll`` the decode pool.  For homogeneous plans the decode-side
    objects default to the prefill-side ones (one shared cluster); for
    heterogeneous plans they default to fresh analytic models of the decode
    pool's own cluster.
    """

    def __init__(self, plan: DisaggPlan, store: ProfileStore,
                 coll: CollectiveModel,
                 kv_model: Optional[KVTransferModel] = None,
                 decode_store: Optional[ProfileStore] = None,
                 decode_coll: Optional[CollectiveModel] = None,
                 cost_store: Optional[SharedCostStore] = None):
        self.plan = plan
        self.scheme = plan.scheme
        if decode_coll is None:
            decode_coll = coll if plan.homogeneous else CollectiveModel(
                plan.decode_cluster, freq_ghz=coll.power.freq_ghz)
        if decode_store is None:
            # inherit frequency/grid granularity from the prefill side so
            # the two pools are costed under one regime
            decode_store = store if plan.homogeneous else ProfileStore(
                AnalyticBackend(plan.decode_cluster,
                                freq_ghz=getattr(store.backend,
                                                 "freq_ghz", None)),
                grid_stride=store.grid_stride)
        if kv_model is None:
            kv_model = KVTransferModel(
                coll, plan.scheme.transfer_mode, link=plan.cross_level,
                endpoint_powers=None if plan.cross_level is None
                else (coll.power, decode_coll.power))
        self.kv = kv_model
        if self.kv.mode != plan.scheme.transfer_mode:
            raise ValueError(
                f"kv_model mode {self.kv.mode!r} != scheme transfer mode "
                f"{plan.scheme.transfer_mode!r}")
        self.pre_sim = PlanSimulator(plan.prefill_plan, store, coll,
                                     cost_store=cost_store)
        self.dec_sim = PlanSimulator(plan.decode_plan, decode_store,
                                     decode_coll, cost_store=cost_store)
        # last simulate()'s combined pool cache counters (cost reuse)
        self.cache_stats = {"hits": 0, "misses": 0, "entries": 0,
                            "evictions": 0}

    # -- helpers --------------------------------------------------------------

    def _drain_rates(self, requests: Sequence[Request],
                     dec_policy: BatchingPolicy) -> tuple:
        """Per-replica drain rates for the two pools' backlog balancers,
        derived from each pool's OWN iteration throughput on a
        trace-representative workload (mean prompt for the prefill pool;
        a mean-KV decode batch for the decode pool)."""
        n = max(1, len(requests))
        ctx = max(1, sum(r.context_len for r in requests) // n)
        gen = max(1, sum(r.gen_len for r in requests) // n)
        w_pre = Workload.from_batch([(ctx, ctx)], [], self.pre_sim.windows,
                                    batch_sequences=1)
        t_pre, _ = self.pre_sim.iteration_cost(w_pre)
        bs = dec_policy.max_batch_size or 32
        w_dec = Workload.from_batch([], [ctx + gen // 2] * bs,
                                    self.dec_sim.windows,
                                    batch_sequences=bs)
        t_dec, _ = self.dec_sim.iteration_cost(w_dec)
        return (derive_drain_rate(ctx, t_pre, fallback=4096.0),
                derive_drain_rate(bs, t_dec, fallback=512.0))

    # -- full-trace simulation ------------------------------------------------

    def simulate(self, requests: Sequence[Request],
                 policy: Optional[BatchingPolicy] = None,
                 keep_records: bool = False,
                 prefill_policy: Optional[BatchingPolicy] = None,
                 decode_policy: Optional[BatchingPolicy] = None,
                 congestion: bool = True,
                 reprefill_occupancy: bool = True,
                 link: Optional[SharedLink] = None,
                 preemption=None,
                 swap_cost: Optional[SwapCost] = None,
                 slo_classes=None,
                 faults=None,
                 window_s: Optional[float] = None) -> SimulationReport:
        """``preemption`` drives BOTH pools' KV-overflow handling (menu
        string or ``PreemptionPolicy``; None = sacrifice + recent-first).
        Under ``swap`` a decode-pool victim's KV parks on the host —
        never leaving the node — so the re-prefill/re-transfer coupling
        (``on_preempt``) fires only for sacrifice.  ``swap_cost``
        overrides the per-pool PCIe host-link pricing; ``slo_classes``
        re-tags the trace's SLO classes by name.

        ``faults`` (a ``core.faults.FaultSchedule``) injects pool-aware
        fail-stops ("prefill"/"decode"/"*" targets), stragglers, and
        cross-pool ``LinkDegradation`` windows (the shared wire's
        transfer times stretch inside them); the report then carries a
        ``resilience`` block.  A decode-pool failure's victims re-fetch
        their prompt KV through the prefill pool, exactly like
        sacrificed preemptees.

        ``window_s`` attaches a per-window metric timeline; per-pool
        policies may carry ``admission_watermark`` gates — rejected
        requests are excluded from the latency stats and counted in
        ``admission_rejected``."""
        plan = self.plan
        requests = retag_slo(requests, slo_classes)
        faulted = faults is not None and not faults.empty
        if faulted and not reprefill_occupancy:
            # the staged baseline drains the two pools back-to-back on
            # detached schedules — a mid-run failure has no coupled
            # dynamics to degrade there
            raise ValueError("fault injection requires "
                             "reprefill_occupancy=True (the coupled "
                             "two-pool mode)")
        pre_pol = (prefill_policy or plan.prefill_policy or policy
                   or BatchingPolicy())
        dec_pol = (decode_policy or plan.decode_policy or policy
                   or BatchingPolicy())
        if pre_pol.mode == "static" or dec_pol.mode == "static":
            # static batching has no meaningful decode-only pool (the
            # strawman prefills and drains one batch at a time); report
            # the plan as infeasible rather than crash mid-search
            return SimulationReport.infeasible(plan.label())
        pre_s, dec_s = self.scheme.prefill, self.scheme.decode
        pre_cap = pre_s.kv_token_capacity(
            plan.prefill_cluster.device.hbm_bytes)
        dec_cap = dec_s.kv_token_capacity(
            plan.decode_cluster.device.hbm_bytes)
        if pre_cap <= 0 or dec_cap <= 0:
            return SimulationReport.infeasible(plan.label())

        is_encdec = self.scheme.model.encoder is not None
        by_rid = {r.rid: r for r in requests}
        lanes = min(pre_s.devices_per_replica, dec_s.devices_per_replica)
        ests: Dict[int, object] = {}

        def est_of(req: Request):
            if req.rid not in ests:
                ests[req.rid] = self.kv.estimate(
                    self.scheme.model, req.context_len, pre_s.quant,
                    plan.transfer_span, lanes=lanes)
            return ests[req.rid]

        pre_rate, dec_rate = self._drain_rates(requests, dec_pol)

        # ---- prefill pool: prefill-only iterations, balancer-routed ----
        # (decayed shortest-queue dispatch — the same balancer the serving
        # PoolRouter uses, so simulated and real dispatch agree; the
        # balancer instance stays live to also place re-prefill jobs)
        pre_reqs = [dataclasses.replace(r, gen_len=1) for r in requests]
        pre_bal = BacklogBalancer(pre_s.model_dp, drain_rate=pre_rate)
        pre_buckets: List[List[Request]] = [[] for _ in range(pre_s.model_dp)]
        for r in sorted(pre_reqs, key=lambda r: (r.arrival, r.rid)):
            pre_buckets[pre_bal.assign(r.arrival,
                                       float(r.context_len))].append(r)

        engine = Engine()
        if link is None:
            link = SharedLink(congestion=congestion,
                              degradation=faults.link_factor
                              if faulted and faults.link_faults else None)
        elif faulted and faults.link_faults and link.degradation is None:
            link.degradation = faults.link_factor
        dec_bal = BacklogBalancer(dec_s.model_dp, drain_rate=dec_rate)
        parked: Dict[int, tuple] = {}   # refetch rid -> (replica, req, t0)
        state = {"refetch_seq": 0}
        finishes: List[tuple] = []      # staged mode: (finish_time, req)

        def on_prefill_finish(replica, req, rec, now):
            if not reprefill_occupancy:
                # no decode->prefill feedback: transfers are resolved in
                # finish order after the prefill pool drains (staged run),
                # which hands the decode pool its full arrival horizon —
                # the same information structure as the pre-engine loops
                finishes.append((now, by_rid[req.rid]))
                return
            if req.rid < 0:
                # a re-prefill occupancy job completed: re-ship the cache
                # and return the victim to its decode replica
                dec_rep, victim, t0 = parked.pop(req.rid)
                est = est_of(victim)
                done = link.transfer(now, est)
                dec_pool.incoming_unknown -= 1

                def stamp_and_route(t, rep=dec_rep, v=victim, t0=t0):
                    vrec = rep.records[v.rid]
                    vrec.refetch_s += t - t0
                    rep.kv_refetch_s += t - t0
                    return rep

                engine.deliver(dec_pool, stamp_and_route,
                               dataclasses.replace(victim, arrival=done),
                               done)
                return
            orig = by_rid[req.rid]
            if orig.gen_len <= 1:       # finishes at the prefill pool
                return
            done = link.transfer(now, est_of(orig))
            engine.deliver(
                dec_pool,
                lambda t, g=float(orig.gen_len):
                dec_pool.replicas[dec_bal.assign(t, g)],
                dataclasses.replace(orig, arrival=done), done)

        def on_decode_preempt(dec_rep, victim, now):
            # route the re-fetch through the engine: a REAL re-prefill on
            # the prefill pool (occupying it), then a fresh transfer.
            # Placement reads the prefill replicas' LIVE queue depth (the
            # trace pre-pass balancer's clock has already run to the last
            # arrival and would see a stale, future-contaminated backlog)
            state["refetch_seq"] -= 1
            rid = state["refetch_seq"]
            job = Request(rid=rid, arrival=now,
                          context_len=victim.context_len, gen_len=1,
                          source_len=victim.source_len)
            parked[rid] = (dec_rep, victim, now)
            dec_pool.incoming_unknown += 1
            target = min(
                pre_pool.replicas,
                key=lambda rep: (sum(r.context_len for r in rep.pending)
                                 + sum(a.prefill_remaining
                                       for a in rep.active), rep.index))
            target.shadow.add(rid)
            engine.deliver(pre_pool, target, job, now)

        def refetch_wire_delay(r: Request) -> float:
            # delay-only model: full-cache wire time (no prefill left to
            # stream behind), costed through the same transfer model
            return est_of(r).wire_s

        fault_key = faults.cost_key() if faulted else ()
        dec_cache = self.dec_sim.cost_cache(fault_key=fault_key)
        pre_cache = self.pre_sim.cost_cache(fault_key=fault_key)

        def add_decode_pool(buckets):
            return engine.add_pool(
                "decode", buckets, dec_cap, dec_pol, dec_cache,
                windows=self.dec_sim.windows, is_encdec=is_encdec,
                role="decode",
                refetch_delay=None if reprefill_occupancy
                else refetch_wire_delay,
                on_preempt=on_decode_preempt if reprefill_occupancy
                else None,
                preemption=preemption,
                swap_cost=swap_cost or default_swap_cost(
                    dec_s, power=self.dec_sim.coll.power))

        pre_pool = engine.add_pool(
            "prefill", pre_buckets, pre_cap, pre_pol, pre_cache,
            windows=self.pre_sim.windows, is_encdec=is_encdec,
            on_finish=on_prefill_finish,
            preemption=preemption,
            swap_cost=swap_cost or default_swap_cost(
                pre_s, power=self.pre_sim.coll.power))
        if reprefill_occupancy:
            # fully coupled: one joint event loop; transfers and re-fetch
            # re-prefills flow between the pools as live events
            dec_pool = add_decode_pool([[] for _ in range(dec_s.model_dp)])
            dec_pool.upstream = pre_pool   # bounds decode fast-forward
            if faulted:
                engine.install_faults(faults)
            engine.run()
        else:
            # staged: drain the prefill pool, resolve transfers through
            # the (possibly congested) link in completion order, then run
            # the decode pool with every arrival known
            engine.run()
            dec_reqs = []
            for t_finish, req in finishes:
                if req.gen_len <= 1:
                    continue
                done = link.transfer(t_finish, est_of(req))
                dec_reqs.append(dataclasses.replace(req, arrival=done))
            dec_buckets: List[List[Request]] = [
                [] for _ in range(dec_s.model_dp)]
            for r in sorted(dec_reqs, key=lambda r: (r.arrival, r.rid)):
                dec_buckets[dec_bal.assign(r.arrival,
                                           float(r.gen_len))].append(r)
            dec_pool = add_decode_pool(dec_buckets)
            engine.run()

        pre_results = pre_pool.results()
        dec_results = dec_pool.results()
        self.cache_stats = {
            k: pre_cache.stats()[k] + dec_cache.stats()[k]
            for k in ("hits", "misses", "entries", "evictions")}
        results = pre_results + dec_results
        if not results:
            return SimulationReport.infeasible(plan.label())

        # replay memoized cost calls into the utilization accumulators in
        # pool/replica order (the legacy sequential summation order)
        for sim, pool in ((self.pre_sim, pre_pool),
                          (self.dec_sim, dec_pool)):
            sim._flops_accum = 0.0
            sim._bytes_accum = 0.0
            pool.replay_accumulators(sim)

        pre_records: Dict[int, RequestRecord] = {
            rec.rid: rec for res in pre_results for rec in res.records}
        dec_records: Dict[int, RequestRecord] = {
            rec.rid: rec for res in dec_results for rec in res.records}

        # ---- transfer energy: every shipped cache + every re-fetch ----
        # (energy is congestion-independent — the same bytes cross the
        # wire whether or not they queued)
        transfer_energy = 0.0
        for rid in pre_records:
            req = by_rid[rid]
            if req.gen_len <= 1:
                continue
            transfer_energy += est_of(req).energy_j
        for rec in dec_records.values():
            # only sacrificed victims re-ship over the wire; a swapped
            # victim's KV parks on the host and never crosses the link
            sacrifices = rec.preemptions - rec.swaps
            if sacrifices > 0:
                transfer_energy += sacrifices * est_of(
                    by_rid[rec.rid]).energy_j

        # ---- merge per-request records across the two pools ----
        merged: List[RequestRecord] = []
        for rid, pre_rec in sorted(pre_records.items()):
            req = by_rid[rid]
            rec = RequestRecord(rid, req.arrival, req.context_len,
                                req.gen_len, slo_class=req.slo_class)
            rec.first_token_time = pre_rec.first_token_time
            rec.rejected = pre_rec.rejected
            dec_rec = dec_records.get(rid)
            if dec_rec is not None:
                rec.finish_time = dec_rec.finish_time
                rec.preemptions = pre_rec.preemptions + dec_rec.preemptions
                rec.refetch_s = dec_rec.refetch_s
                rec.swaps = pre_rec.swaps + dec_rec.swaps
                rec.swap_s = pre_rec.swap_s + dec_rec.swap_s
                rec.rejected = rec.rejected or dec_rec.rejected
            else:                      # gen_len == 1: done at prefill
                rec.finish_time = pre_rec.finish_time
                rec.preemptions = pre_rec.preemptions
                rec.swaps = pre_rec.swaps
                rec.swap_s = pre_rec.swap_s
            merged.append(rec)

        merged = [r for r in merged if not r.rejected]
        all_merged = merged
        if faulted:
            # stranded on a dead replica with no survivor: never finished
            merged = [r for r in merged if r.finish_time > 0.0]
        total_time = max(res.total_time for res in results)
        total_energy = (sum(res.total_energy for res in results)
                        + transfer_energy)
        gen_tokens = sum(r.gen_len for r in merged)

        # utilization against each pool's OWN silicon: a H100-prefill/
        # H200-decode deployment is normalized by the sum of per-pool
        # peak rates, not one device's numbers
        pre_dev = plan.prefill_cluster.device
        dec_dev = plan.decode_cluster.device
        n_pre, n_dec = self.scheme.prefill_devices, self.scheme.decode_devices
        flops = self.pre_sim._flops_accum + self.dec_sim._flops_accum
        nbytes = self.pre_sim._bytes_accum + self.dec_sim._bytes_accum
        peak = (n_pre * pre_dev.flops(self.pre_sim.q.compute_dtype)
                + n_dec * dec_dev.flops(self.dec_sim.q.compute_dtype))
        bw = n_pre * pre_dev.hbm_bw + n_dec * dec_dev.hbm_bw
        mfu = flops / (total_time * peak) if total_time > 0 else 0.0
        mbu = nbytes / (total_time * bw) if total_time > 0 else 0.0

        resilience = None
        if faulted:
            from ..core.faults import build_resilience
            resilience = build_resilience(
                faults, all_merged, total_time,
                {"prefill": pre_s.model_dp, "decode": dec_s.model_dp},
                engine.fault_requeues)

        return SimulationReport(
            plan_label=plan.label(),
            e2e_latency=total_time,
            total_energy=total_energy,
            throughput_tok_s=gen_tokens / total_time if total_time else 0.0,
            mfu=min(mfu, 1.0), mbu=min(mbu, 1.0),
            iterations=sum(r.iterations for r in results),
            preemptions=sum(r.preemptions for r in results),
            peak_kv_tokens=max(r.peak_kv_tokens for r in results),
            peak_batch=max(r.peak_batch for r in results),
            feasible=True,
            records=merged if keep_records else None,
            swap_outs=sum(r.swap_outs for r in results),
            swap_ins=sum(r.swap_ins for r in results),
            kv_swap_s=sum(r.kv_swap_s for r in results),
            kv_refetch_s=sum(r.kv_refetch_s for r in results),
            resilience=resilience,
            admission_rejected=sum(r.admission_rejected for r in results),
            admission_deferred=sum(r.admission_deferred for r in results),
            windows=(windowed_metrics(merged, window_s=window_s,
                                      horizon=total_time)
                     if window_s is not None else None),
            **request_metrics(merged, total_time))
