"""Coupled two-pool simulation of disaggregated prefill/decode serving.

The prefill pool runs prefill-only iterations (requests truncated to their
first token), finished prompts hand their KV cache to the decode pool
through the KV-transfer model, and the decode pool runs decode-only
continuous batching with *transfer-delayed admissions*: a request becomes
visible to the decode pool only at

    prefill_finish + transfer_delay(ctx_len, transfer_mode).

Both pools are ordinary ``BatchingModule`` instances driven by their own
``PlanSimulator`` iteration-cost callbacks — the decode pool in
``role="decode"`` (admission materializes the shipped prompt KV).  Both
pools share one virtual clock origin, so the merged per-request records
(TTFT from the prefill pool, completion from the decode pool) compose into
the same ``SimulationReport`` the colocated simulator emits, and the joint
search (core/search.py) ranks colocated and disaggregated plans under one
objective.

Heterogeneous pools: when the plan carries per-pool clusters (different
``DeviceSpec`` per pool), each pool's iteration costs, KV capacity, and
energy come from its OWN cluster — per-pool ``ProfileStore`` /
``CollectiveModel`` (and therefore each pool's own ``PowerModel``) — and
the KV handoff is costed on the plan's explicit cross-pool network level.
With a shared cluster this degenerates to the homogeneous PR-1 behavior.

First-order modeling choices, in the open:
  * per-request transfers are independent (no cross-pool link congestion);
  * prefill-side KV is freed at handoff (no holding cost while draining);
  * a decode-pool preemption re-fetches its prompt KV through the same
    KV-transfer model (full-cache wire time — a re-fetch cannot stream
    behind a prefill that already happened) and its wire energy is charged.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from ..core.batching import (BatchingModule, BatchingPolicy, BatchingResult,
                             RequestRecord)
from ..core.profiles import AnalyticBackend, CollectiveModel, ProfileStore
from ..core.simulator import PlanSimulator, SimulationReport, _p95
from ..core.trace import Request
from ..serving.router import BacklogBalancer
from .kv_transfer import KVTransferModel
from .pools import DisaggPlan


class DisaggSimulator:
    """Costs one DisaggPlan by running its two pools against one trace.

    ``store``/``coll`` cost the prefill pool; ``decode_store``/
    ``decode_coll`` the decode pool.  For homogeneous plans the decode-side
    objects default to the prefill-side ones (one shared cluster); for
    heterogeneous plans they default to fresh analytic models of the decode
    pool's own cluster.
    """

    def __init__(self, plan: DisaggPlan, store: ProfileStore,
                 coll: CollectiveModel,
                 kv_model: Optional[KVTransferModel] = None,
                 decode_store: Optional[ProfileStore] = None,
                 decode_coll: Optional[CollectiveModel] = None):
        self.plan = plan
        self.scheme = plan.scheme
        if decode_coll is None:
            decode_coll = coll if plan.homogeneous else CollectiveModel(
                plan.decode_cluster, freq_ghz=coll.power.freq_ghz)
        if decode_store is None:
            # inherit frequency/grid granularity from the prefill side so
            # the two pools are costed under one regime
            decode_store = store if plan.homogeneous else ProfileStore(
                AnalyticBackend(plan.decode_cluster,
                                freq_ghz=getattr(store.backend,
                                                 "freq_ghz", None)),
                grid_stride=store.grid_stride)
        if kv_model is None:
            kv_model = KVTransferModel(
                coll, plan.scheme.transfer_mode, link=plan.cross_level,
                endpoint_powers=None if plan.cross_level is None
                else (coll.power, decode_coll.power))
        self.kv = kv_model
        if self.kv.mode != plan.scheme.transfer_mode:
            raise ValueError(
                f"kv_model mode {self.kv.mode!r} != scheme transfer mode "
                f"{plan.scheme.transfer_mode!r}")
        self.pre_sim = PlanSimulator(plan.prefill_plan, store, coll)
        self.dec_sim = PlanSimulator(plan.decode_plan, decode_store,
                                     decode_coll)

    # -- helpers --------------------------------------------------------------

    def _infeasible(self) -> SimulationReport:
        return SimulationReport(
            plan_label=self.plan.label(), e2e_latency=float("inf"),
            total_energy=float("inf"), ttft_mean=0, ttft_p95=0,
            tpot_mean=0, tpot_p95=0, latency_p95=0, throughput_tok_s=0,
            mfu=0, mbu=0, iterations=0, preemptions=0, peak_kv_tokens=0,
            peak_batch=0, feasible=False)

    @staticmethod
    def _route(requests: Sequence[Request], n_replicas: int, cost_of,
               drain_rate: float) -> List[List[Request]]:
        """Decayed shortest-queue dispatch across a pool's replicas — the
        same balancer (and per-pool drain rates) the serving PoolRouter
        uses (serving/router.py), so simulated and real dispatch agree."""
        bal = BacklogBalancer(n_replicas, drain_rate=drain_rate)
        buckets: List[List[Request]] = [[] for _ in range(n_replicas)]
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            buckets[bal.assign(r.arrival, cost_of(r))].append(r)
        return buckets

    # -- full-trace simulation ------------------------------------------------

    def simulate(self, requests: Sequence[Request],
                 policy: Optional[BatchingPolicy] = None,
                 keep_records: bool = False) -> SimulationReport:
        policy = policy or BatchingPolicy()
        if policy.mode == "static":
            # static batching has no meaningful decode-only pool (the
            # strawman prefills and drains one batch at a time); report
            # the plan as infeasible rather than crash mid-search
            return self._infeasible()
        # the pool simulators' MFU/MBU accumulators are driven through
        # iteration_cost (not their own simulate()), so reset them here
        for sim in (self.pre_sim, self.dec_sim):
            sim._flops_accum = 0.0
            sim._bytes_accum = 0.0
        pre_s, dec_s = self.scheme.prefill, self.scheme.decode
        pre_cap = pre_s.kv_token_capacity(
            self.plan.prefill_cluster.device.hbm_bytes)
        dec_cap = dec_s.kv_token_capacity(
            self.plan.decode_cluster.device.hbm_bytes)
        if pre_cap <= 0 or dec_cap <= 0:
            return self._infeasible()

        is_encdec = self.scheme.model.encoder is not None

        # ---- prefill pool: prefill-only iterations ----
        pre_reqs = [dataclasses.replace(r, gen_len=1) for r in requests]
        pre_buckets = self._route(pre_reqs, pre_s.model_dp,
                                  lambda r: float(r.context_len),
                                  drain_rate=4096.0)
        pre_results: List[BatchingResult] = []
        for bucket in pre_buckets:
            if not bucket:
                continue
            module = BatchingModule(pre_cap, policy,
                                    model_windows=self.pre_sim.windows,
                                    is_encdec=is_encdec)
            pre_results.append(module.run(bucket,
                                          self.pre_sim.iteration_cost))
        pre_records: Dict[int, RequestRecord] = {
            rec.rid: rec for res in pre_results for rec in res.records}

        # ---- KV handoff: transfer-delayed decode admission ----
        # gen_len <= 1 requests finish at the prefill pool and never ship
        by_rid = {r.rid: r for r in requests}
        lanes = min(pre_s.devices_per_replica, dec_s.devices_per_replica)
        transfer_energy = 0.0
        dec_reqs: List[Request] = []
        for rid, rec in pre_records.items():
            req = by_rid[rid]
            if req.gen_len <= 1:
                continue
            est = self.kv.estimate(self.scheme.model, req.context_len,
                                   pre_s.quant, self.plan.transfer_span,
                                   lanes=lanes)
            transfer_energy += est.energy_j
            ready = rec.finish_time + est.delay_s
            dec_reqs.append(dataclasses.replace(req, arrival=ready))

        # ---- decode pool: decode-only continuous batching ----
        # a preempted request must re-fetch its prompt KV before it can be
        # re-admitted: full-cache wire time (no prefill left to stream
        # behind), costed through the same transfer model
        def refetch_delay(r: Request) -> float:
            return self.kv.estimate(self.scheme.model, r.context_len,
                                    pre_s.quant, self.plan.transfer_span,
                                    lanes=lanes).wire_s

        dec_buckets = self._route(dec_reqs, dec_s.model_dp,
                                  lambda r: float(r.gen_len),
                                  drain_rate=512.0)
        dec_results: List[BatchingResult] = []
        for bucket in dec_buckets:
            if not bucket:
                continue
            module = BatchingModule(dec_cap, policy,
                                    model_windows=self.dec_sim.windows,
                                    is_encdec=is_encdec, role="decode",
                                    refetch_delay=refetch_delay)
            dec_results.append(module.run(bucket,
                                          self.dec_sim.iteration_cost))
        dec_records: Dict[int, RequestRecord] = {
            rec.rid: rec for res in dec_results for rec in res.records}
        # each re-fetch re-serializes the cache on the wire: charge it
        for rec in dec_records.values():
            if rec.preemptions:
                est = self.kv.estimate(self.scheme.model,
                                       by_rid[rec.rid].context_len,
                                       pre_s.quant, self.plan.transfer_span,
                                       lanes=lanes)
                transfer_energy += rec.preemptions * est.energy_j

        # ---- merge per-request records across the two pools ----
        merged: List[RequestRecord] = []
        for rid, pre_rec in sorted(pre_records.items()):
            req = by_rid[rid]
            rec = RequestRecord(rid, req.arrival, req.context_len,
                                req.gen_len)
            rec.first_token_time = pre_rec.first_token_time
            dec_rec = dec_records.get(rid)
            if dec_rec is not None:
                rec.finish_time = dec_rec.finish_time
                rec.preemptions = pre_rec.preemptions + dec_rec.preemptions
                rec.refetch_s = dec_rec.refetch_s
            else:                      # gen_len == 1: done at prefill
                rec.finish_time = pre_rec.finish_time
                rec.preemptions = pre_rec.preemptions
            merged.append(rec)

        ttfts = [r.ttft for r in merged]
        tpots = [r.tpot for r in merged if r.gen_len > 1]
        e2es = [r.e2e for r in merged]
        results = pre_results + dec_results
        if not results:
            return self._infeasible()
        total_time = max(res.total_time for res in results)
        total_energy = (sum(res.total_energy for res in results)
                        + transfer_energy)
        gen_tokens = sum(r.gen_len for r in merged)

        # utilization against each pool's OWN silicon: a H100-prefill/
        # H200-decode deployment is normalized by the sum of per-pool
        # peak rates, not one device's numbers
        pre_dev = self.plan.prefill_cluster.device
        dec_dev = self.plan.decode_cluster.device
        n_pre, n_dec = self.scheme.prefill_devices, self.scheme.decode_devices
        flops = self.pre_sim._flops_accum + self.dec_sim._flops_accum
        nbytes = self.pre_sim._bytes_accum + self.dec_sim._bytes_accum
        peak = (n_pre * pre_dev.flops(self.pre_sim.q.compute_dtype)
                + n_dec * dec_dev.flops(self.dec_sim.q.compute_dtype))
        bw = n_pre * pre_dev.hbm_bw + n_dec * dec_dev.hbm_bw
        mfu = flops / (total_time * peak) if total_time > 0 else 0.0
        mbu = nbytes / (total_time * bw) if total_time > 0 else 0.0

        return SimulationReport(
            plan_label=self.plan.label(),
            e2e_latency=total_time,
            total_energy=total_energy,
            ttft_mean=sum(ttfts) / len(ttfts) if ttfts else 0.0,
            ttft_p95=_p95(ttfts),
            tpot_mean=sum(tpots) / len(tpots) if tpots else 0.0,
            tpot_p95=_p95(tpots),
            latency_p95=_p95(e2es),
            throughput_tok_s=gen_tokens / total_time if total_time else 0.0,
            mfu=min(mfu, 1.0), mbu=min(mbu, 1.0),
            iterations=sum(r.iterations for r in results),
            preemptions=sum(r.preemptions for r in results),
            peak_kv_tokens=max(r.peak_kv_tokens for r in results),
            peak_batch=max(r.peak_batch for r in results),
            feasible=True,
            records=merged if keep_records else None)
