"""Disaggregated prefill/decode serving subsystem.

Splits one cluster into a prefill pool and a decode pool, each with its own
parallel scheme, couples them through a KV-transfer cost model, and plugs
into the APEX plan search (``ApexSearch.search(..., disaggregated=True)``)
so colocated and disaggregated plans are ranked under one objective.
"""

from .kv_transfer import KVTransferModel, TransferEstimate
from .pools import (DisaggPlan, DisaggScheme, cross_pool_span,
                    generate_disagg_schemes, is_mixed_label,
                    map_disagg_scheme, pool_splits)
from .simulate import DisaggSimulator

__all__ = [
    "DisaggPlan", "DisaggScheme", "DisaggSimulator", "KVTransferModel",
    "TransferEstimate", "cross_pool_span", "generate_disagg_schemes",
    "is_mixed_label", "map_disagg_scheme", "pool_splits",
]
