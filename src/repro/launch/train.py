"""Training driver: runnable end-to-end loop with checkpoint/restart.

CPU-scale by default (reduced configs); the same step factory lowers on
the production mesh in the dry-run.  Demonstrates: data pipeline ->
microbatched AdamW step -> atomic checkpoints -> crash-resume.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --steps 20 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.data.pipeline import TokenPipeline
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.models import encdec as ED
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import adamw_init


def train(arch: str = "internlm2-1.8b", steps: int = 20, batch: int = 8,
          seq: int = 64, microbatches: int = 1, ckpt_dir: str = None,
          ckpt_every: int = 10, reduced: bool = True, seed: int = 0,
          log=print):
    cfg = C.get_reduced(arch) if reduced else C.get_config(arch)
    rng = jax.random.PRNGKey(seed)
    if cfg.encoder is not None:
        params = ED.init_encdec_params(rng, cfg)
    else:
        params = T.init_params(rng, cfg)
    opt = adamw_init(params)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=seq,
                         global_batch=batch, seed=seed)
    step_fn = jax.jit(make_train_step(cfg, microbatches=microbatches,
                                      remat=True))
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if mgr and mgr.list_checkpoints():
        start_step, (params, opt), extra = mgr.restore((params, opt))
        log(f"resumed from step {start_step}")

    losses = []
    for step in range(start_step, steps):
        data = pipe.global_batch_at(step)
        batch_in = {"tokens": data["tokens"], "labels": data["labels"]}
        if cfg.encoder is not None:
            B = data["tokens"].shape[0]
            batch_in["frames"] = jax.random.normal(
                jax.random.fold_in(rng, step), (B, seq, cfg.d_model),
                jnp.float32)
        elif cfg.embeds_input:
            B = data["tokens"].shape[0]
            batch_in["embeds"] = jax.random.normal(
                jax.random.fold_in(rng, step), (B, seq, cfg.d_model),
                jnp.float32)
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch_in)
        loss = float(metrics["loss"])
        losses.append(loss)
        log(f"step {step}: loss {loss:.4f} "
            f"gnorm {float(metrics['grad_norm']):.3f} "
            f"[{time.perf_counter() - t0:.2f}s]")
        if mgr and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, (params, opt))
    if mgr:
        mgr.save(steps, (params, opt))
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full", action="store_true",
                    help="full (assigned) config instead of reduced")
    args = ap.parse_args()
    train(args.arch, args.steps, args.batch, args.seq, args.microbatches,
          args.ckpt_dir, reduced=not args.full)


if __name__ == "__main__":
    main()
