"""Input-shape cells for the dry-run: ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, NO device allocation.

Cells (applied per arch; skips per configs/<arch>.SKIP_SHAPES):
    train_4k     seq 4096  x global_batch 256   -> train_step
    prefill_32k  seq 32768 x global_batch 32    -> prefill forward
    decode_32k   seq 32768 x global_batch 128   -> serve_step (1 new token)
    long_500k    seq 524288 x global_batch 1    -> serve_step
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def _struct_like(tree):
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)


def input_specs(cfg: ModelConfig, shape: ShapeCell,
                cache_dtype=None) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell.

    train  -> {"tokens","labels"} (+ "frames"/"embeds" for stub frontends)
    prefill-> {"tokens"} / {"embeds"} / {"frames","tokens"}
    decode -> {"tokens": (B,1)} + "cache" structs sized to seq_len
    """
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    i32, dt = jnp.int32, jnp.dtype(cfg.dtype)

    if shape.kind == "train":
        out = {"labels": SDS((B, S), i32)}
        if cfg.encoder is not None:
            # enc-dec: source frames length == seq budget, short targets
            out["frames"] = SDS((B, S, d), dt)
            out["tokens"] = SDS((B, max(256, S // 8)), i32)
            out["labels"] = SDS((B, max(256, S // 8)), i32)
        elif cfg.embeds_input:
            out["embeds"] = SDS((B, S, d), dt)
        else:
            out["tokens"] = SDS((B, S), i32)
        return out

    if shape.kind == "prefill":
        if cfg.encoder is not None:
            return {"frames": SDS((B, S, d), dt),
                    "tokens": SDS((B, 1), i32)}
        if cfg.embeds_input:
            return {"embeds": SDS((B, S, d), dt)}
        return {"tokens": SDS((B, S), i32)}

    # decode: one new token against a cache of S
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, B, S,
                             source_len=cfg.cross_source_len
                             if cfg.cross_attn else 0,
                             cache_dtype=cache_dtype))
    return {"tokens": SDS((B, 1), i32), "cache": _struct_like(cache)}
