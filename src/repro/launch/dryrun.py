import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (jax locks the
# device count at first init).  Everything below is ordinary code.

import argparse          # noqa: E402
import functools         # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs as C                          # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.launch.shapes import SHAPES, input_specs     # noqa: E402
from repro.launch.steps import make_serve_step, make_train_step  # noqa: E402
from repro.launch import hlo_utils                      # noqa: E402
from repro.models import transformer as T               # noqa: E402
from repro.models import encdec as ED                   # noqa: E402
from repro.models.config import ModelConfig             # noqa: E402
from repro.parallel.sharding import (batch_pspec, cache_pspecs,  # noqa: E402
                                     param_pspecs)
from repro.training.optimizer import adamw_init         # noqa: E402

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and record memory/cost/collective analysis.

This proves the distribution config is coherent without hardware: a
sharding mismatch, compile-time OOM, or unsupported collective fails the
cell.  Results feed EXPERIMENTS.md §Dry-run and the §Roofline analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
        --shape train_4k --multi-pod both --out results/dryrun.json
"""


# fp8 KV-cache overrides: cells whose bf16 KV cache cannot fit the pod
# (see EXPERIMENTS.md §Dry-run notes).
CACHE_DTYPE_OVERRIDES = {
    ("qwen1_5_32b", "decode_32k"): jnp.float8_e4m3fn,
}


def _struct_params(cfg: ModelConfig):
    if cfg.encoder is not None:
        return jax.eval_shape(
            lambda: ED.init_encdec_params(jax.random.PRNGKey(0), cfg))
    return jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))


def _shard(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda s: isinstance(s, P))


# q-head counts that don't divide the 16-wide model axis train without
# microbatching so the batch itself can reshard over ("data","model")
# around attention (see parallel/sharding.py head-alignment note).
_MB1_ARCHS = {"qwen2_0_5b", "qwen1_5_32b", "qwen2_vl_7b"}


def _analytic_workspace(cfg: ModelConfig, cell, mesh,
                        microbatches: int) -> float:
    """Per-device activation-workspace estimate (bytes) from the config +
    sharding layout.  Conservative (x2 live-set factor); validated against
    cells free of CPU dtype-normalization artifacts."""
    m = mesh.shape.get("model", 1)
    n_data = 1
    for a in ("pod", "data"):
        n_data *= mesh.shape.get(a, 1)
    B, S = cell.global_batch, cell.seq_len
    d = cfg.d_model
    dt = 2.0                                     # bf16
    v_loc = -(-cfg.vocab_size // m)
    hq = cfg.n_heads
    hd = cfg.resolved_head_dim

    def ceil_div(a, b):
        return -(-a // b)

    if cell.kind == "train":
        b_loc = ceil_div(ceil_div(B, microbatches), n_data)
        toks = b_loc * S
        ws = 16 * toks * d * dt                  # one live layer fwd+bwd
        ws += 2 * b_loc * 512 * v_loc * 4        # loss chunk logits (f32)
        if cfg.ffn_kind == "moe":
            # EP-sharded: e_loc experts at full width; else one expert at
            # a time with the d_ff dim TP-sharded (layers/moe.py layouts)
            if cfg.n_routed % m == 0:
                ws += 3 * ceil_div(cfg.n_routed, m) * toks \
                    * cfg.d_ff_expert * dt
            else:
                ws += 3 * toks * ceil_div(cfg.d_ff_expert, m) * dt
        elif cfg.d_ff:
            ws += 3 * toks * ceil_div(cfg.d_ff, m) * dt
        if any(s.kind == "ssm" for s in cfg.block_pattern):
            q = 128
            nC = ceil_div(S, q)
            ws += nC * b_loc * cfg.n_ssd_heads * \
                (cfg.d_inner // max(cfg.n_ssd_heads, 1)) * cfg.d_state * 4
        ws += 2 * b_loc * hq * 512 * 1024 * 4    # attention tiles (f32)
        return 2.0 * ws
    if cell.kind == "prefill":
        b_loc = ceil_div(B, n_data)
        toks = b_loc * S
        ws = 8 * toks * d * dt
        ws += 2 * b_loc * hq * 512 * 1024 * 4
        if cfg.ffn_kind == "moe":
            if cfg.n_routed % m == 0:
                ws += 3 * ceil_div(cfg.n_routed, m) * toks \
                    * cfg.d_ff_expert * dt
            else:
                ws += 3 * toks * ceil_div(cfg.d_ff_expert, m) * dt
        return 2.0 * ws
    # decode: per-layer KV repeat + scores + head logits
    b_loc = ceil_div(B, n_data)
    s_loc = S // m if S % m == 0 else S
    ws = 2 * b_loc * s_loc * hq * hd * dt        # kr/vr transient
    ws += b_loc * hq * s_loc * 4                 # scores f32
    ws += b_loc * v_loc * 4                      # logits
    ws += 8 * b_loc * d * dt * 64
    return 2.0 * ws


def lower_cell(arch: str, shape_name: str, mesh, *,
               microbatches: int = 4, cfg_override=None) -> dict:
    """Lower + compile one (arch x shape) cell on ``mesh``.

    ``cfg_override``: substitute ModelConfig (perf-iteration variants,
    e.g. head-padded deployments)."""
    cfg = cfg_override or C.get_config(arch)
    cell = SHAPES[shape_name]
    norm = C.ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if norm in _MB1_ARCHS and cfg_override is None:
        microbatches = 1
    cache_dtype = CACHE_DTYPE_OVERRIDES.get(
        (C.ALIASES.get(arch, arch).replace("-", "_").replace(".", "_"),
         shape_name))
    specs = input_specs(cfg, cell, cache_dtype=cache_dtype)
    params = _struct_params(cfg)
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dax = daxes if len(daxes) > 1 else daxes[0]

    # an active mesh enables PartitionSpec-based shard_hints inside model
    # code (set_mesh on new jax, the legacy global-mesh context on old)
    from .mesh import mesh_context
    mesh_ctx = mesh_context(mesh)

    with mesh_ctx:
        t0 = time.perf_counter()
        if cell.kind == "train":
            pspecs = param_pspecs(params, cfg, mesh, fsdp=True)
            opt = jax.eval_shape(adamw_init, params)
            ospecs = type(opt)(master=pspecs, m=pspecs, v=pspecs, step=P())
            # batch sharding: leading batch dim over the data axes
            bspecs = jax.tree.map(
                lambda s: P(dax, *([None] * (len(s.shape) - 1))), specs)
            step_fn = make_train_step(cfg, microbatches=microbatches,
                                      remat=True)
            lowered = jax.jit(
                step_fn,
                in_shardings=(_shard(pspecs, mesh), _shard(ospecs, mesh),
                              _shard(bspecs, mesh)),
                out_shardings=(_shard(pspecs, mesh), _shard(ospecs, mesh),
                               None),
                donate_argnums=(0, 1),      # params/opt update in place
            ).lower(params, opt, specs)
        elif cell.kind == "prefill":
            pspecs = param_pspecs(params, cfg, mesh, fsdp=False)

            def prefill_fn(p, batch):
                head = (p["embed"].T if cfg.tie_embeddings else p["head"])
                if cfg.encoder is not None:
                    memory = ED.encode(p, cfg, batch["frames"])
                    hidden = T.forward(p, cfg, tokens=batch["tokens"],
                                       enc_memory=memory, return_hidden=True)
                elif cfg.embeds_input:
                    hidden = T.forward(p, cfg, embeds=batch["embeds"],
                                       return_hidden=True)
                else:
                    hidden = T.forward(p, cfg, tokens=batch["tokens"],
                                       return_hidden=True)
                # serving prefill emits logits for the LAST position only
                return hidden[:, -1, :] @ head

            bspecs = jax.tree.map(
                lambda s: P(dax, *([None] * (len(s.shape) - 1))), specs)
            lowered = jax.jit(
                prefill_fn,
                in_shardings=(_shard(pspecs, mesh), _shard(bspecs, mesh)),
            ).lower(params, specs)
        else:  # decode
            pspecs = param_pspecs(params, cfg, mesh, fsdp=False)
            cspecs = cache_pspecs(specs["cache"], cfg, mesh)
            serve_fn = make_serve_step(cfg)
            n_data = 1
            for a in daxes:
                n_data *= mesh.shape[a]
            bdax = dax if specs["tokens"].shape[0] % n_data == 0 else None
            tok_spec = P(bdax, None)
            lowered = jax.jit(
                lambda p, t, c: serve_fn(p, t, c),
                in_shardings=(_shard(pspecs, mesh),
                              NamedSharding(mesh, tok_spec),
                              _shard(cspecs, mesh)),
                out_shardings=(NamedSharding(mesh, P(bdax)),
                               _shard(cspecs, mesh)),
                donate_argnums=(2,),        # KV cache updates in place
            ).lower(params, specs["tokens"], specs["cache"])
        t_lower = time.perf_counter() - t0

        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    summary = hlo_utils.cost_summary(compiled)
    hlo = hlo_utils.analyze(compiled.as_text())
    n_dev = mesh.devices.size
    mem = summary["memory"]
    per_dev = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               - mem.get("alias_size_in_bytes", 0))
    # Model-based per-device estimate: XLA's argument sizes (exact, sharded)
    # + an analytic workspace.  The raw CPU-backend temp is inflated by
    # float-normalization (bf16->f32 weight copies, fp8->f16 cache upcasts)
    # hoisted out of the layer loop — buffers a real TPU (native bf16/fp8)
    # never materializes; see EXPERIMENTS.md §Dry-run notes.
    ws = _analytic_workspace(cfg, cell, mesh, microbatches)
    per_dev_model = mem.get("argument_size_in_bytes", 0) + ws
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "devices": n_dev,
        "kind": cell.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        # loop-corrected per-step totals (see hlo_utils docstring); raw
        # XLA cost_analysis kept for reference (counts while bodies once)
        "dot_flops": hlo["dot_flops"],
        "collective_bytes": hlo["collective_bytes"],
        "flops_raw": summary["flops"],
        "bytes_accessed_raw": summary["bytes_accessed"],
        "memory": mem,
        "per_device_bytes_raw": per_dev,
        "workspace_model": ws,
        "per_device_bytes": per_dev_model,
        "fits_16gb": bool(per_dev_model <= 16e9),
        "status": "ok",
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="single arch id (default: all)")
    ap.add_argument("--shape", default=None,
                    help="single shape id (default: all applicable)")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="off")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--microbatches", type=int, default=4)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(C.ARCHS)
    meshes = []
    if args.multi_pod in ("off", "both"):
        meshes.append(make_production_mesh(multi_pod=False))
    if args.multi_pod in ("on", "both"):
        meshes.append(make_production_mesh(multi_pod=True))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "ok"}

    for mesh in meshes:
        mesh_name = "x".join(str(s) for s in mesh.devices.shape)
        for arch in archs:
            skips = C.shape_skips(arch)
            shapes = [args.shape] if args.shape else list(SHAPES)
            for shape in shapes:
                if shape in skips:
                    print(f"SKIP {arch} x {shape}: {skips[shape]}")
                    continue
                if (arch, shape, mesh_name) in done:
                    print(f"done {arch} x {shape} x {mesh_name} (cached)")
                    continue
                print(f"=== {arch} x {shape} x mesh {mesh_name} ===",
                      flush=True)
                try:
                    rec = lower_cell(arch, shape, mesh,
                                     microbatches=args.microbatches)
                    cb = sum(rec["collective_bytes"].values())
                    print(f"  ok: lower {rec['lower_s']}s compile "
                          f"{rec['compile_s']}s dot_flops "
                          f"{rec['dot_flops']:.3e} coll {cb / 1e9:.2f}GB "
                          f"per-dev {rec['per_device_bytes'] / 1e9:.2f}GB "
                          f"fits16GB={rec['fits_16gb']}", flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": f"error: {type(e).__name__}: {e}"}
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"])
                           != (arch, shape, mesh_name)]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\n{ok}/{len(results)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()
