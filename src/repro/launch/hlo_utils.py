"""HLO post-processing: loop-aware FLOP / collective-traffic accounting.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — with our
layer-stacked ``lax.scan`` models that undercounts a 48-layer network 48x.
This module parses the optimized HLO text into its computation call graph,
extracts loop trip counts from the scan conditions' comparison constants,
and propagates multipliers ENTRY -> callees so that:

  * ``dot_flops``        — 2 * prod(result) * prod(contracting dims) per
    dot, times the computation's execution multiplier,
  * ``collective_bytes`` — result bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute ops, multiplied the
    same way,

reflect one full step.  Validated against hand-counted scans in
tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
# result type is either a (tuple, of, shapes) or a single shape token
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\([^)]*\)|[\w\[\]{},.]+)\s+([\w\-]+)\((.*)$")
_CALL_RE = re.compile(r"(?:calls|to_apply|branch_computations)="
                      r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _shape_info(type_str: str) -> Tuple[float, List[Tuple[str, List[int]]]]:
    """(total bytes, [(dtype, dims), ...]) for a result type string."""
    total = 0.0
    shapes = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        dlist = [int(x) for x in dims.split(",")] if dims else []
        n = 1
        for d in dlist:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, dlist))
    return total, shapes


class HloModule:
    """Parsed computations: per-comp op stats + call edges."""

    def __init__(self, text: str):
        self.comps: Dict[str, dict] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._multipliers = self._propagate()

    # -- parsing -----------------------------------------------------------

    def _parse(self, text: str) -> None:
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line.startswith(" "):
                header = _COMP_HEADER_RE.match(line.strip())
                if header:
                    is_entry, name = header.groups()
                    cur = {
                        "name": name, "dot_flops": 0.0,
                        "coll": defaultdict(float),
                        "calls": [],            # callee names (x1)
                        "while_bodies": [],     # (body, cond, trips|None)
                        "constants": [],
                        "symbols": {},          # instr name -> result type
                    }
                    self.comps[name] = cur
                    if is_entry:
                        self.entry = name
                    continue
                if line.strip() == "}":
                    cur = None
                    continue
            if cur is None or not line.strip() or line.strip() == "}":
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            iname, type_str, op, rest = m.groups()
            cur["symbols"][iname] = type_str
            for const in _CONST_RE.findall(line):
                cur["constants"].append(int(const))
            # call edges
            if " while(" in line:
                bm = re.search(r"body=%?([\w.\-]+)", line)
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                tm = _TRIP_RE.search(line)
                if bm:
                    cur["while_bodies"].append(
                        (bm.group(1), cm.group(1) if cm else None,
                         int(tm.group(1)) if tm else None))
            else:
                for cm in _CALL_RE.finditer(line):
                    for callee in re.split(r",\s*", cm.group(1)):
                        cur["calls"].append(callee.lstrip("%"))
            # collectives
            for k in _COLLECTIVE_KINDS:
                if op == k or op.startswith(k + "-start"):
                    nbytes, _ = _shape_info(type_str)
                    cur["coll"][k] += nbytes
                    break
            # dot flops
            if op in ("dot", "dot-general"):
                cur["dot_flops"] += self._dot_flops(cur, type_str, rest,
                                                    line)

    # first operand of an instruction's argument list: an optional inline
    # type annotation (newer HLO: ``dot(f32[64,32]{1,0} %Arg_0.1, ...)``)
    # followed by the operand name
    _LHS_RE = re.compile(r"\s*(?:(\w+\[[\d,]*\](?:\{[\d,]*\})?)\s+)?"
                         r"%?([\w.\-]+)")

    @classmethod
    def _dot_flops(cls, comp: dict, result_type: str, rest: str,
                   line: str) -> float:
        _, rshapes = _shape_info(result_type)
        if not rshapes:
            return 0.0
        rdims = rshapes[0][1]
        rsize = 1
        for d in rdims:
            rsize *= d
        # contracting dims from the lhs operand's shape (inline type when
        # the HLO dialect prints one, else the defining instruction's)
        lhs_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        ops_m = cls._LHS_RE.match(rest)
        contract = 1
        if lhs_m and ops_m:
            inline_type, lhs_name = ops_m.groups()
            lhs_type = inline_type or comp["symbols"].get(lhs_name)
            if lhs_type:
                _, lshapes = _shape_info(lhs_type)
                if lshapes:
                    ldims = lshapes[0][1]
                    for idx in (lhs_m.group(1).split(",")
                                if lhs_m.group(1) else []):
                        i = int(idx)
                        if i < len(ldims):
                            contract *= ldims[i]
        return 2.0 * rsize * contract

    # -- multiplier propagation ---------------------------------------------

    def _trip_count(self, cond_name: Optional[str],
                    known: Optional[int]) -> int:
        """Loop bound: XLA's known_trip_count when present, else the
        condition computation's comparison constant."""
        if known is not None:
            return max(known, 1)
        if cond_name and cond_name in self.comps:
            consts = self.comps[cond_name]["constants"]
            if consts:
                return max(max(consts), 1)
        return 1

    def _propagate(self) -> Dict[str, float]:
        mult: Dict[str, float] = defaultdict(float)
        if self.entry is None:
            return mult

        def visit(name: str, factor: float) -> None:
            if name not in self.comps or factor == 0:
                return
            mult[name] += factor
            comp = self.comps[name]
            for body, cond, known in comp["while_bodies"]:
                trips = self._trip_count(cond, known)
                visit(body, factor * trips)
                if cond:
                    visit(cond, factor * (trips + 1))
            for callee in comp["calls"]:
                visit(callee, factor)

        visit(self.entry, 1.0)
        return dict(mult)

    # -- public totals -----------------------------------------------------------

    def total_dot_flops(self) -> float:
        return sum(c["dot_flops"] * self._multipliers.get(n, 0.0)
                   for n, c in self.comps.items())

    def total_collective_bytes(self) -> Dict[str, float]:
        out: Dict[str, float] = defaultdict(float)
        for n, c in self.comps.items():
            f = self._multipliers.get(n, 0.0)
            for k, v in c["coll"].items():
                out[k] += v * f
        return dict(out)

    def loop_report(self) -> List[Tuple[str, float]]:
        return sorted(((n, m) for n, m in self._multipliers.items()
                       if m > 1.0), key=lambda t: -t[1])


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    coll = mod.total_collective_bytes()
    return {
        "dot_flops": mod.total_dot_flops(),
        "collective_bytes": coll,
        "collective_total": sum(coll.values()),
        "loops": mod.loop_report()[:8],
    }


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Loop-corrected collective traffic by kind."""
    return HloModule(hlo_text).total_collective_bytes()


def total_collective_bytes(hlo_text: str) -> float:
    return sum(collective_bytes(hlo_text).values())


def cost_summary(compiled) -> dict:
    """Normalized cost_analysis + memory_analysis for one executable.

    NOTE: XLA's flops/bytes count while bodies once; prefer
    ``analyze(compiled.as_text())['dot_flops']`` for per-step FLOPs.
    """
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    mem_out = {}
    for attr in ("generated_code_size_in_bytes",
                 "argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes"):
        if hasattr(mem, attr):
            mem_out[attr] = getattr(mem, attr)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "memory": mem_out,
    }
