"""Serving driver: APEX plan search + real engine execution.

The paper's workflow end-to-end: given (arch, trace, cluster) APEX finds
the optimal parallel execution plan; this driver also RUNS the reduced
model on this host's engine so the fidelity loop closes.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --trace chat --requests 8
"""

from __future__ import annotations

import argparse

import jax

from repro import configs as C
from repro.core import (ApexSearch, get_cluster, get_trace)
from repro.data.requests import make_serving_requests
from repro.models import transformer as T
from repro.serving.engine import ServingEngine


def serve(arch: str = "mixtral-8x7b", trace: str = "chat",
          requests: int = 8, cluster: str = "h100x8",
          arrival_rate: float = 2.0, max_batch: int = 4,
          max_len: int = 256, log=print):
    # 1) APEX plan search for the FULL model on the target cluster
    cfg_full = C.get_config(arch)
    model_ir = cfg_full.to_ir()
    clu = get_cluster(cluster)
    reqs = get_trace(trace, arrival_rate=0.5, num_requests=64)
    search = ApexSearch(model_ir, clu)
    base = search.evaluate_baseline(reqs)
    best = search.search(reqs, feasible_only=False)
    log(f"APEX: baseline {base.plan_label} e2e={base.e2e_latency:.1f}s")
    log(f"APEX: optimal  {best.best.plan_label} "
        f"e2e={best.best.e2e_latency:.1f}s "
        f"({base.e2e_latency / best.best.e2e_latency:.2f}x) "
        f"[{best.num_schemes} plans in {best.search_seconds:.1f}s]")

    # 2) run the REDUCED model on this host
    cfg = C.get_reduced(arch)
    if cfg.encoder is not None or cfg.embeds_input:
        log("(reduced engine demo skipped: stub-frontend arch)")
        return base, best, None
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, max_batch=max_batch,
                           max_len=max_len)
    rqs = make_serving_requests(trace, arrival_rate, requests,
                                cfg.vocab_size, max_len=max_len // 4)
    for r in rqs:
        r["gen_len"] = min(r["gen_len"], max_len // 4)
    report = engine.run(rqs, time_scale=0.0)   # all arrive at t=0
    log(f"engine: {len(report.results)} requests in "
        f"{report.total_time:.1f}s, {report.iterations} iterations, "
        f"TTFT {report.ttft_mean * 1e3:.0f}ms TPOT "
        f"{report.tpot_mean * 1e3:.0f}ms "
        f"throughput {report.throughput:.1f} tok/s")
    return base, best, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--trace", default="chat")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--cluster", default="h100x8")
    args = ap.parse_args()
    serve(args.arch, args.trace, args.requests, args.cluster)


if __name__ == "__main__":
    main()
