"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE first jax use,
and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod mesh ("data", "model") or 2x16x16 multi-pod
    ("pod", "data", "model").  The pod axis carries model-level data
    parallelism (independent serving replicas / second-level gradient
    all-reduce), so adding pods scales capacity elastically."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Generic mesh builder for tests/examples (e.g. ("stage", "model")
    pipeline meshes, or small CPU meshes)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_context(mesh):
    """Context manager activating ``mesh`` for PartitionSpec-based
    sharding constraints, across jax versions: ``jax.sharding.set_mesh``
    (newest), ``use_mesh`` (transitional), or the legacy global-mesh
    context (``with mesh:``) on jax <= 0.4.x."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh          # jax.sharding.Mesh is itself a context manager


def data_axes(mesh) -> tuple:
    """The batch-sharding axes of a mesh: ("pod", "data") when a pod axis
    exists, else ("data",)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
