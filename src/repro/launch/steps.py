"""Jittable train_step / serve_step factories shared by the real drivers
(launch/train.py, launch/serve.py) and the multi-pod dry-run.

train_step: microbatched grad accumulation + chunked cross-entropy (the
LM-head matmul and loss run over sequence chunks so the (B, S, vocab)
logits tensor is never materialized — with 256k-entry vocabularies that
tensor would dwarf everything else in HBM).

serve_step: one decode iteration for a batch of sequences against the KV
cache (the iteration-level batching engine calls this once per iteration).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models import encdec as ED
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWState, adamw_update, cosine_lr


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def chunked_ce_loss(hidden: jnp.ndarray, head: jnp.ndarray,
                    labels: jnp.ndarray, chunk: int = 512) -> jnp.ndarray:
    """Cross-entropy over sequence chunks.  hidden: (B, S, d) post-norm;
    head: (d, V); labels: (B, S).  fp32 log-softmax.

    Memory discipline (measured on the 16x16 dry-run, see §Perf log):
      * the gold logit is h . head[:, label] computed via ONE gather of the
        label rows (same pattern as the forward embedding lookup) + a dot —
        never a (B, c, V) one-hot or take_along_axis over the vocab-sharded
        logits (both force GSPMD replication, ~10-20 GB/device);
      * only the logsumexp term touches (B, c, V), one chunk at a time,
        sharded along the vocab axis.
    """
    B, S, d = hidden.shape
    # gold logits for ALL positions with one vocab gather
    lab_vec = head.T[labels]                              # (B, S, d)
    gold_all = jnp.einsum("bsd,bsd->bs", hidden.astype(jnp.float32),
                          lab_vec.astype(jnp.float32))

    c = min(chunk, S)
    S_p = -(-S // c) * c
    if S_p != S:
        hidden = jnp.pad(hidden, ((0, 0), (0, S_p - S), (0, 0)))
    nc = S_p // c
    hs = hidden.reshape(B, nc, c, d).transpose(1, 0, 2, 3)

    def chunk_lse(carry, h):
        logits = (h @ head).astype(jnp.float32)           # (B, c, V)
        return carry + jnp.sum(jax.nn.logsumexp(logits, axis=-1)), None

    lse_total, _ = jax.lax.scan(chunk_lse, jnp.zeros((), jnp.float32), hs)
    # padded positions contribute logsumexp of the zero-vector hidden —
    # a constant log(V) offset; subtract it exactly.
    n_pad = S_p - S
    if n_pad:
        pad_lse = jax.nn.logsumexp(
            jnp.zeros((head.shape[1],), jnp.float32))
        lse_total = lse_total - B * n_pad * pad_lse
    return (lse_total - jnp.sum(gold_all)) / (B * S)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, *, microbatches: int = 1,
                    remat: bool = True, peak_lr: float = 3e-4,
                    loss_chunk: int = 512):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics).

    batch: {"tokens": (B, S) int32, "labels": (B, S) int32} for LM archs;
    {"frames": (B, Ssrc, d), "tokens", "labels"} for enc-dec;
    {"embeds": (B, S, d), "labels"} for stub-frontend archs.
    """

    def loss_fn(params, batch):
        head = (params["embed"].T if cfg.tie_embeddings
                else params["head"])
        if cfg.encoder is not None:
            memory = ED.encode(params, cfg, batch["frames"], remat=remat)
            hidden = T.forward(params, cfg, tokens=batch["tokens"],
                               enc_memory=memory, remat=remat,
                               return_hidden=True)
        elif cfg.embeds_input:
            hidden = T.forward(params, cfg, embeds=batch["embeds"],
                               remat=remat, return_hidden=True)
        else:
            hidden = T.forward(params, cfg, tokens=batch["tokens"],
                               remat=remat, return_hidden=True)
        return chunked_ce_loss(hidden, head, batch["labels"],
                               chunk=loss_chunk)

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state: AdamWState, batch):
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def mb_step(acc, mb):
                loss_acc, grads_acc = acc
                loss, grads = grad_fn(params, mb)
                grads = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32),
                    grads_acc, grads)
                return (loss_acc + loss, grads), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                mb_step, (jnp.zeros((), jnp.float32), zero), mbs)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = grad_fn(params, batch)

        lr = cosine_lr(opt_state.step + 1, peak_lr=peak_lr)
        new_params, new_state, metrics = adamw_update(
            params, grads, opt_state, lr)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# serve step
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig):
    """Returns serve_step(params, tokens (B,1), cache) ->
    (next_tokens (B,), cache) — greedy decode of one iteration."""

    def serve_step(params, tokens, cache):
        logits, cache = T.decode_step(params, cfg, tokens, cache)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return serve_step
