"""Training substrate: optimizer, checkpointing, elastic re-meshing,
gradient compression."""

from .optimizer import adamw_init, adamw_update, cosine_lr
from .checkpoint import CheckpointManager
from .compress import dequantize_int8, quantize_int8
from .elastic import reshard_state

__all__ = ["CheckpointManager", "adamw_init", "adamw_update", "cosine_lr",
           "dequantize_int8", "quantize_int8", "reshard_state"]
