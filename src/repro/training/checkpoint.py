"""Fault-tolerant checkpointing: atomic, content-hashed, resumable.

Design for 1000+ nodes (DESIGN.md §5):
  * every save writes to a temp directory then atomically renames — a
    crash mid-save leaves no partial checkpoint visible;
  * a MANIFEST (json) lists every array file with its sha256; restore
    verifies hashes and refuses corrupt checkpoints, falling back to the
    newest complete one;
  * arrays are saved per-leaf as raw .npy (host-local shards in a real
    multi-host run; device_get here), so restore can re-shard onto a
    DIFFERENT mesh (training/elastic.py) — node failure => shrink the mesh
    and resume;
  * ``keep`` rotates old checkpoints; the manifest records step + RNG fold
    index so the data pipeline resumes deterministically (straggler /
    skip-ahead support).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy cannot serialize bf16/fp8 natively: store as a same-width unsigned
# view and record the true dtype in the manifest.
_VIEW_DTYPES = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}


def _leaf_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "__".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------

    def save(self, step: int, state: Any, extra: Optional[dict] = None
             ) -> str:
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=f".tmp_{step}_")
        manifest = {"step": int(step), "files": {}, "extra": extra or {}}
        for name, leaf in _leaf_paths(state):
            arr = np.asarray(jax.device_get(leaf))
            true_dtype = str(arr.dtype)
            if true_dtype in _VIEW_DTYPES:
                arr = arr.view(_VIEW_DTYPES[true_dtype][0])
            fn = f"{name}.npy"
            np.save(os.path.join(tmp, fn), arr, allow_pickle=False)
            with open(os.path.join(tmp, fn), "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            manifest["files"][fn] = {"sha256": digest,
                                     "shape": list(arr.shape),
                                     "dtype": true_dtype}
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic publish
        self._rotate()
        return final

    def _rotate(self) -> None:
        ckpts = self.list_checkpoints()
        for path in ckpts[:-self.keep]:
            shutil.rmtree(path, ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def list_checkpoints(self) -> list:
        out = []
        for d in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, d)
            if d.startswith("step_") and os.path.isdir(full) \
                    and os.path.exists(os.path.join(full, "MANIFEST.json")):
                out.append(full)
        return out

    def _verify(self, path: str) -> Optional[dict]:
        try:
            with open(os.path.join(path, "MANIFEST.json")) as f:
                manifest = json.load(f)
            for fn, meta in manifest["files"].items():
                with open(os.path.join(path, fn), "rb") as f:
                    if hashlib.sha256(f.read()).hexdigest() != meta["sha256"]:
                        return None
            return manifest
        except (OSError, json.JSONDecodeError, KeyError):
            return None

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[int, Any, dict]:
        """Restore into the structure of ``template`` (its shardings are
        reapplied by the caller via device_put).  Picks the newest VERIFIED
        checkpoint; corrupt/partial ones are skipped.
        Returns (step, state, extra)."""
        ckpts = self.list_checkpoints()
        if step is not None:
            ckpts = [c for c in ckpts if c.endswith(f"step_{step:010d}")]
        for path in reversed(ckpts):
            manifest = self._verify(path)
            if manifest is None:
                continue
            leaves = []
            flat, tdef = jax.tree_util.tree_flatten_with_path(template)
            ok = True
            for ppath, leaf in flat:
                name = "__".join(
                    str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in ppath)
                fn = os.path.join(path, f"{name}.npy")
                if not os.path.exists(fn):
                    ok = False
                    break
                arr = np.load(fn, allow_pickle=False)
                true_dtype = manifest["files"][f"{name}.npy"]["dtype"]
                if true_dtype in _VIEW_DTYPES:
                    arr = arr.view(_VIEW_DTYPES[true_dtype][1])
                leaves.append(arr)
            if not ok:
                continue
            state = jax.tree_util.tree_unflatten(
                tdef, [jax.numpy.asarray(x) for x in leaves])
            return manifest["step"], state, manifest.get("extra", {})
        raise FileNotFoundError(
            f"no complete checkpoint in {self.dir} "
            f"({len(ckpts)} candidates, all failed verification)")
