"""Int8 gradient compression with stochastic rounding.

Used for the cross-pod (DCN-level) gradient reduction in the pipeline /
multi-pod training path: per-tensor absmax scaling to int8 quarters the
gradient bytes on the slowest link.  Stochastic rounding keeps the
quantizer unbiased (E[dequant(quant(x))] == x), so momentum-based
optimizers see zero-mean noise instead of bias — the property the
hypothesis tests assert.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, rng: jax.Array
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x -> (int8 codes, fp32 scale).  Stochastic rounding."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    lo = jnp.floor(y)
    p_up = y - lo
    up = jax.random.uniform(rng, y.shape) < p_up
    q = jnp.clip(lo + up.astype(jnp.float32), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, rng: jax.Array):
    """Quantize every leaf; returns (codes_tree, scales_tree)."""
    leaves, tdef = jax.tree.flatten(grads)
    rngs = jax.random.split(rng, len(leaves))
    qs, ss = [], []
    for leaf, r in zip(leaves, rngs):
        q, s = quantize_int8(leaf, r)
        qs.append(q)
        ss.append(s)
    return jax.tree.unflatten(tdef, qs), jax.tree.unflatten(tdef, ss)


def decompress_tree(codes, scales):
    return jax.tree.map(dequantize_int8, codes, scales)
