"""AdamW with fp32 master weights for bf16 training (pure JAX, no optax).

State layout (pytrees mirroring the parameter tree):
    master : fp32 master copy of the parameters
    m, v   : fp32 first/second moments
    step   : scalar int32

Updates are computed in fp32 against the master weights; the model's bf16
parameters are re-cast from the updated masters each step.  All state
pytrees inherit the parameters' shardings (FSDP: sharded over "data").
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    master: dict
    m: dict
    v: dict
    step: jnp.ndarray


def adamw_init(params) -> AdamWState:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(master=f32(params), m=zeros(params), v=zeros(params),
                      step=jnp.zeros((), jnp.int32))


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: float = 1.0):
    """One AdamW step.  ``lr`` may be a traced scalar.
    Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        new_master = master - lr * (mh / (jnp.sqrt(vh) + eps)
                                    + weight_decay * master)
        return m, v, new_master

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_w = jax.tree.leaves(state.master)
    new_m, new_v, new_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        m2, v2, w2 = upd(g, m, v, w)
        new_m.append(m2)
        new_v.append(v2)
        new_w.append(w2)
    new_state = AdamWState(master=jax.tree.unflatten(tdef, new_w),
                           m=jax.tree.unflatten(tdef, new_m),
                           v=jax.tree.unflatten(tdef, new_v), step=step)
    new_params = jax.tree.map(
        lambda w, p: w.astype(p.dtype), new_state.master, params)
    return new_params, new_state, {"grad_norm": gnorm, "step": step}


def cosine_lr(step, peak_lr: float = 3e-4, warmup: int = 100,
              total: int = 10000, floor: float = 0.1):
    """Warmup + cosine decay schedule (traced-scalar friendly)."""
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(math.pi * prog)))
    return jnp.where(s < warmup, warm, cos)
