"""Elastic re-meshing: resume a checkpoint on a DIFFERENT mesh shape.

Device failure at scale means the replacement slice rarely matches the old
topology.  Checkpoints store full (unsharded) arrays per leaf
(training/checkpoint.py); ``reshard_state`` device_puts them under the new
mesh's shardings.  Shrinking the "data" (FSDP/batch) axis or dropping the
"pod" axis needs no arithmetic — only re-slicing, which device_put with a
NamedSharding performs.  Growing/shrinking the "model" axis re-shards TP
dims the same way.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def reshard_state(state, spec_tree, mesh: Mesh):
    """device_put every leaf under its spec on the (new) mesh."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(put, state, spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
