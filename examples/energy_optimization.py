"""Energy-aware plan selection (paper §4.2.4 / Table 4): latency-optimal
vs energy-optimal plans, and frequency-scaled serving under relaxed SLOs.

    PYTHONPATH=src python examples/energy_optimization.py
"""

from repro.core import ApexSearch, get_trace, h100_node, ir_from_hf_config

model = ir_from_hf_config(dict(
    hidden_size=8192, num_hidden_layers=80, num_attention_heads=64,
    num_key_value_heads=8, intermediate_size=28672, vocab_size=128256,
), name="llama-3.1-70b")
cluster = h100_node(8)
reqs = get_trace("summarization", arrival_rate=3.0, num_requests=64)

lat = ApexSearch(model, cluster).search(reqs, objective="latency")
en = ApexSearch(model, cluster).search(reqs, objective="energy")
slow = ApexSearch(model, cluster, freq_ghz=0.8).search(
    reqs, objective="energy")

rows = [("latency-opt @2.0GHz", lat.best),
        ("energy-opt  @2.0GHz", en.best),
        ("energy-opt  @0.8GHz", slow.best)]
base = lat.best.total_energy
print(f"{'variant':22s} {'energy kJ':>10s} {'saving':>8s} "
      f"{'TTFT ms':>9s} {'TPOT ms':>9s}  plan")
for name, rep in rows:
    print(f"{name:22s} {rep.total_energy / 1e3:10.2f} "
          f"{1 - rep.total_energy / base:8.0%} "
          f"{rep.ttft_mean * 1e3:9.1f} {rep.tpot_mean * 1e3:9.2f}  "
          f"{rep.plan_label}")
print("\nAs in the paper: energy-optimal != latency-optimal, and "
      "downclocking trades TTFT/TPOT for large energy savings.")
