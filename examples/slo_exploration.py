"""Beyond plan search (paper §4.6 / Fig. 9): sweep max-batch-size caps to
meet a TPOT SLO, exposing the over-restriction cliff.

    PYTHONPATH=src python examples/slo_exploration.py
"""

from repro.core import (ApexSearch, BatchingPolicy, get_trace, h100_node,
                        ir_from_hf_config)

model = ir_from_hf_config(dict(
    hidden_size=8192, num_hidden_layers=80, num_attention_heads=64,
    num_key_value_heads=8, intermediate_size=28672, vocab_size=128256,
), name="llama-3.1-70b")
cluster = h100_node(8)
reqs = get_trace("creation", arrival_rate=6.0, num_requests=64)
search = ApexSearch(model, cluster)

print(f"{'max batch':>10s} {'TPOT ms':>9s} {'e2e s':>8s}")
for cap in (2, 4, 8, 16, 32, None):
    rep = search.evaluate_baseline(
        reqs, policy=BatchingPolicy(max_batch_size=cap))
    print(f"{str(cap or 'inf'):>10s} {rep.tpot_mean * 1e3:9.2f} "
          f"{rep.e2e_latency:8.1f}")
print("\nSmaller caps improve TPOT until the end-to-end latency cliff — "
      "use the table to pick the largest cap meeting the SLO.")
