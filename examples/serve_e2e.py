"""End-to-end serving driver (the paper's kind of system): APEX picks the
plan for the full architecture, then the REAL JAX engine serves a batched
request stream with the reduced config on this host — iteration-level
batching, greedy admission, preemption and all.

    PYTHONPATH=src python examples/serve_e2e.py [--arch mixtral-8x7b]
"""

import argparse

from repro.launch.serve import serve

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--trace", default="chat")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    serve(args.arch, args.trace, args.requests)
