"""Quickstart: find the optimal parallel execution plan for serving
Llama-3.1-70B on an 8xH100 node (the paper's §3.1 walkthrough).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import ApexSearch, get_trace, h100_node, ir_from_hf_config

# 1) the model — a HuggingFace-style config dict is all APEX needs
llama70b = ir_from_hf_config(dict(
    hidden_size=8192, num_hidden_layers=80, num_attention_heads=64,
    num_key_value_heads=8, intermediate_size=28672, vocab_size=128256,
), name="llama-3.1-70b")
print(llama70b.describe())

# 2) the cluster and the workload (Poisson arrivals, chat-style lengths)
cluster = h100_node(8)
print(cluster.describe())
requests = get_trace("chat", arrival_rate=16.0, num_requests=128)

# 3) search: baseline heuristic vs feasible-optimal vs APEX-optimal
search = ApexSearch(llama70b, cluster)
baseline = search.evaluate_baseline(requests)
print(f"\nbaseline  {baseline.summary()}")

feasible = search.search(requests, feasible_only=True)
print(f"feasible  {feasible.best.summary()}")

full = search.search(requests, feasible_only=False)
print(f"apex      {full.best.summary()}")
print(f"\nsearched {full.num_schemes} plans in {full.search_seconds:.1f}s "
      f"({full.num_feasible} feasible)")
print(f"speedup vs baseline: feasible "
      f"{baseline.e2e_latency / feasible.best.e2e_latency:.2f}x, "
      f"apex {baseline.e2e_latency / full.best.e2e_latency:.2f}x")

print("\ntop-5 plans by end-to-end latency:")
for rep in full.top(5):
    print("  ", rep.summary())
