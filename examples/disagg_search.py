"""Disaggregated prefill/decode plan search (repro.disagg).

Searches colocated AND two-pool disaggregated plans jointly under a TTFT
objective, then prints the winner and the best plan of each family —
including HETEROGENEOUS pools (H100 prefill / H200 decode) drawn from a
pool menu.

Run:  PYTHONPATH=src python examples/disagg_search.py
"""

from repro.core import ApexSearch, get_trace, h100_multinode, h100_node, \
    h200_node, ir_from_hf_config
from repro.disagg import is_mixed_label

model = ir_from_hf_config(
    dict(hidden_size=5120, num_hidden_layers=64, num_attention_heads=40,
         num_key_value_heads=8, intermediate_size=27648,
         vocab_size=152064), name="qwen2.5-32b")
cluster = h100_multinode(num_nodes=2, gpus_per_node=8)
requests = get_trace("chat", arrival_rate=2.0, num_requests=96)

search = ApexSearch(model, cluster)
result = search.search(requests, objective="ttft", feasible_only=True,
                       disaggregated=True,
                       # hetero candidates: every (prefill, decode) device
                       # assignment from the menu within the 16-GPU budget
                       pool_menu=[h100_node(8), h200_node(8)])

print(f"searched {result.num_schemes} plans "
      f"({result.num_feasible} feasible) in "
      f"{result.search_seconds:.1f}s; objective={result.objective}\n")
print("winner:", result.best.summary(), "\n")

feasible = [r for r in result.all_reports if r.feasible]
for family, match in (("colocated", lambda l: not l.startswith("disagg[")),
                      ("disaggregated", lambda l: l.startswith("disagg[")
                       and not is_mixed_label(l)),
                      ("hetero pools", is_mixed_label)):
    fam = [r for r in feasible if match(r.plan_label)]
    if not fam:
        print(f"best {family}: (none feasible)")
        continue
    best = min(fam, key=lambda r: r.ttft_p95)
    print(f"best {family}: TTFT p95 {best.ttft_p95 * 1e3:.1f}ms, "
          f"TPOT p95 {best.tpot_p95 * 1e3:.2f}ms, "
          f"energy {best.total_energy / 1e3:.1f}kJ")
    print(f"  {best.plan_label}")
