"""Disaggregated prefill/decode plan search (repro.disagg).

Searches colocated AND two-pool disaggregated plans jointly under a TTFT
objective, then prints the winner and the best plan of each family.

Run:  PYTHONPATH=src python examples/disagg_search.py
"""

from repro.core import ApexSearch, get_trace, h100_multinode, \
    ir_from_hf_config

model = ir_from_hf_config(
    dict(hidden_size=5120, num_hidden_layers=64, num_attention_heads=40,
         num_key_value_heads=8, intermediate_size=27648,
         vocab_size=152064), name="qwen2.5-32b")
cluster = h100_multinode(num_nodes=2, gpus_per_node=8)
requests = get_trace("chat", arrival_rate=2.0, num_requests=96)

search = ApexSearch(model, cluster)
result = search.search(requests, objective="ttft", feasible_only=True,
                       disaggregated=True)

print(f"searched {result.num_schemes} plans "
      f"({result.num_feasible} feasible) in "
      f"{result.search_seconds:.1f}s; objective={result.objective}\n")
print("winner:", result.best.summary(), "\n")

feasible = [r for r in result.all_reports if r.feasible]
for family, match in (("colocated", lambda l: not l.startswith("disagg[")),
                      ("disaggregated", lambda l: l.startswith("disagg["))):
    fam = [r for r in feasible if match(r.plan_label)]
    best = min(fam, key=lambda r: r.ttft_p95)
    print(f"best {family}: TTFT p95 {best.ttft_p95 * 1e3:.1f}ms, "
          f"TPOT p95 {best.tpot_p95 * 1e3:.2f}ms")
    print(f"  {best.plan_label}")
