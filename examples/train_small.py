"""Train a small LM end to end on CPU with the production substrate:
deterministic data pipeline, microbatched AdamW, chunked CE loss,
activation checkpointing, atomic checkpoints + resume.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""

import argparse

from repro.launch.train import train

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    _, _, losses = train(args.arch, steps=args.steps, batch=args.batch,
                         seq=args.seq, microbatches=2,
                         ckpt_dir=args.ckpt_dir)
    k = max(len(losses) // 10, 1)
    print(f"\nloss: first-10 avg {sum(losses[:k]) / k:.4f} -> "
          f"last-10 avg {sum(losses[-k:]) / k:.4f}")
