"""Simulator invariants: interpolation, scaling, quantization, energy."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (AnalyticBackend, ApexSearch, ProfileStore,
                        get_format, get_trace, h100_node, h200_node,
                        ir_from_hf_config, tpu_v5e_pod)
from repro.core.energy import PowerModel
from repro.core.cluster import H100

CFG = dict(hidden_size=2048, num_hidden_layers=16, num_attention_heads=16,
           num_key_value_heads=8, intermediate_size=8192, vocab_size=32000)


def _model():
    return ir_from_hf_config(CFG, name="tiny-7b")


def test_interpolation_error_bounded():
    """Sparser profiling grids (paper: measured points + interpolation)
    stay within a small relative error of the dense grid."""
    cluster = h100_node(8)
    dense = ProfileStore(AnalyticBackend(cluster), grid_stride=1)
    sparse = ProfileStore(AnalyticBackend(cluster), grid_stride=2)
    for x in [3, 77, 1000, 30000, 1.5e6]:
        td, _ = dense.query("gemm", (4096, 4096, "fp16"), x)
        ts, _ = sparse.query("gemm", (4096, 4096, "fp16"), x)
        assert abs(td - ts) / td < 0.35


@given(x=st.floats(1, 1e8))
@settings(max_examples=30, deadline=None)
def test_interpolation_monotone_gemm(x):
    cluster = h100_node(8)
    store = ProfileStore(AnalyticBackend(cluster))
    t1 = store.time("gemm", (1024, 1024, "fp16"), x)
    t2 = store.time("gemm", (1024, 1024, "fp16"), x * 2)
    assert t2 >= t1 * 0.999
    assert t1 > 0


def test_energy_frequency_scaling():
    """Table 4: downclocking cuts energy on compute-bound work."""
    full = PowerModel(H100, freq_ghz=2.0)
    slow = PowerModel(H100, freq_ghz=0.8)
    # same op takes 2.5x longer at 0.8 GHz but dynamic power drops 6.25x
    t = 1.0
    e_full = full.energy(t, utilization=0.9)
    e_slow = slow.energy(t * 2.5, utilization=0.9)
    assert e_slow < e_full


def test_quantization_capacity():
    """fp8 KV doubles token capacity; w8a8 halves weight bytes."""
    model = _model()
    from repro.core import generate_schemes
    s16 = generate_schemes(model, 8, quant="fp16")[0]
    s8 = type(s16)(model=model, model_dp=s16.model_dp,
                   pp_stages=s16.pp_stages,
                   cell_schemes=s16.cell_schemes, quant="kv8")
    w8 = type(s16)(model=model, model_dp=s16.model_dp,
                   pp_stages=s16.pp_stages,
                   cell_schemes=s16.cell_schemes, quant="w8a8")
    assert s8.kv_bytes_per_token_per_device() == pytest.approx(
        s16.kv_bytes_per_token_per_device() / 2)
    assert w8.weight_bytes_per_device() == pytest.approx(
        s16.weight_bytes_per_device() / 2)
    cap16 = s16.kv_token_capacity(80e9)
    cap8 = s8.kv_token_capacity(80e9)
    assert cap8 > cap16 * 1.5


def test_h200_larger_design_space():
    """Paper §4.2.3: more HBM -> more feasible plans."""
    big = ir_from_hf_config(dict(hidden_size=8192, num_hidden_layers=80,
                                 num_attention_heads=64,
                                 num_key_value_heads=8,
                                 intermediate_size=28672,
                                 vocab_size=128256), name="llama70")
    reqs = get_trace("chat", arrival_rate=2.0, num_requests=24)
    n_h100 = ApexSearch(big, h100_node(8)).search(reqs).num_feasible
    n_h200 = ApexSearch(big, h200_node(8)).search(reqs).num_feasible
    assert n_h200 >= n_h100


def test_tpu_cluster_supported():
    """Paper: ASIC clusters (TPU) are first-class."""
    model = _model()
    reqs = get_trace("chat", arrival_rate=8.0, num_requests=16)
    s = ApexSearch(model, tpu_v5e_pod(16, ring_group=4))
    res = s.search(reqs, max_model_dp=4)
    assert res.best.feasible


def test_trace_statistics_match_spec():
    """Synthetic traces match Table 1 moments (within sampling noise)."""
    from repro.core import TRACE_SPECS, trace_stats
    for name, spec in TRACE_SPECS.items():
        reqs = get_trace(name, arrival_rate=1.0, seed=3)
        st_ = trace_stats(reqs)
        assert abs(st_["ctx_mean"] - spec.ctx_mean) / spec.ctx_mean < 0.25
        assert abs(st_["gen_mean"] - spec.gen_mean) / spec.gen_mean < 0.25


def test_extensibility_register_format():
    """Table 5: adding a quantization format is one call."""
    from repro.core import FORMATS, QuantFormat, register_format
    register_format(QuantFormat("w2a16-test", 0.25, 2.0, 2.0, "fp16"))
    assert "w2a16-test" in FORMATS
    assert get_format("w2a16-test").weight_bytes == 0.25
    del FORMATS["w2a16-test"]


def test_extensibility_new_cluster():
    """Table 5: a new device cluster is a preset function."""
    from repro.core.cluster import (CLUSTER_PRESETS, Cluster, DeviceSpec,
                                    NetworkLevel)
    dev = DeviceSpec("test-asic", {"bf16": 100e12}, 32e9, 1e12, 50, 300)
    CLUSTER_PRESETS["test-asic-8"] = lambda: Cluster(
        "test-asic-8", dev, (NetworkLevel("link", 8, 100e9, 1e-6),), 8)
    from repro.core import get_cluster
    c = get_cluster("test-asic-8")
    assert c.num_devices == 8
    del CLUSTER_PRESETS["test-asic-8"]
