"""Non-stationary traffic + epoch-gated dynamic re-planning tests.

Covers the PR's load-bearing invariants:

  * arrival processes — stationary ``ConstantRate`` is bit-identical to
    the legacy float path, every variant replays under its seed, and
    input validation refuses nonsense;
  * memory-threshold admission control — defer holds-then-serves,
    reject drops-and-counts, and the knobs validate;
  * windowed metrics — hand-computable 3-request timeline;
  * the dynamic controller — a static (one-epoch) schedule through
    ``DynamicPlanSimulator`` reproduces the plain simulator's records
    bit-for-bit, both mechanisms conserve requests, migrate carries
    in-flight progress, and every reconfiguration is billed;
  * search integration — ``dynamic=DynamicSpec()`` (empty) is
    bit-identical to ``dynamic=None``; a non-empty spec adds
    reconfig-bearing candidates under the same objective;
  * the fluid guard — non-stationary traces trip the surrogate's
    z-score and ``MultiFidelitySearch`` refuses (or screens at peak).
"""

import dataclasses
import random

import pytest

from repro.core import (ApexSearch, BatchingPolicy, MultiFidelitySearch,
                        get_trace, h100_node, ir_from_hf_config)
from repro.core.dynamic import (DynamicPlanSimulator, DynamicSpec,
                                EpochSchedule, build_schedules,
                                fault_schedule, reactive_schedule)
from repro.core.engine import Engine
from repro.core.faults import FaultSchedule, ReplicaFault
from repro.core.fluid import TraceSummary
from repro.core.metrics import windowed_metrics
from repro.core.trace import (ArrivalProcess, BurstProcess, ConstantRate,
                              DiurnalRate, PiecewiseRate,
                              as_arrival_process, synthesize_trace)

TINY = dict(hidden_size=256, num_hidden_layers=4, num_attention_heads=8,
            num_key_value_heads=4, intermediate_size=1024, vocab_size=1024)


@pytest.fixture(scope="module")
def search():
    return ApexSearch(ir_from_hf_config(TINY, name="tiny"), h100_node(8))


@pytest.fixture(scope="module")
def cands(search):
    return search.candidates(quant="fp16")


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def test_constant_rate_bit_identical_to_float():
    a = get_trace("summarization", arrival_rate=0.5, seed=7,
                  num_requests=24)
    b = get_trace("summarization", arrival_rate=ConstantRate(0.5), seed=7,
                  num_requests=24)
    assert [dataclasses.astuple(r) for r in a] == \
           [dataclasses.astuple(r) for r in b]


@pytest.mark.parametrize("proc", [
    ConstantRate(4.0),
    PiecewiseRate(starts=(0.0, 5.0), rates=(1.0, 16.0)),
    DiurnalRate(base_rate=4.0, amplitude=0.8, period_s=60.0),
    BurstProcess(base_rate=1.0, burst_rate=32.0, mean_burst_s=2.0,
                 mean_gap_s=5.0),
], ids=["constant", "piecewise", "diurnal", "burst"])
def test_arrival_variants_deterministic_under_seed(proc):
    a = get_trace("chat", arrival_rate=proc, seed=11, num_requests=40)
    b = get_trace("chat", arrival_rate=proc, seed=11, num_requests=40)
    assert [dataclasses.astuple(r) for r in a] == \
           [dataclasses.astuple(r) for r in b]
    arrivals = [r.arrival for r in a]
    assert arrivals == sorted(arrivals)
    assert all(t > 0 for t in arrivals)


def test_piecewise_shifts_arrival_mass():
    proc = PiecewiseRate(starts=(0.0, 10.0), rates=(8.0, 1.0))
    reqs = get_trace("chat", arrival_rate=proc, seed=5,
                     num_requests=120)
    early = sum(1 for r in reqs if r.arrival < 10.0)
    # ~80 expected in the rate-8 first 10 s under a stationary split of
    # the same 120 arrivals; the piecewise process concentrates them
    assert early > 60


def test_arrival_validation():
    with pytest.raises(ValueError):
        ConstantRate(0.0)
    with pytest.raises(ValueError):
        PiecewiseRate(starts=(1.0, 2.0), rates=(1.0, 2.0))  # no t=0
    with pytest.raises(ValueError):
        PiecewiseRate(starts=(0.0, 2.0, 1.0), rates=(1.0, 1.0, 1.0))
    with pytest.raises(ValueError):
        PiecewiseRate(starts=(0.0, 1.0), rates=(1.0, 0.0))  # ends silent
    with pytest.raises(ValueError):
        DiurnalRate(base_rate=2.0, amplitude=1.5)
    with pytest.raises(ValueError):
        BurstProcess(base_rate=8.0, burst_rate=4.0, mean_burst_s=1.0,
                     mean_gap_s=1.0)  # burst below base
    with pytest.raises(TypeError):
        as_arrival_process(True)
    with pytest.raises(TypeError):
        as_arrival_process("fast")
    with pytest.raises(ValueError):
        get_trace("chat", arrival_rate=1.0, num_requests=0)


def test_mean_rate_and_rate_at():
    pw = PiecewiseRate(starts=(0.0, 10.0), rates=(2.0, 6.0))
    assert pw.rate_at(0.0) == 2.0
    assert pw.rate_at(10.0) == 6.0
    assert pw.mean_rate(20.0) == pytest.approx(4.0, rel=0.05)
    di = DiurnalRate(base_rate=4.0, amplitude=0.5, period_s=100.0)
    assert di.mean_rate(100.0) == pytest.approx(4.0, rel=1e-6)
    assert di.peak_rate() == pytest.approx(6.0)
    assert isinstance(as_arrival_process(2), ConstantRate)
    assert isinstance(as_arrival_process(pw), ArrivalProcess)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def _admission_setup(search, cands, mode):
    candidates, kv = cands
    _, sim = search.make_simulator(candidates[0], kv)
    cap = sim.scheme.kv_token_capacity(
        search.cluster.device.hbm_bytes)
    reqs = get_trace("summarization", arrival_rate=32.0, seed=3,
                     num_requests=48)
    pol = BatchingPolicy(admission_watermark=9000.0 / cap,
                         admission_mode=mode)
    return sim, reqs, pol


def test_admission_defer_holds_then_serves_all(search, cands):
    sim, reqs, pol = _admission_setup(search, cands, "defer")
    rep = sim.simulate(reqs, policy=pol, keep_records=True)
    assert rep.admission_deferred > 0
    assert rep.admission_rejected == 0
    assert len(rep.records) == len(reqs)            # nobody starves
    assert all(r.finish_time > 0 for r in rep.records)


def test_admission_reject_drops_and_counts(search, cands):
    sim, reqs, pol = _admission_setup(search, cands, "reject")
    rep = sim.simulate(reqs, policy=pol, keep_records=True)
    assert rep.admission_rejected > 0
    assert len(rep.records) == len(reqs) - rep.admission_rejected
    assert all(r.ttft >= 0 for r in rep.records)


def test_admission_validation(search, cands):
    candidates, kv = cands
    _, sim = search.make_simulator(candidates[0], kv)
    reqs = get_trace("summarization", arrival_rate=4.0, seed=3,
                     num_requests=4)
    with pytest.raises(ValueError):
        sim.simulate(reqs, policy=BatchingPolicy(admission_watermark=1.5))
    with pytest.raises(ValueError):
        sim.simulate(reqs, policy=BatchingPolicy(
            admission_watermark=0.5, admission_mode="teleport"))
    with pytest.raises(ValueError):
        sim.simulate(reqs, policy=BatchingPolicy(
            mode="static", admission_watermark=0.5))


# ---------------------------------------------------------------------------
# windowed metrics
# ---------------------------------------------------------------------------

def _rec(rid, arrival, first, finish, gen=4):
    from repro.core.batching import RequestRecord
    return RequestRecord(rid=rid, arrival=arrival, context_len=8,
                         gen_len=gen, first_token_time=first,
                         finish_time=finish)


def test_windowed_metrics_hand_computed():
    recs = [_rec(0, 0.5, 1.0, 2.5),     # arrives w0, finishes w0
            _rec(1, 2.0, 3.5, 7.0),     # arrives w0, finishes w1
            _rec(2, 6.5, 8.0, 9.0)]     # arrives w1, finishes last w
    ws = windowed_metrics(recs, window_s=4.0, horizon=9.0)
    assert len(ws) == 3
    assert [w.arrivals for w in ws] == [2, 1, 0]
    assert [w.finished for w in ws] == [1, 1, 1]
    assert ws[0].ttft_mean == pytest.approx(0.5)    # 1.0 - 0.5
    assert sum(w.arrivals for w in ws) == len(recs)
    assert sum(w.finished for w in ws) == len(recs)


def test_windowed_metrics_explicit_boundaries_and_validation():
    recs = [_rec(0, 0.5, 1.0, 2.5)]
    ws = windowed_metrics(recs, boundaries=[0.0, 2.0], horizon=3.0)
    assert [(w.start, w.end) for w in ws] == [(0.0, 2.0), (2.0, 3.0)]
    with pytest.raises(ValueError):
        windowed_metrics(recs)                       # neither knob
    with pytest.raises(ValueError):
        windowed_metrics(recs, window_s=1.0, boundaries=[0.0])
    with pytest.raises(ValueError):
        windowed_metrics(recs, boundaries=[1.0, 2.0])  # no t=0


# ---------------------------------------------------------------------------
# epoch schedules
# ---------------------------------------------------------------------------

def test_epoch_schedule_validation_and_collapse():
    s = EpochSchedule(epochs=((0.0, 1), (2.0, 1), (4.0, 0)))
    assert s.epochs == ((0.0, 1), (4.0, 0))         # same-plan collapsed
    assert s.num_switches == 1
    assert s.plan_at(3.9) == 1 and s.plan_at(4.0) == 0
    assert EpochSchedule.static(2).is_static
    with pytest.raises(ValueError):
        EpochSchedule(epochs=())
    with pytest.raises(ValueError):
        EpochSchedule(epochs=((1.0, 0),))            # must start at 0
    with pytest.raises(ValueError):
        EpochSchedule(epochs=((0.0, 0), (0.0, 1)))   # not increasing


def test_reactive_schedule_is_causal():
    reqs = get_trace(
        "summarization", num_requests=140, seed=3,
        arrival_rate=PiecewiseRate(starts=(0.0, 4.0, 6.0),
                                   rates=(2.0, 60.0, 2.0)))
    horizon = max(r.arrival for r in reqs)
    s = reactive_schedule(reqs, epoch_s=2.0, horizon_s=horizon,
                          lo_plan=0, hi_plan=1)
    # the burst lives in [4, 6); a lag-1 controller reacts one epoch
    # late — hi during [6, 8), never during the burst itself
    assert s.plan_at(5.0) == 0
    assert s.plan_at(7.0) == 1
    assert s.plan_at(9.0) == 0
    with pytest.raises(ValueError):
        reactive_schedule(reqs, epoch_s=2.0, horizon_s=horizon,
                          lo_plan=0, hi_plan=1, lag=0)


def test_fault_schedule_from_windows():
    fs = FaultSchedule(replica_faults=(
        ReplicaFault(pool="serve", replica=0, start=3.0, repair=5.0),))
    s = fault_schedule(fs, horizon_s=10.0, primary=0, fallback=1)
    assert s.epochs == ((0.0, 0), (3.0, 1), (5.0, 0))


# ---------------------------------------------------------------------------
# the dynamic controller
# ---------------------------------------------------------------------------

def _nonstat_trace(n=60):
    return get_trace(
        "summarization", num_requests=n, seed=3,
        arrival_rate=PiecewiseRate(starts=(0.0, 1.0),
                                   rates=(30.0, 60.0)))


def _rec_tuple(records):
    return sorted((r.rid, r.first_token_time, r.finish_time,
                   r.preemptions, r.refetch_s) for r in records)


@pytest.mark.parametrize("mechanism", ["drain", "migrate"])
def test_static_schedule_matches_plain_simulator(search, cands, mechanism):
    candidates, kv = cands
    reqs = _nonstat_trace()
    dyn = DynamicPlanSimulator(search, candidates, EpochSchedule.static(0),
                               kv_model=kv, mechanism=mechanism)
    rep_d = dyn.simulate(reqs, keep_records=True)
    _, sim = search.make_simulator(candidates[0], kv)
    rep_s = sim.simulate(reqs, keep_records=True)
    assert _rec_tuple(rep_d.records) == _rec_tuple(rep_s.records)
    assert rep_d.reconfig.num_switches == 0
    assert rep_d.total_energy == pytest.approx(rep_s.total_energy)


@pytest.mark.parametrize("mechanism", ["drain", "migrate"])
def test_switching_conserves_requests_and_bills_reconfig(
        search, cands, mechanism):
    candidates, kv = cands
    reqs = _nonstat_trace()
    sched = EpochSchedule(epochs=((0.0, 0), (1.0, 3)))
    dyn = DynamicPlanSimulator(search, candidates, sched, kv_model=kv,
                               mechanism=mechanism)
    rep = dyn.simulate(reqs, keep_records=True)
    assert len(rep.records) == len(reqs)             # nobody lost
    for r in rep.records:
        assert r.finish_time > r.first_token_time > r.arrival >= 0.0
    assert rep.reconfig.num_switches == 1
    sw = rep.reconfig.switches[0]
    assert sw.reshard_s > 0 and sw.reshard_bytes > 0
    if mechanism == "migrate":
        assert sw.migrated > 0 and sw.migrate_s > 0   # busy boundary
        assert sw.drained == 0
    else:
        assert sw.migrated == 0
    assert rep.windows is not None and len(rep.windows) == 2
    assert sum(w.arrivals for w in rep.windows) == len(reqs)


def test_dynamic_validation(search, cands):
    candidates, kv = cands
    with pytest.raises(ValueError):
        DynamicPlanSimulator(search, candidates, EpochSchedule.static(0),
                             mechanism="teleport")
    with pytest.raises(ValueError):
        DynamicPlanSimulator(
            search, candidates,
            EpochSchedule(epochs=((0.0, 0), (1.0, len(candidates)))))
    fake_disagg = [("disagg", candidates[0][1], None)]
    with pytest.raises(ValueError):
        DynamicPlanSimulator(search, fake_disagg, EpochSchedule.static(0),
                             mechanism="migrate")
    dyn = DynamicPlanSimulator(search, candidates,
                               EpochSchedule(epochs=((0.0, 0), (1.0, 1))),
                               kv_model=kv, mechanism="migrate")
    fs = FaultSchedule(replica_faults=(
        ReplicaFault(pool="serve", replica=0, start=0.5, repair=1.5),))
    with pytest.raises(ValueError):
        dyn.simulate(_nonstat_trace(12), faults=fs)


def test_engine_epoch_stop_and_boundary_union():
    eng = Engine()
    eng.fault_times = [4.0]
    eng.install_epoch(2.0, lambda t: eng.stop())
    assert eng.next_boundary(0.0) == 2.0
    assert eng.next_boundary(2.0) == 4.0             # union with faults
    assert eng.fault_bound(0.0) == 2.0               # PR-9 alias intact


# ---------------------------------------------------------------------------
# search integration
# ---------------------------------------------------------------------------

def test_empty_dynamic_spec_bit_identical_to_none(search):
    reqs = _nonstat_trace(48)
    kw = dict(objective="goodput", slo_ttft_s=0.5, slo_tpot_s=0.2)
    a = search.search(reqs, **kw)
    b = search.search(reqs, dynamic=DynamicSpec(), **kw)
    assert [dataclasses.asdict(r) for r in a.all_reports] == \
           [dataclasses.asdict(r) for r in b.all_reports]
    assert a.best.plan_label == b.best.plan_label


def test_search_dynamic_adds_reconfig_candidates(search):
    reqs = _nonstat_trace(48)
    spec = DynamicSpec(
        top_k=2, mechanism="drain",
        schedules=(EpochSchedule(epochs=((0.0, 0), (1.0, 1))),
                   EpochSchedule(epochs=((0.0, 1), (1.0, 0)))))
    res = search.search(reqs, objective="goodput", slo_ttft_s=0.5,
                        slo_tpot_s=0.2, dynamic=spec)
    dyn = [r for r in res.all_reports if r.reconfig is not None]
    assert len(dyn) == 2
    assert res.num_schemes == 49 + 2
    for r in dyn:
        assert r.plan_label.startswith("dyn-drain[")
        assert r.reconfig.num_switches == 1
        assert r.reconfig.total_reshard_s > 0
    # best is picked over the union under the same objective
    assert res.best.goodput_rps == max(
        r.goodput_rps for r in res.all_reports if res.admissible(r))


def test_build_schedules_drops_static_and_validates():
    reqs = _nonstat_trace(48)
    spec = DynamicSpec(schedules=(EpochSchedule.static(0),
                                  EpochSchedule(epochs=((0.0, 0),
                                                        (1.0, 1)))))
    out = build_schedules(spec, reqs, 2.0, k=2)
    assert len(out) == 1                             # static dropped
    bad = DynamicSpec(schedules=(EpochSchedule(epochs=((0.0, 0),
                                                       (1.0, 5))),))
    with pytest.raises(ValueError):
        build_schedules(bad, reqs, 2.0, k=2)
    with pytest.raises(ValueError):
        DynamicSpec(top_k=0)
    with pytest.raises(ValueError):
        DynamicSpec(mechanism="teleport")


# ---------------------------------------------------------------------------
# fluid guard
# ---------------------------------------------------------------------------

def test_trace_summary_nonstationarity_scores():
    stat = get_trace("summarization", arrival_rate=16.0, seed=3,
                     num_requests=48)
    assert TraceSummary.of(stat).nonstationarity < 6.0
    ns = get_trace("summarization", seed=3, num_requests=48,
                   arrival_rate=PiecewiseRate(starts=(0.0, 2.0),
                                              rates=(2.0, 80.0)))
    ts = TraceSummary.of(ns)
    assert ts.nonstationarity > 6.0
    assert ts.peak_rate > ts.arrival_rate


def test_multifid_refuses_nonstationary_by_default(search):
    ns = get_trace("summarization", seed=3, num_requests=48,
                   arrival_rate=PiecewiseRate(starts=(0.0, 2.0),
                                              rates=(2.0, 80.0)))
    mf = MultiFidelitySearch(search, frontier_k=4)
    with pytest.raises(ValueError, match="non-stationary"):
        mf.search(ns, objective="goodput")
    with pytest.raises(ValueError):
        mf.search(ns, objective="goodput", nonstationary="sideways")
    r = mf.search(ns, objective="goodput", nonstationary="peak")
    assert r.best.feasible
    r2 = mf.search(ns, objective="goodput", nonstationary="ignore")
    assert r2.best.feasible
