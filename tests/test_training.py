"""Training substrate: optimizer, checkpoint/restart, compression,
pipeline determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.data.pipeline import TokenPipeline
from repro.training.checkpoint import CheckpointManager
from repro.training.compress import dequantize_int8, quantize_int8
from repro.training.optimizer import adamw_init, adamw_update, cosine_lr


def test_adamw_reduces_quadratic():
    w = {"x": jnp.asarray([3.0, -2.0, 1.5])}
    state = adamw_init(w)
    for _ in range(200):
        g = {"x": 2 * w["x"]}
        w, state, _ = adamw_update(w, g, state, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(w["x"]).max()) < 0.3


def test_cosine_lr_schedule():
    assert float(cosine_lr(jnp.asarray(0), warmup=10)) == 0.0
    peak = float(cosine_lr(jnp.asarray(10), peak_lr=1e-3, warmup=10))
    assert peak == pytest.approx(1e-3, rel=0.1)
    late = float(cosine_lr(jnp.asarray(10000), peak_lr=1e-3, warmup=10,
                           total=10000))
    assert late < peak


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
             "b": {"c": jnp.ones((4,), jnp.float32)}}
    mgr.save(5, state, extra={"rng": 42})
    step, restored, extra = mgr.restore(state)
    assert step == 5 and extra["rng"] == 42
    np.testing.assert_array_equal(
        np.asarray(restored["a"], np.float32),
        np.asarray(state["a"], np.float32))
    assert restored["a"].dtype == jnp.bfloat16


def test_checkpoint_rotation_and_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.ones((3,))}
    for s in (1, 2, 3):
        mgr.save(s, state)
    assert len(mgr.list_checkpoints()) == 2      # rotated
    # corrupt the newest; restore must fall back to the older one
    newest = mgr.list_checkpoints()[-1]
    victim = [f for f in os.listdir(newest) if f.endswith(".npy")][0]
    with open(os.path.join(newest, victim), "wb") as f:
        f.write(b"garbage")
    step, _, _ = mgr.restore(state)
    assert step == 2


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_int8_quantization_unbiased(seed):
    """Stochastic rounding: E[dequant(quant(x))] == x."""
    rngs = jax.random.split(jax.random.PRNGKey(seed), 64)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (32,)) * 0.1
    acc = jnp.zeros_like(x)
    for r in rngs:
        q, s = quantize_int8(x, r)
        acc = acc + dequantize_int8(q, s)
    mean = acc / len(rngs)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.abs(mean - x).max()) < 4 * scale / np.sqrt(len(rngs)) \
        + 1e-6


def test_quantization_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,))
    q, s = quantize_int8(x, jax.random.PRNGKey(1))
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) + 1e-7


def test_pipeline_deterministic_and_sharded():
    pipe = TokenPipeline(vocab_size=100, seq_len=8, global_batch=4,
                         num_shards=2, seed=7)
    a = pipe.batch(3, 0)
    b = pipe.batch(3, 0)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])   # recomputable
    c = pipe.batch(3, 1)
    assert not np.array_equal(a["tokens"], c["tokens"])       # shards differ
    d = pipe.batch(4, 0)
    assert not np.array_equal(a["tokens"], d["tokens"])       # steps differ
    # labels are next-token shifted
    g = pipe.global_batch_at(0)
    assert g["tokens"].shape == (4, 8)


def test_train_resume_bitexact(tmp_path):
    """Crash-resume yields the same state as an uninterrupted run."""
    from repro.launch.train import train
    logs = []
    p1, o1, l1 = train("qwen2-0.5b", steps=4, batch=2, seq=16,
                       ckpt_dir=str(tmp_path / "a"), ckpt_every=2,
                       log=lambda *a: logs.append(a))
    # interrupted run: 2 steps, then resume to 4
    train("qwen2-0.5b", steps=2, batch=2, seq=16,
          ckpt_dir=str(tmp_path / "b"), ckpt_every=2,
          log=lambda *a: None)
    p2, o2, l2 = train("qwen2-0.5b", steps=4, batch=2, seq=16,
                       ckpt_dir=str(tmp_path / "b"), ckpt_every=2,
                       log=lambda *a: None)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
