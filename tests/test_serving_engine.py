"""Real serving engine: completion, preemption, routing, fidelity hooks."""

import jax
import pytest

from repro import configs as C
from repro.data.requests import make_serving_requests
from repro.models import transformer as T
from repro.serving.engine import ServingEngine
from repro.serving.router import ReplicaRouter


@pytest.fixture(scope="module")
def small():
    cfg = C.get_reduced("qwen2_0_5b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, n, gen=6, ctx=12, rate=100.0):
    rs = make_serving_requests("chat", rate, n, cfg.vocab_size, max_len=ctx)
    for r in rs:
        r["gen_len"] = gen
        r["prompt"] = r["prompt"][:ctx]
    return rs


def test_all_requests_served(small):
    cfg, params = small
    eng = ServingEngine(cfg, params, max_batch=3, max_len=64)
    rep = eng.run(_reqs(cfg, 5), time_scale=0.0)
    assert len(rep.results) == 5
    for r in rep.results:
        assert len(r.tokens) == 6
        assert r.e2e >= r.ttft >= 0


def test_greedy_decode_deterministic(small):
    cfg, params = small
    e1 = ServingEngine(cfg, params, max_batch=2, max_len=64)
    e2 = ServingEngine(cfg, params, max_batch=2, max_len=64)
    r1 = e1.run(_reqs(cfg, 3), time_scale=0.0)
    r2 = e2.run(_reqs(cfg, 3), time_scale=0.0)
    t1 = {r.rid: r.tokens for r in r1.results}
    t2 = {r.rid: r.tokens for r in r2.results}
    assert t1 == t2


def test_kv_budget_preemption(small):
    cfg, params = small
    eng = ServingEngine(cfg, params, max_batch=4, max_len=64,
                        kv_token_budget=40)
    rep = eng.run(_reqs(cfg, 4, gen=8, ctx=16), time_scale=0.0)
    assert len(rep.results) == 4           # everyone completes eventually
    assert rep.preemptions >= 0


def test_router_spreads_load(small):
    cfg, params = small
    engines = [ServingEngine(cfg, params, max_batch=2, max_len=64)
               for _ in range(2)]
    router = ReplicaRouter(engines)
    buckets = router.split(_reqs(cfg, 6))
    assert len(buckets) == 2
    assert abs(len(buckets[0]) - len(buckets[1])) <= 1


def test_engine_matches_model_decode(small):
    """Engine-produced tokens == raw greedy decode_step tokens."""
    import jax.numpy as jnp
    cfg, params = small
    prompt = jnp.asarray([[5, 9, 3, 7]], jnp.int32)
    # reference: prefill + greedy decode
    from repro.models import init_cache, decode_step
    cache = init_cache(cfg, 1, 64)
    for t in range(4):
        logits, cache = decode_step(params, cfg, prompt[:, t:t + 1], cache)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        logits, cache = decode_step(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(logits[0])))
    eng = ServingEngine(cfg, params, max_batch=1, max_len=64)
    rep = eng.run([dict(rid=0, arrival=0.0,
                        prompt=[5, 9, 3, 7], gen_len=4)], time_scale=0.0)
    assert rep.results[0].tokens == toks
