"""Successive-halving confirmation + shared step-cost store (PR 8).

Containment: the full-trace exact winner survives EVERY halving rung at
three seeded (model, trace) points x two objectives — the same way PR 4
pinned fluid screening.  Correctness of sharing: plans differing in any
cost-relevant coordinate (quant format, cluster device type) never share
a store bucket, and shared-store search results are bit-identical to
private-cache results.  Plus the satellites: LRU bounds with eviction
counters, the spawn-only ``fork_map`` fallback, and trace-prefix
statistics.
"""

import multiprocessing

import pytest

from repro.core import (ApexSearch, MultiFidelitySearch, SharedCostStore,
                        StepCostCache, TraceSummary, cost_fingerprint,
                        get_trace, h100_node, h200_node, ir_from_hf_config,
                        map_scheme, prefix_trace)
from repro.core.search import OBJECTIVES, fork_map

SMALL = dict(hidden_size=256, num_hidden_layers=4, num_attention_heads=8,
             num_key_value_heads=4, intermediate_size=1024, vocab_size=1024)
MEDIUM = dict(hidden_size=512, num_hidden_layers=8, num_attention_heads=8,
              num_key_value_heads=4, intermediate_size=2048, vocab_size=4096)


def small_model():
    return ir_from_hf_config(SMALL, name="tiny")


def medium_model():
    return ir_from_hf_config(MEDIUM, name="tiny8")


# ---------------------------------------------------------------------------
# containment: the exact winner survives every rung
# ---------------------------------------------------------------------------

def _rung_containment_point(model, cluster, reqs, objective, **kw):
    """Exact full search vs halving multi-fidelity search: the exact
    winner's label must appear in every rung's promoted set and in the
    finalists, and the confirmed objective value must agree."""
    exact = ApexSearch(model, cluster).search(reqs, objective=objective,
                                              **kw)
    search = ApexSearch(model, cluster)
    mf = MultiFidelitySearch(search)
    mres = mf.search(reqs, objective=objective, **kw)
    assert mres.rungs, "seeded point must actually exercise the rungs"
    label = exact.best.plan_label
    labels_of = lambda idx: {mres.surrogate_reports[i].plan_label
                             for i in idx}
    for rung in mres.rungs:
        assert label in labels_of(rung.survivor_indices), (
            f"exact best {label} pruned at rung {rung.fraction:.0%} "
            f"({rung.evaluated} -> {rung.promoted})")
        assert rung.n_requests < len(reqs)
        assert rung.promoted <= rung.evaluated
        assert rung.seconds >= 0
    assert label in labels_of(mres.survivor_indices)
    key = OBJECTIVES[objective]
    assert key(mres.best) == pytest.approx(key(exact.best), rel=1e-9)
    return mres


@pytest.mark.parametrize("objective", ["latency", "throughput"])
def test_winner_survives_rungs_chat_menu(objective):
    """Seeded point 1: small model, chat load, joint hetero disagg."""
    reqs = get_trace("chat", arrival_rate=8.0, seed=0, num_requests=48)
    _rung_containment_point(
        small_model(), h100_node(8), reqs, objective,
        feasible_only=True, disaggregated=True, max_disagg_plans=32,
        pool_menu=[h100_node(4), h200_node(4)])


@pytest.mark.parametrize("objective", ["latency", "throughput"])
def test_winner_survives_rungs_heavy_summarization(objective):
    """Seeded point 2: deeper model, bursty summarization load."""
    reqs = get_trace("summarization", arrival_rate=100.0, seed=7,
                     num_requests=40)
    _rung_containment_point(
        medium_model(), h100_node(8), reqs, objective,
        feasible_only=True, disaggregated=True, max_disagg_plans=32)


@pytest.mark.parametrize("objective", ["latency", "throughput"])
def test_winner_survives_rungs_creation_menu(objective):
    """Seeded point 3: creation trace, colocated + hetero pool menu."""
    reqs = get_trace("creation", arrival_rate=4.0, seed=11,
                     num_requests=32)
    _rung_containment_point(
        small_model(), h100_node(8), reqs, objective,
        feasible_only=True, disaggregated=True, max_disagg_plans=24,
        pool_menu=[h100_node(4), h200_node(4)])


def test_halving_matches_no_halving_best():
    """The ladder and the cliff agree on the winner (the CI smoke
    assertion, pinned here at a seeded point)."""
    reqs = get_trace("chat", arrival_rate=8.0, seed=0, num_requests=48)
    mf = MultiFidelitySearch(ApexSearch(small_model(), h100_node(8)))
    kw = dict(feasible_only=True, disaggregated=True, max_disagg_plans=32,
              pool_menu=[h100_node(4), h200_node(4)])
    with_h = mf.search(reqs, **kw)
    without = mf.search(reqs, halving=False, **kw)
    assert with_h.best.plan_label == without.best.plan_label
    assert with_h.rungs and not without.rungs
    # the ladder runs the full trace for strictly fewer candidates
    assert with_h.num_survivors < without.num_survivors
    assert with_h.screen_survivors == without.num_survivors


def test_halving_jobs_equals_serial():
    """Forked rung evaluation (pre-seeded store snapshot in each worker)
    is bit-identical to serial."""
    reqs = get_trace("summarization", arrival_rate=100.0, seed=7,
                     num_requests=40)
    kw = dict(feasible_only=True, disaggregated=True, max_disagg_plans=32)
    serial = MultiFidelitySearch(
        ApexSearch(medium_model(), h100_node(8))).search(reqs, **kw)
    par = MultiFidelitySearch(
        ApexSearch(medium_model(), h100_node(8))).search(reqs, jobs=2,
                                                         **kw)
    assert par.survivor_indices == serial.survivor_indices
    assert [r.survivor_indices for r in par.rungs] == \
        [r.survivor_indices for r in serial.rungs]
    assert par.result.all_reports == serial.result.all_reports


def test_rung_fraction_validation():
    search = ApexSearch(small_model(), h100_node(4))
    with pytest.raises(ValueError):
        MultiFidelitySearch(search, rungs=(0.25, 1.0))
    with pytest.raises(ValueError):
        MultiFidelitySearch(search, rungs=(0.0,))
    with pytest.raises(ValueError):
        MultiFidelitySearch(search, promote_frac=0.0)


def test_tiny_trace_skips_rungs():
    """Prefixes below ``min_rung_requests`` are skipped — a 8-request
    trace ranks on noise at 25%."""
    reqs = get_trace("chat", arrival_rate=8.0, seed=0, num_requests=8)
    mf = MultiFidelitySearch(ApexSearch(small_model(), h100_node(8)),
                             min_rung_requests=8)
    mres = mf.search(reqs, feasible_only=True, disaggregated=True,
                     max_disagg_plans=32,
                     pool_menu=[h100_node(4), h200_node(4)])
    assert all(r.n_requests >= 8 for r in mres.rungs)
    assert all(r.fraction >= 0.5 for r in mres.rungs)


# ---------------------------------------------------------------------------
# fingerprint correctness: no collisions across cost-relevant coordinates
# ---------------------------------------------------------------------------

def _colocated_plan(search, quant="fp16", model_dp=None):
    cands, _ = search.candidates(quant=quant, feasible_only=True)
    schemes = [c[1] for c in cands]
    if model_dp is not None:
        schemes = [s for s in schemes if s.model_dp == model_dp]
    return map_scheme(schemes[0], search.cluster)


def test_fingerprint_distinguishes_quant():
    """Two plans differing ONLY in quant format never share a bucket."""
    search = ApexSearch(small_model(), h100_node(4))
    fp16 = _colocated_plan(search, quant="fp16")
    w8a8 = _colocated_plan(search, quant="w8a8")
    f1 = cost_fingerprint(fp16, search.store, search.coll)
    f2 = cost_fingerprint(w8a8, search.store, search.coll)
    assert f1 != f2


def test_fingerprint_distinguishes_device_type():
    """Same scheme mapped onto H100 vs H200 clusters keys differently."""
    model = small_model()
    s100 = ApexSearch(model, h100_node(4))
    s200 = ApexSearch(model, h200_node(4))
    p100 = _colocated_plan(s100)
    p200 = _colocated_plan(s200)
    assert p100.scheme == p200.scheme     # truly only the cluster differs
    f100 = cost_fingerprint(p100, s100.store, s100.coll)
    f200 = cost_fingerprint(p200, s200.store, s200.coll)
    assert f100 != f200


def test_fingerprint_shares_across_model_dp():
    """Replicas of one layout run identical iterations, so DP widths of
    the same per-stage scheme SHARE a bucket — the cross-plan win (e.g.
    a disagg pool running the same 1-device layout at DP4 and DP8)."""
    import dataclasses

    search = ApexSearch(small_model(), h100_node(4))
    cands, _ = search.candidates(feasible_only=True)
    scheme = next(c[1] for c in cands if c[1].model_dp >= 2)
    narrower = dataclasses.replace(scheme, model_dp=scheme.model_dp // 2)
    wide = map_scheme(scheme, search.cluster)
    narrow = map_scheme(narrower, search.cluster)
    f_wide = cost_fingerprint(wide, search.store, search.coll)
    f_narrow = cost_fingerprint(narrow, search.store, search.coll)
    assert f_wide == f_narrow


def test_adversarial_quant_store_isolation():
    """Drive two same-shape searches differing only in quant through ONE
    shared store: per-quant tables must stay disjoint, so every report
    matches its private-cache twin bit-for-bit."""
    model = small_model()
    reqs = get_trace("chat", arrival_rate=4.0, seed=3, num_requests=24)
    shared = ApexSearch(model, h100_node(4))
    private = ApexSearch(model, h100_node(4), share_step_costs=False)
    for quant in ("fp16", "w8a8"):
        rs = shared.search(reqs, quant=quant, feasible_only=True)
        rp = private.search(reqs, quant=quant, feasible_only=True)
        assert rs.all_reports == rp.all_reports, quant
    # and the store actually kept them apart
    quants = {fp[3] for fp in shared.cost_store.tables}
    assert quants == {"fp16", "w8a8"}


def test_shared_store_bit_identical_joint_search():
    """The headline guarantee: a joint colocated+hetero-disagg search
    with the shared store returns byte-identical reports to the
    private-cache search."""
    model = small_model()
    reqs = get_trace("creation", arrival_rate=4.0, seed=11,
                     num_requests=24)
    kw = dict(objective="latency", feasible_only=True, disaggregated=True,
              max_disagg_plans=24, pool_menu=[h100_node(4), h200_node(4)])
    rs = ApexSearch(model, h100_node(8)).search(reqs, **kw)
    rp = ApexSearch(model, h100_node(8),
                    share_step_costs=False).search(reqs, **kw)
    assert rs.all_reports == rp.all_reports
    assert rs.best == rp.best
    # sharing must HELP: strictly more hits than the private caches
    assert rs.cache_hits > rp.cache_hits


# ---------------------------------------------------------------------------
# LRU bound + eviction counters
# ---------------------------------------------------------------------------

def test_step_cost_cache_lru_bound():
    from repro.core.ir import Workload
    calls = []

    def cost(w):
        calls.append(w.prefill_tokens)
        return float(w.prefill_tokens), 0.0

    cache = StepCostCache(cost, maxsize=4)
    for t in range(1, 9):
        cache.cost(Workload(prefill_tokens=t, batch_sequences=1))
    st = cache.stats()
    assert st["entries"] == 4
    assert st["evictions"] == 4
    assert st["misses"] == 8 and st["hits"] == 0
    # the four youngest survive; re-asking an evicted key re-prices it
    cache.cost(Workload(prefill_tokens=8, batch_sequences=1))
    assert cache.stats()["hits"] == 1
    cache.cost(Workload(prefill_tokens=1, batch_sequences=1))
    assert cache.stats()["misses"] == 9


def test_step_cost_cache_lru_recency():
    from repro.core.ir import Workload

    cache = StepCostCache(lambda w: (1.0, 0.0), maxsize=2)
    w1, w2, w3 = (Workload(prefill_tokens=t, batch_sequences=1)
                  for t in (1, 2, 3))
    cache.cost(w1)
    cache.cost(w2)
    cache.cost(w1)          # refresh w1 — w2 becomes the LRU victim
    cache.cost(w3)
    assert w1.signature() in cache.table
    assert w2.signature() not in cache.table


def test_shared_store_stats_and_eviction_rollup():
    store = SharedCostStore(maxsize=2)
    c = store.cache(("fp",), lambda w: (1.0, 0.0))
    from repro.core.ir import Workload
    for t in (1, 2, 3):
        c.cost(Workload(prefill_tokens=t, batch_sequences=1))
    st = store.stats()
    assert st == {"tables": 1, "entries": 2, "evictions": 1}
    assert c.stats()["evictions"] == 1
    # a second view on the same fingerprint sees the shared entries
    c2 = store.cache(("fp",), lambda w: (1.0, 0.0))
    c2.cost(Workload(prefill_tokens=3, batch_sequences=1))
    assert c2.stats() == {"hits": 1, "misses": 0, "entries": 2,
                          "evictions": 1}


# ---------------------------------------------------------------------------
# spawn-only platforms fall back to serial with a warning
# ---------------------------------------------------------------------------

def test_fork_map_spawn_only_falls_back_serial(monkeypatch):
    monkeypatch.setattr(multiprocessing, "get_all_start_methods",
                        lambda: ["spawn"])
    with pytest.warns(RuntimeWarning, match="fork"):
        out = fork_map(lambda i: i * i, 6, jobs=3)
    assert out == [i * i for i in range(6)]


def test_fork_map_spawn_only_search_still_works(monkeypatch):
    monkeypatch.setattr(multiprocessing, "get_all_start_methods",
                        lambda: ["spawn"])
    search = ApexSearch(small_model(), h100_node(4))
    reqs = get_trace("chat", arrival_rate=4.0, seed=0, num_requests=12)
    with pytest.warns(RuntimeWarning):
        res = search.search(reqs, feasible_only=True, jobs=4)
    assert res.best.feasible


# ---------------------------------------------------------------------------
# trace prefixes preserve arrival statistics
# ---------------------------------------------------------------------------

def test_prefix_trace_properties():
    reqs = get_trace("chat", arrival_rate=8.0, seed=0, num_requests=64)
    pre = prefix_trace(reqs, 0.25)
    assert len(pre) == 16
    # a count-prefix keeps absolute arrivals of the earliest requests
    ordered = sorted(reqs, key=lambda r: (r.arrival, r.rid))
    assert pre == ordered[:16]
    assert prefix_trace(reqs, 1.0) == ordered
    assert prefix_trace(reqs, 2.0) == ordered
    assert len(prefix_trace(reqs, 1e-9)) == 1
    with pytest.raises(ValueError):
        prefix_trace(reqs, 0.0)


def test_prefix_trace_preserves_arrival_rate():
    """Poisson prefix: the empirical rate of the first quarter matches
    the full trace's rate (same process, shorter window)."""
    reqs = get_trace("chat", arrival_rate=16.0, seed=1, num_requests=400)
    full = TraceSummary.of(reqs)
    quarter = TraceSummary.of(prefix_trace(reqs, 0.25))
    assert quarter.arrival_rate == pytest.approx(full.arrival_rate,
                                                 rel=0.25)
    assert quarter.ctx_mean == pytest.approx(full.ctx_mean, rel=0.35)


def test_of_prefixes_matches_pointwise():
    reqs = get_trace("chat", arrival_rate=8.0, seed=0, num_requests=64)
    summaries = TraceSummary.of_prefixes(reqs, (0.25, 0.5))
    assert set(summaries) == {0.25, 0.5, 1.0}
    ordered = sorted(reqs, key=lambda r: (r.arrival, r.rid))
    for f in (0.25, 0.5, 1.0):
        assert summaries[f] == TraceSummary.of(prefix_trace(ordered, f,
                                                            presorted=True))
