"""Disaggregated serving subsystem + trace synthesis invariants.

Covers the PR-1 satellite checklist: trace determinism / moment matching,
the KV-transfer byte/time model against hand-computed values, the
decayed-backlog router fix, the SearchResult objective fix, and an
end-to-end coupled two-pool simulation smoke test.
"""

import math

import pytest

from repro.core import (ApexSearch, BatchingModule, BatchingPolicy,
                        CollectiveModel, get_format, get_trace,
                        h100_multinode, h100_node, ir_from_hf_config,
                        synthesize_trace, trace_stats)
from repro.core.search import OBJECTIVES, SearchResult
from repro.core.simulator import SimulationReport
from repro.core.trace import TRACE_SPECS, Request
from repro.disagg import (DisaggScheme, DisaggSimulator, KVTransferModel,
                          cross_pool_span, generate_disagg_schemes,
                          map_disagg_scheme)
from repro.serving.router import BacklogBalancer

SMALL = dict(hidden_size=256, num_hidden_layers=4, num_attention_heads=8,
             num_key_value_heads=4, intermediate_size=1024, vocab_size=1024)


def small_model():
    return ir_from_hf_config(SMALL, name="tiny")


# ---------------------------------------------------------------------------
# trace synthesis: determinism + moment matching
# ---------------------------------------------------------------------------

def test_trace_same_seed_reproducible():
    spec = TRACE_SPECS["chat"]
    a = synthesize_trace(spec, arrival_rate=1.0, seed=7)
    b = synthesize_trace(spec, arrival_rate=1.0, seed=7)
    assert a == b


def test_trace_seed_changes_trace():
    spec = TRACE_SPECS["chat"]
    a = synthesize_trace(spec, arrival_rate=1.0, seed=1)
    b = synthesize_trace(spec, arrival_rate=1.0, seed=2)
    assert a != b


@pytest.mark.parametrize("name", sorted(TRACE_SPECS))
def test_trace_moments_match_spec(name):
    spec = TRACE_SPECS[name]
    reqs = synthesize_trace(spec, arrival_rate=1.0, seed=0,
                            num_requests=4000)
    stats = trace_stats(reqs)
    # 4000 log-normal samples: means within ~3 stderr of the target
    for key, mean, std in (("ctx_mean", spec.ctx_mean, spec.ctx_std),
                           ("gen_mean", spec.gen_mean, spec.gen_std)):
        tol = 3.5 * std / math.sqrt(len(reqs)) + 0.02 * mean
        assert abs(stats[key] - mean) < tol, (key, stats[key], mean)


# ---------------------------------------------------------------------------
# KV-transfer byte/time model vs hand-computed values
# ---------------------------------------------------------------------------

def test_kv_bytes_hand_computed():
    model = small_model()
    coll = CollectiveModel(h100_multinode(2, 8))
    kv = KVTransferModel(coll, mode="blocking")
    q = get_format("fp16")
    # layers x 2(K,V) x kv_heads x head_dim x kv_bytes x ctx
    expected = 4 * 2 * 4 * 32 * q.kv_bytes * 1000
    assert kv.kv_bytes(model, 1000, "fp16") == pytest.approx(expected)
    # kv8 halves the payload
    assert kv.kv_bytes(model, 1000, "kv8") == pytest.approx(expected / 2)


def test_kv_transfer_time_hand_computed():
    cluster = h100_multinode(2, 8)
    coll = CollectiveModel(cluster)
    model = small_model()
    ctx, lanes, span = 1000, 2, 16
    nbytes = 4 * 2 * 4 * 32 * 2.0 * ctx
    ib = cluster.levels[1]          # span 16 -> infiniband
    wire = (nbytes / lanes) / ib.bw_per_device + ib.launch_s + ib.latency_s

    blocking = KVTransferModel(coll, mode="blocking")
    est = blocking.estimate(model, ctx, "fp16", span, lanes=lanes)
    assert est.nbytes == pytest.approx(nbytes)
    assert est.delay_s == pytest.approx(wire)
    assert est.wire_s == pytest.approx(wire)
    assert est.energy_j > 0

    layerwise = KVTransferModel(coll, mode="layerwise")
    est_l = layerwise.estimate(model, ctx, "fp16", span, lanes=lanes)
    per_layer = (nbytes / (lanes * 4)) / ib.bw_per_device \
        + ib.launch_s + ib.latency_s
    assert est_l.delay_s == pytest.approx(per_layer)
    assert est_l.wire_s == pytest.approx(wire)
    assert est_l.delay_s < est.delay_s


def test_cross_pool_span_picks_mapper_level():
    cluster = h100_multinode(2, 8)
    # split at 8: pools on different nodes -> the IB level
    assert cross_pool_span(cluster, 8) == 16
    assert cluster.level_for_group(cross_pool_span(cluster, 8)).name \
        == "infiniband"
    # split at 4: both pools inside one NVLink group
    assert cross_pool_span(cluster, 4) == 2
    assert cluster.level_for_group(cross_pool_span(cluster, 4)).name \
        == "nvlink"


# ---------------------------------------------------------------------------
# pool enumeration: weight-memory pre-filter path
# ---------------------------------------------------------------------------

def test_infeasible_pool_splits_rejected():
    big = ir_from_hf_config(
        dict(hidden_size=8192, num_hidden_layers=80,
             num_attention_heads=64, num_key_value_heads=8,
             intermediate_size=28672, vocab_size=128256), name="llama70b")
    cluster = h100_multinode(2, 8)
    cap = cluster.device.hbm_bytes * 0.92
    schemes = generate_disagg_schemes(big, cluster, max_plans=100000)
    assert schemes, "some split must fit"
    for s in schemes:
        assert s.prefill.weight_bytes_per_device() < cap
        assert s.decode.weight_bytes_per_device() < cap
        assert s.total_devices == cluster.num_devices
    # a 1-device pool cannot hold 140 GB of weights -> no such split
    assert all(s.prefill_devices > 1 and s.decode_devices > 1
               for s in schemes)


# ---------------------------------------------------------------------------
# decode-role batching
# ---------------------------------------------------------------------------

def test_decode_role_runs_no_prefill_tokens():
    seen = []

    def step_cost(w):
        seen.append(w)
        return 1e-3, 1e-2

    reqs = [Request(rid=i, arrival=0.0, context_len=64, gen_len=8)
            for i in range(4)]
    mod = BatchingModule(10000, BatchingPolicy(fast_forward=False),
                         role="decode")
    res = mod.run(reqs, step_cost)
    assert all(w.prefill_tokens == 0 for w in seen)
    assert all(rec.finish_time > 0 for rec in res.records)
    # each request decodes gen_len - 1 tokens here (token 1 came from the
    # prefill pool); KV includes the shipped prompt
    assert res.peak_kv_tokens >= 4 * 65


def test_decode_role_gen1_finishes_instantly():
    reqs = [Request(rid=0, arrival=0.5, context_len=32, gen_len=1)]
    mod = BatchingModule(1000, BatchingPolicy(), role="decode")
    res = mod.run(reqs, lambda w: (1e-3, 0.0))
    assert res.records[0].finish_time == pytest.approx(0.5)
    assert res.iterations == 0


# ---------------------------------------------------------------------------
# router: decayed backlog
# ---------------------------------------------------------------------------

def test_backlog_decays_with_arrival_gaps():
    bal = BacklogBalancer(2, drain_rate=100.0)
    assert bal.assign(0.0, 1000.0) == 0
    # immediately after, replica 1 is emptier
    assert bal.assign(0.0, 10.0) == 1
    # 100 s later both replicas have fully drained; assignment must not
    # remember the old 1000-token backlog (the monotonic-accumulation bug)
    i = bal.assign(100.0, 10.0)
    assert bal.backlog[0] <= 10.0 + 1e-9 and bal.backlog[1] <= 20.0
    assert i in (0, 1)


# ---------------------------------------------------------------------------
# SearchResult.top ranks by the search's own objective
# ---------------------------------------------------------------------------

def _mk_report(label, e2e, energy):
    return SimulationReport(
        plan_label=label, e2e_latency=e2e, total_energy=energy,
        ttft_mean=0, ttft_p95=0, tpot_mean=0, tpot_p95=0, latency_p95=0,
        throughput_tok_s=0, mfu=0, mbu=0, iterations=1, preemptions=0,
        peak_kv_tokens=1, peak_batch=1, feasible=True)


def test_search_result_top_respects_objective():
    fast_hot = _mk_report("fast-hot", e2e=1.0, energy=100.0)
    slow_cool = _mk_report("slow-cool", e2e=2.0, energy=10.0)
    res = SearchResult(best=slow_cool, best_plan=None,
                       all_reports=[fast_hot, slow_cool], num_schemes=2,
                       num_feasible=2, search_seconds=0.0,
                       objective="energy")
    assert res.top(1)[0].plan_label == "slow-cool"
    res_lat = SearchResult(best=fast_hot, best_plan=None,
                           all_reports=[fast_hot, slow_cool],
                           num_schemes=2, num_feasible=2,
                           search_seconds=0.0, objective="latency")
    assert res_lat.top(1)[0].plan_label == "fast-hot"


# ---------------------------------------------------------------------------
# coupled two-pool simulation end to end
# ---------------------------------------------------------------------------

def _simulate_disagg(scheme, reqs, cluster):
    search = ApexSearch(small_model(), cluster)
    plan = map_disagg_scheme(scheme, cluster)
    sim = DisaggSimulator(plan, search.store, search.coll)
    return sim.simulate(reqs, keep_records=True)


def test_disagg_simulation_end_to_end():
    cluster = h100_node(8)
    model = small_model()
    schemes = generate_disagg_schemes(model, cluster, max_plans=100000)
    scheme = next(s for s in schemes
                  if s.prefill_devices == 4 and s.decode_devices == 4
                  and s.prefill.model_dp == 1 and s.decode.model_dp == 1)
    reqs = get_trace("chat", arrival_rate=4.0, seed=3, num_requests=40)
    rep = _simulate_disagg(scheme, reqs, cluster)
    assert rep.feasible
    assert rep.records is not None and len(rep.records) == len(reqs)
    for rec in rep.records:
        assert rec.first_token_time >= rec.arrival
        assert rec.finish_time >= rec.first_token_time
        if rec.gen_len > 1:
            assert rec.tpot > 0
    assert rep.ttft_p95 > 0 and rep.e2e_latency > 0
    assert rep.e2e_latency >= max(r.finish_time for r in rep.records) - 1e-9

    # determinism: identical inputs -> identical report
    rep2 = _simulate_disagg(scheme, reqs, cluster)
    assert rep.e2e_latency == rep2.e2e_latency
    assert rep.ttft_p95 == rep2.ttft_p95
    assert rep.tpot_p95 == rep2.tpot_p95
    assert rep.total_energy == rep2.total_energy


def test_blocking_transfer_delays_decode():
    """Blocking KV handoff must not finish earlier than layerwise."""
    cluster = h100_multinode(2, 8)   # cross-node handoff: visible cost
    model = small_model()
    schemes = generate_disagg_schemes(model, cluster, max_plans=100000)
    base = next(s for s in schemes
                if s.prefill_devices == 8 and s.prefill.model_dp == 1
                and s.decode.model_dp == 1)
    reqs = get_trace("summarization", arrival_rate=2.0, seed=1,
                     num_requests=24)
    lw = _simulate_disagg(base, reqs, cluster)
    import dataclasses
    blocking = dataclasses.replace(base, transfer_mode="blocking")
    bl = _simulate_disagg(blocking, reqs, cluster)
    assert bl.feasible and lw.feasible
    assert bl.e2e_latency >= lw.e2e_latency - 1e-9
    assert bl.tpot_p95 >= lw.tpot_p95 - 1e-9


def test_joint_search_ranks_both_families():
    model = small_model()
    cluster = h100_node(8)
    reqs = get_trace("chat", arrival_rate=4.0, seed=0, num_requests=32)
    search = ApexSearch(model, cluster)
    res = search.search(reqs, objective="ttft", feasible_only=True,
                        disaggregated=True, max_disagg_plans=64)
    labels = [r.plan_label for r in res.all_reports]
    assert any(l.startswith("disagg[") for l in labels)
    assert any(not l.startswith("disagg[") for l in labels)
    assert res.objective == "ttft"
    # best-by-objective really is the argmin over feasible reports
    feas = [r for r in res.all_reports if r.feasible]
    assert res.best.ttft_p95 == min(OBJECTIVES["ttft"](r) for r in feas)
