"""Disaggregated serving subsystem + trace synthesis invariants.

Covers the PR-1 satellite checklist: trace determinism / moment matching,
the KV-transfer byte/time model against hand-computed values, the
decayed-backlog router fix, the SearchResult objective fix, and an
end-to-end coupled two-pool simulation smoke test.
"""

import dataclasses
import math

import pytest

from repro.core import (ApexSearch, BatchingModule, BatchingPolicy,
                        CollectiveModel, NetworkLevel, cross_pool_link,
                        get_format, get_trace, h100_multinode, h100_node,
                        h200_node, ir_from_hf_config, synthesize_trace,
                        trace_stats, tpu_v5e_pod)
from repro.core.profiles import AnalyticBackend, ProfileStore
from repro.core.search import OBJECTIVES, SearchResult
from repro.core.simulator import SimulationReport
from repro.core.trace import TRACE_SPECS, Request
from repro.disagg import (DisaggScheme, DisaggSimulator, KVTransferModel,
                          cross_pool_span, generate_disagg_schemes,
                          is_mixed_label, map_disagg_scheme)
from repro.serving.router import BacklogBalancer

SMALL = dict(hidden_size=256, num_hidden_layers=4, num_attention_heads=8,
             num_key_value_heads=4, intermediate_size=1024, vocab_size=1024)


def small_model():
    return ir_from_hf_config(SMALL, name="tiny")


# ---------------------------------------------------------------------------
# trace synthesis: determinism + moment matching
# ---------------------------------------------------------------------------

def test_trace_same_seed_reproducible():
    spec = TRACE_SPECS["chat"]
    a = synthesize_trace(spec, arrival_rate=1.0, seed=7)
    b = synthesize_trace(spec, arrival_rate=1.0, seed=7)
    assert a == b


def test_trace_seed_changes_trace():
    spec = TRACE_SPECS["chat"]
    a = synthesize_trace(spec, arrival_rate=1.0, seed=1)
    b = synthesize_trace(spec, arrival_rate=1.0, seed=2)
    assert a != b


@pytest.mark.parametrize("name", sorted(TRACE_SPECS))
def test_trace_moments_match_spec(name):
    spec = TRACE_SPECS[name]
    reqs = synthesize_trace(spec, arrival_rate=1.0, seed=0,
                            num_requests=4000)
    stats = trace_stats(reqs)
    # 4000 log-normal samples: means within ~3 stderr of the target
    for key, mean, std in (("ctx_mean", spec.ctx_mean, spec.ctx_std),
                           ("gen_mean", spec.gen_mean, spec.gen_std)):
        tol = 3.5 * std / math.sqrt(len(reqs)) + 0.02 * mean
        assert abs(stats[key] - mean) < tol, (key, stats[key], mean)


# ---------------------------------------------------------------------------
# KV-transfer byte/time model vs hand-computed values
# ---------------------------------------------------------------------------

def test_kv_bytes_hand_computed():
    model = small_model()
    coll = CollectiveModel(h100_multinode(2, 8))
    kv = KVTransferModel(coll, mode="blocking")
    q = get_format("fp16")
    # layers x 2(K,V) x kv_heads x head_dim x kv_bytes x ctx
    expected = 4 * 2 * 4 * 32 * q.kv_bytes * 1000
    assert kv.kv_bytes(model, 1000, "fp16") == pytest.approx(expected)
    # kv8 halves the payload
    assert kv.kv_bytes(model, 1000, "kv8") == pytest.approx(expected / 2)


def test_kv_transfer_time_hand_computed():
    cluster = h100_multinode(2, 8)
    coll = CollectiveModel(cluster)
    model = small_model()
    ctx, lanes, span = 1000, 2, 16
    nbytes = 4 * 2 * 4 * 32 * 2.0 * ctx
    ib = cluster.levels[1]          # span 16 -> infiniband
    wire = (nbytes / lanes) / ib.bw_per_device + ib.launch_s + ib.latency_s

    blocking = KVTransferModel(coll, mode="blocking")
    est = blocking.estimate(model, ctx, "fp16", span, lanes=lanes)
    assert est.nbytes == pytest.approx(nbytes)
    assert est.delay_s == pytest.approx(wire)
    assert est.wire_s == pytest.approx(wire)
    assert est.energy_j > 0

    layerwise = KVTransferModel(coll, mode="layerwise")
    est_l = layerwise.estimate(model, ctx, "fp16", span, lanes=lanes)
    per_layer = (nbytes / (lanes * 4)) / ib.bw_per_device \
        + ib.launch_s + ib.latency_s
    assert est_l.delay_s == pytest.approx(per_layer)
    assert est_l.wire_s == pytest.approx(wire)
    assert est_l.delay_s < est.delay_s


def test_cross_pool_span_picks_mapper_level():
    cluster = h100_multinode(2, 8)
    # split at 8: pools on different nodes -> the IB level
    assert cross_pool_span(cluster, 8) == 16
    assert cluster.level_for_group(cross_pool_span(cluster, 8)).name \
        == "infiniband"
    # split at 4: both pools inside one NVLink group
    assert cross_pool_span(cluster, 4) == 2
    assert cluster.level_for_group(cross_pool_span(cluster, 4)).name \
        == "nvlink"


# ---------------------------------------------------------------------------
# pool enumeration: weight-memory pre-filter path
# ---------------------------------------------------------------------------

def test_infeasible_pool_splits_rejected():
    big = ir_from_hf_config(
        dict(hidden_size=8192, num_hidden_layers=80,
             num_attention_heads=64, num_key_value_heads=8,
             intermediate_size=28672, vocab_size=128256), name="llama70b")
    cluster = h100_multinode(2, 8)
    cap = cluster.device.hbm_bytes * 0.92
    schemes = generate_disagg_schemes(big, cluster, max_plans=100000)
    assert schemes, "some split must fit"
    for s in schemes:
        assert s.prefill.weight_bytes_per_device() < cap
        assert s.decode.weight_bytes_per_device() < cap
        assert s.total_devices == cluster.num_devices
    # a 1-device pool cannot hold 140 GB of weights -> no such split
    assert all(s.prefill_devices > 1 and s.decode_devices > 1
               for s in schemes)


# ---------------------------------------------------------------------------
# decode-role batching
# ---------------------------------------------------------------------------

def test_decode_role_runs_no_prefill_tokens():
    seen = []

    def step_cost(w):
        seen.append(w)
        return 1e-3, 1e-2

    reqs = [Request(rid=i, arrival=0.0, context_len=64, gen_len=8)
            for i in range(4)]
    mod = BatchingModule(10000, BatchingPolicy(fast_forward=False),
                         role="decode")
    res = mod.run(reqs, step_cost)
    assert all(w.prefill_tokens == 0 for w in seen)
    assert all(rec.finish_time > 0 for rec in res.records)
    # each request decodes gen_len - 1 tokens here (token 1 came from the
    # prefill pool); KV includes the shipped prompt
    assert res.peak_kv_tokens >= 4 * 65


def test_decode_role_gen1_finishes_instantly():
    reqs = [Request(rid=0, arrival=0.5, context_len=32, gen_len=1)]
    mod = BatchingModule(1000, BatchingPolicy(), role="decode")
    res = mod.run(reqs, lambda w: (1e-3, 0.0))
    assert res.records[0].finish_time == pytest.approx(0.5)
    assert res.iterations == 0


# ---------------------------------------------------------------------------
# router: decayed backlog
# ---------------------------------------------------------------------------

def test_backlog_decays_with_arrival_gaps():
    bal = BacklogBalancer(2, drain_rate=100.0)
    assert bal.assign(0.0, 1000.0) == 0
    # immediately after, replica 1 is emptier
    assert bal.assign(0.0, 10.0) == 1
    # 100 s later both replicas have fully drained; assignment must not
    # remember the old 1000-token backlog (the monotonic-accumulation bug)
    i = bal.assign(100.0, 10.0)
    assert bal.backlog[0] <= 10.0 + 1e-9 and bal.backlog[1] <= 20.0
    assert i in (0, 1)


# ---------------------------------------------------------------------------
# SearchResult.top ranks by the search's own objective
# ---------------------------------------------------------------------------

def _mk_report(label, e2e, energy):
    return SimulationReport(
        plan_label=label, e2e_latency=e2e, total_energy=energy,
        ttft_mean=0, ttft_p95=0, tpot_mean=0, tpot_p95=0, latency_p95=0,
        throughput_tok_s=0, mfu=0, mbu=0, iterations=1, preemptions=0,
        peak_kv_tokens=1, peak_batch=1, feasible=True)


def test_search_result_top_respects_objective():
    fast_hot = _mk_report("fast-hot", e2e=1.0, energy=100.0)
    slow_cool = _mk_report("slow-cool", e2e=2.0, energy=10.0)
    res = SearchResult(best=slow_cool, best_plan=None,
                       all_reports=[fast_hot, slow_cool], num_schemes=2,
                       num_feasible=2, search_seconds=0.0,
                       objective="energy")
    assert res.top(1)[0].plan_label == "slow-cool"
    res_lat = SearchResult(best=fast_hot, best_plan=None,
                           all_reports=[fast_hot, slow_cool],
                           num_schemes=2, num_feasible=2,
                           search_seconds=0.0, objective="latency")
    assert res_lat.top(1)[0].plan_label == "fast-hot"


# ---------------------------------------------------------------------------
# coupled two-pool simulation end to end
# ---------------------------------------------------------------------------

def _simulate_disagg(scheme, reqs, cluster):
    search = ApexSearch(small_model(), cluster)
    plan = map_disagg_scheme(scheme, cluster)
    sim = DisaggSimulator(plan, search.store, search.coll)
    return sim.simulate(reqs, keep_records=True)


def test_disagg_simulation_end_to_end():
    cluster = h100_node(8)
    model = small_model()
    schemes = generate_disagg_schemes(model, cluster, max_plans=100000)
    scheme = next(s for s in schemes
                  if s.prefill_devices == 4 and s.decode_devices == 4
                  and s.prefill.model_dp == 1 and s.decode.model_dp == 1)
    reqs = get_trace("chat", arrival_rate=4.0, seed=3, num_requests=40)
    rep = _simulate_disagg(scheme, reqs, cluster)
    assert rep.feasible
    assert rep.records is not None and len(rep.records) == len(reqs)
    for rec in rep.records:
        assert rec.first_token_time >= rec.arrival
        assert rec.finish_time >= rec.first_token_time
        if rec.gen_len > 1:
            assert rec.tpot > 0
    assert rep.ttft_p95 > 0 and rep.e2e_latency > 0
    assert rep.e2e_latency >= max(r.finish_time for r in rep.records) - 1e-9

    # determinism: identical inputs -> identical report
    rep2 = _simulate_disagg(scheme, reqs, cluster)
    assert rep.e2e_latency == rep2.e2e_latency
    assert rep.ttft_p95 == rep2.ttft_p95
    assert rep.tpot_p95 == rep2.tpot_p95
    assert rep.total_energy == rep2.total_energy


def test_blocking_transfer_delays_decode():
    """Blocking KV handoff must not finish earlier than layerwise."""
    cluster = h100_multinode(2, 8)   # cross-node handoff: visible cost
    model = small_model()
    schemes = generate_disagg_schemes(model, cluster, max_plans=100000)
    base = next(s for s in schemes
                if s.prefill_devices == 8 and s.prefill.model_dp == 1
                and s.decode.model_dp == 1)
    reqs = get_trace("summarization", arrival_rate=2.0, seed=1,
                     num_requests=24)
    lw = _simulate_disagg(base, reqs, cluster)
    import dataclasses
    blocking = dataclasses.replace(base, transfer_mode="blocking")
    bl = _simulate_disagg(blocking, reqs, cluster)
    assert bl.feasible and lw.feasible
    assert bl.e2e_latency >= lw.e2e_latency - 1e-9
    assert bl.tpot_p95 >= lw.tpot_p95 - 1e-9


# ---------------------------------------------------------------------------
# heterogeneous pools
# ---------------------------------------------------------------------------

def test_cross_pool_link_picks_min_bandwidth():
    h100, tpu = h100_node(4), tpu_v5e_pod(chips=16, ring_group=16)
    link = cross_pool_link(h100, tpu)
    # joint wire is paced by the slower injector (ICI 50 GB/s vs NVLink 450)
    assert link.bw_per_device == pytest.approx(50e9)
    assert link.latency_s == pytest.approx(
        max(h100.levels[-1].latency_s, tpu.levels[-1].latency_s))
    assert link.launch_s == pytest.approx(
        max(h100.levels[-1].launch_s, tpu.levels[-1].launch_s))
    assert link.group_size == 4 + 16
    # symmetric in the min/max aggregates
    rev = cross_pool_link(tpu, h100)
    assert rev.bw_per_device == link.bw_per_device
    assert rev.latency_s == link.latency_s


def test_is_mixed_label_classification():
    assert not is_mixed_label("disagg[2P:x | 2D:y]@layerwise")
    assert not is_mixed_label("DP4xPP1x[...]@fp16")
    assert not is_mixed_label("disagg[...]@layerwise#H200-SXM>H200-SXM")
    assert is_mixed_label("disagg[...]@layerwise#H100-SXM>H200-SXM")
    # stays consistent with what DisaggPlan.label() actually emits
    model = small_model()
    scheme = _hetero_scheme(model, h100_node(2), h200_node(2))
    plan = map_disagg_scheme(scheme, prefill_cluster=h100_node(2),
                             decode_cluster=h200_node(2))
    assert is_mixed_label(plan.label())
    same = map_disagg_scheme(scheme, prefill_cluster=h100_node(2),
                             decode_cluster=h100_node(2))
    assert not is_mixed_label(same.label())


def test_hetero_prefilter_uses_per_pool_hbm():
    """A model too big for a 2xH100 pool but fitting a 2xH200 pool must
    only be admitted on the H200 side."""
    big = ir_from_hf_config(
        dict(hidden_size=8192, num_hidden_layers=96,
             num_attention_heads=64, num_key_value_heads=8,
             intermediate_size=28672, vocab_size=128256), name="mid")
    per_dev_2 = None
    from repro.core import generate_schemes
    cands = [s for s in generate_schemes(big, 2, quant="fp16")
             if s.is_feasible_for_current_systems()]
    per_dev_2 = min(s.weight_bytes_per_device() for s in cands)
    # sanity: the scenario really straddles the two HBM sizes
    assert 80e9 * 0.92 < per_dev_2 < 141e9 * 0.92

    from repro.disagg import generate_disagg_schemes
    h100_fit = generate_disagg_schemes(
        big, prefill_cluster=h100_node(2), decode_cluster=h100_node(2),
        max_plans=100000)
    mixed = generate_disagg_schemes(
        big, prefill_cluster=h100_node(2), decode_cluster=h200_node(2),
        max_plans=100000)
    assert not h100_fit          # neither pool can hold the weights
    assert not mixed             # the H100 prefill pool still can't
    h200_both = generate_disagg_schemes(
        big, prefill_cluster=h200_node(2), decode_cluster=h200_node(2),
        max_plans=100000)
    assert h200_both             # per-pool HBM admits the H200 pools


def _hetero_scheme(model, pre_c, dec_c):
    schemes = generate_disagg_schemes(
        model, prefill_cluster=pre_c, decode_cluster=dec_c,
        max_plans=100000)
    return next(s for s in schemes
                if s.prefill.model_dp == 1 and s.decode.model_dp == 1
                and s.prefill.pp_stages == 1 and s.decode.pp_stages == 1)


def test_hetero_plan_simulates_end_to_end():
    model = small_model()
    pre_c, dec_c = h100_node(4), h200_node(4)
    scheme = _hetero_scheme(model, pre_c, dec_c)
    plan = map_disagg_scheme(scheme, prefill_cluster=pre_c,
                             decode_cluster=dec_c)
    assert not plan.homogeneous
    assert plan.cross_level is not None
    assert "#H100-SXM>H200-SXM" in plan.label()
    store = ProfileStore(AnalyticBackend(pre_c))
    sim = DisaggSimulator(plan, store, CollectiveModel(pre_c))
    reqs = get_trace("chat", arrival_rate=4.0, seed=3, num_requests=40)
    rep = sim.simulate(reqs, keep_records=True)
    assert rep.feasible
    assert rep.plan_label == plan.label()
    assert len(rep.records) == len(reqs)
    for rec in rep.records:
        assert rec.finish_time >= rec.first_token_time >= rec.arrival
    # decode pool sized by the H200's HBM, not the H100's
    assert scheme.decode.kv_token_capacity(141e9) \
        > scheme.decode.kv_token_capacity(80e9)


def test_hetero_degenerate_matches_homogeneous():
    """Identical pool devices through the per-pool-cluster plumbing must
    reproduce the shared-cluster (PR-1) path exactly: same labels, same
    objective values, bit for bit."""
    model = small_model()
    cluster = h100_node(8)
    schemes = generate_disagg_schemes(model, cluster, max_plans=100000)
    scheme = next(s for s in schemes
                  if s.prefill_devices == 4 and s.decode_devices == 4
                  and s.prefill.model_dp == 1 and s.decode.model_dp == 1
                  and s.prefill.pp_stages == 1 and s.decode.pp_stages == 1)
    reqs = get_trace("chat", arrival_rate=4.0, seed=3, num_requests=40)

    search = ApexSearch(model, cluster)
    homo = DisaggSimulator(map_disagg_scheme(scheme, cluster),
                           search.store, search.coll).simulate(reqs)

    pre_c, dec_c = h100_node(4), h100_node(4)
    plan = map_disagg_scheme(scheme, prefill_cluster=pre_c,
                             decode_cluster=dec_c)
    het = DisaggSimulator(plan, ProfileStore(AnalyticBackend(pre_c)),
                          CollectiveModel(pre_c)).simulate(reqs)

    # island pairs are suffixed with their pool devices (they are NOT the
    # same deployment as a shared-cluster split); everything else matches
    assert het.plan_label == homo.plan_label + "#H100-SXM>H100-SXM"
    for field in ("e2e_latency", "total_energy", "ttft_mean", "ttft_p95",
                  "tpot_mean", "tpot_p95", "latency_p95",
                  "throughput_tok_s", "mfu", "mbu", "iterations",
                  "preemptions", "peak_kv_tokens", "peak_batch"):
        assert getattr(het, field) == getattr(homo, field), field


def test_hetero_blocking_no_faster_than_layerwise():
    model = small_model()
    pre_c, dec_c = h100_node(4), h200_node(4)
    base = _hetero_scheme(model, pre_c, dec_c)
    blocking = dataclasses.replace(base, transfer_mode="blocking")
    reqs = get_trace("summarization", arrival_rate=2.0, seed=1,
                     num_requests=24)

    def run(s):
        plan = map_disagg_scheme(s, prefill_cluster=pre_c,
                                 decode_cluster=dec_c)
        sim = DisaggSimulator(plan, ProfileStore(AnalyticBackend(pre_c)),
                              CollectiveModel(pre_c))
        return sim.simulate(reqs)

    lw, bl = run(base), run(blocking)
    assert bl.feasible and lw.feasible
    assert bl.e2e_latency >= lw.e2e_latency - 1e-9


def test_search_pool_menu_ranks_hetero_plans():
    """A heterogeneous DisaggPlan must appear (and rank) in
    ApexSearch.search(disaggregated=True) alongside colocated and
    homogeneous-disagg candidates."""
    model = small_model()
    search = ApexSearch(model, h100_node(4))
    reqs = get_trace("chat", arrival_rate=4.0, seed=0, num_requests=24)
    res = search.search(reqs, objective="ttft", feasible_only=True,
                        disaggregated=True, max_disagg_plans=64,
                        pool_menu=[h100_node(2), h200_node(2)])
    labels = [r.plan_label for r in res.all_reports]
    assert any("#H100-SXM>H200-SXM" in l for l in labels)
    assert any("#H200-SXM>H100-SXM" in l for l in labels)
    assert any(l.startswith("disagg[") and "#" not in l for l in labels)
    assert any(not l.startswith("disagg[") for l in labels)
    feas = [r for r in res.all_reports if r.feasible]
    assert res.best.ttft_p95 == min(r.ttft_p95 for r in feas)
    # menu pairs over the device budget are never enumerated: every
    # hetero candidate fits 2 + 2 = 4 devices
    for l in labels:
        if "#" in l:
            assert "2P:" in l and "2D:" in l


class _FreeRefetchKV:
    """Wraps a KVTransferModel zeroing the full-cache wire time the
    re-fetch path charges (delay_s — the admission handoff — is kept), to
    reconstruct the pre-fix free-re-fetch behavior as a baseline."""

    def __init__(self, inner):
        self.inner = inner
        self.mode = inner.mode

    def kv_bytes(self, *a, **k):
        return self.inner.kv_bytes(*a, **k)

    def estimate(self, *a, **k):
        return dataclasses.replace(self.inner.estimate(*a, **k),
                                   wire_s=0.0)


def test_coupled_refetch_raises_tpot_in_kv_constrained_pool():
    """Acceptance: with preemption re-fetch charged, a KV-constrained
    decode pool shows strictly higher TPOT p95 than the free-re-fetch
    baseline — in the coupled two-pool simulation, not just the module.

    Scenario built for determinism: two requests exactly fill the decode
    pool, decode growth evicts the younger one, the short request drains
    the pool, and the victim's re-admission is gated only by the re-fetch
    over a deliberately slow cross-pool link.
    """
    model = small_model()
    pre_c = h100_node(2)
    schemes = generate_disagg_schemes(
        model, prefill_cluster=pre_c, decode_cluster=h100_node(2),
        max_plans=100000)
    scheme = next(s for s in schemes
                  if s.prefill.model_dp == 1 and s.decode.model_dp == 1
                  and s.prefill.pp_stages == 1 and s.decode.pp_stages == 1)
    # decode-pool HBM sized so capacity == both prompts + admission
    # headroom: the first decode iterations overflow it
    ctx = 600
    cap_target = 2 * (ctx + 1) + 2
    per_tok = scheme.decode.kv_bytes_per_token_per_device()
    need = (scheme.decode.weight_bytes_per_device()
            + scheme.decode.state_bytes_per_seq_per_device() * 512
            + cap_target * per_tok)
    small_dev = dataclasses.replace(h100_node(2).device, name="H100-tiny",
                                    hbm_bytes=need / 0.85)
    dec_c = dataclasses.replace(h100_node(2), device=small_dev,
                                name="h100tiny x2")
    assert abs(scheme.decode.kv_token_capacity(dec_c.device.hbm_bytes)
               - cap_target) <= 1

    slow_wan = NetworkLevel("wan", 4, 1e9, 1e-4, launch_s=5e-5)
    plan = map_disagg_scheme(scheme, prefill_cluster=pre_c,
                             decode_cluster=dec_c, cross_level=slow_wan)
    # gen 50 amortizes the short request's handoff delay so the VICTIM'S
    # TPOT is the p95 in both runs
    reqs = [Request(rid=0, arrival=0.0, context_len=ctx, gen_len=50),
            Request(rid=1, arrival=0.0, context_len=ctx, gen_len=400)]

    def run(free: bool):
        sim = DisaggSimulator(plan, ProfileStore(AnalyticBackend(pre_c)),
                              CollectiveModel(pre_c))
        if free:
            sim.kv = _FreeRefetchKV(sim.kv)
        # delay-mode re-fetch (the model this regression test pins);
        # the engine-coupled default — re-prefill occupancy + shared-link
        # queuing — is covered by tests/test_engine_golden.py
        return sim.simulate(reqs, keep_records=True, congestion=False,
                            reprefill_occupancy=False)

    paid, free = run(False), run(True)
    assert paid.feasible and free.feasible
    assert paid.preemptions > 0 and free.preemptions > 0
    victim = next(r for r in paid.records if r.preemptions > 0)
    assert victim.refetch_s > 0.0      # the merge carries the charge
    assert paid.tpot_p95 > free.tpot_p95
    assert paid.e2e_latency > free.e2e_latency


def test_joint_search_ranks_both_families():
    model = small_model()
    cluster = h100_node(8)
    reqs = get_trace("chat", arrival_rate=4.0, seed=0, num_requests=32)
    search = ApexSearch(model, cluster)
    res = search.search(reqs, objective="ttft", feasible_only=True,
                        disaggregated=True, max_disagg_plans=64)
    labels = [r.plan_label for r in res.all_reports]
    assert any(l.startswith("disagg[") for l in labels)
    assert any(not l.startswith("disagg[") for l in labels)
    assert res.objective == "ttft"
    # best-by-objective really is the argmin over feasible reports
    feas = [r for r in res.all_reports if r.feasible]
    assert res.best.ttft_p95 == min(OBJECTIVES["ttft"](r) for r in feas)
