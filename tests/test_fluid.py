"""Fluid-limit surrogate (core/fluid.py): report sanity, infeasibility
agreement with the exact simulator, and the screening property the
multi-fidelity search relies on — the exact search's winner survives the
default surrogate frontier, at several seeded (model, trace) points and
for more than one objective."""

import dataclasses

import pytest

from repro.core import (ApexSearch, BatchingPolicy, FluidSimulator,
                        MultiFidelitySearch, TraceSummary, get_trace,
                        h100_node, h200_node, ir_from_hf_config, map_scheme)
from repro.core.fluid import FluidDisaggSimulator
from repro.core.search import OBJECTIVES

SMALL = dict(hidden_size=256, num_hidden_layers=4, num_attention_heads=8,
             num_key_value_heads=4, intermediate_size=1024, vocab_size=1024)
MEDIUM = dict(hidden_size=512, num_hidden_layers=8, num_attention_heads=8,
              num_key_value_heads=4, intermediate_size=2048, vocab_size=4096)


def small_model(name="tiny"):
    return ir_from_hf_config(SMALL, name=name)


def medium_model(name="tiny8"):
    return ir_from_hf_config(MEDIUM, name=name)


def _fluid_sim(model, cluster):
    search = ApexSearch(model, cluster)
    cands, _ = search.candidates(feasible_only=True)
    plan, sim = search.make_simulator(cands[0], fluid=True)
    return plan, sim


# ---------------------------------------------------------------------------
# surrogate report sanity
# ---------------------------------------------------------------------------

def test_fluid_report_is_sane():
    plan, sim = _fluid_sim(small_model(), h100_node(4))
    reqs = get_trace("chat", arrival_rate=2.0, seed=0, num_requests=32)
    rep = sim.simulate(reqs)
    assert rep.feasible
    assert rep.plan_label == plan.scheme.label()
    assert rep.e2e_latency > 0
    assert rep.ttft_mean > 0
    assert rep.ttft_p95 >= rep.ttft_mean
    assert rep.tpot_mean > 0
    assert rep.throughput_tok_s > 0
    assert rep.total_energy > 0
    assert 1 <= rep.peak_batch <= 512
    # counters come from the probe StepCostCache
    assert sim.cache_stats["misses"] > 0


def test_fluid_tracks_exact_scale():
    """Surrogate means land within a small factor of the exact engine's
    (it is a screening model, not a clone — but the scale must match)."""
    model = small_model()
    cluster = h100_node(4)
    search = ApexSearch(model, cluster)
    cands, _ = search.candidates(feasible_only=True)
    reqs = get_trace("chat", arrival_rate=4.0, seed=3, num_requests=32)
    _, fluid = search.make_simulator(cands[0], fluid=True)
    _, exact = search.make_simulator(cands[0])
    fr = fluid.simulate(reqs)
    er = exact.simulate(reqs)
    assert fr.e2e_latency == pytest.approx(er.e2e_latency, rel=0.5)
    assert fr.throughput_tok_s == pytest.approx(er.throughput_tok_s,
                                                rel=0.5)


def test_fluid_infeasible_when_kv_capacity_zero():
    """A scheme whose weights leave no KV room is infeasible at BOTH
    fidelities (same kv_token_capacity gate)."""
    big = ir_from_hf_config(
        dict(hidden_size=8192, num_hidden_layers=80,
             num_attention_heads=64, num_key_value_heads=8,
             intermediate_size=28672, vocab_size=128256), name="big")
    from repro.core import generate_schemes
    cluster = h100_node(1)
    schemes = [s for s in generate_schemes(big, 1)]
    plan = map_scheme(schemes[0], cluster)
    search = ApexSearch(big, cluster)
    sim = FluidSimulator(plan, search.store, search.coll)
    reqs = get_trace("chat", arrival_rate=2.0, seed=0, num_requests=8)
    rep = sim.simulate(reqs)
    assert not rep.feasible


def test_fluid_static_disagg_infeasible():
    """Static batching has no meaningful decode pool — the fluid disagg
    surrogate mirrors the exact simulator's infeasible verdict."""
    model = small_model()
    search = ApexSearch(model, h100_node(4))
    cands, kv = search.candidates(feasible_only=True, disaggregated=True,
                                  max_disagg_plans=4)
    dis = [c for c in cands if c[0] == "disagg"][0]
    _, sim = search.make_simulator(dis, kv, fluid=True)
    reqs = get_trace("chat", arrival_rate=2.0, seed=0, num_requests=8)
    rep = sim.simulate(reqs, policy=BatchingPolicy(mode="static"))
    assert not rep.feasible


def test_trace_summary_moments():
    reqs = get_trace("chat", arrival_rate=2.0, seed=0, num_requests=64)
    ts = TraceSummary.of(reqs)
    assert ts.n == 64
    assert ts.span_s == max(r.arrival for r in reqs)
    assert ts.ctx_mean == pytest.approx(
        sum(r.context_len for r in reqs) / 64)
    assert ts.ctx_p95 >= ts.ctx_mean
    assert ts.gen_p95 >= ts.gen_mean
    # summary short-circuits recomputation: same report either way
    plan, sim = _fluid_sim(small_model(), h100_node(4))
    assert sim.simulate(reqs, summary=ts) == sim.simulate(reqs)


def test_fluid_much_faster_than_exact():
    import time
    model = medium_model()
    cluster = h100_node(8)
    search = ApexSearch(model, cluster)
    cands, _ = search.candidates(feasible_only=True)
    reqs = get_trace("summarization", arrival_rate=8.0, seed=0,
                     num_requests=48)
    _, exact = search.make_simulator(cands[0])
    t0 = time.perf_counter()
    exact.simulate(reqs)
    t_exact = time.perf_counter() - t0
    _, fluid = search.make_simulator(cands[0], fluid=True)
    t0 = time.perf_counter()
    fluid.simulate(reqs)
    t_fluid = time.perf_counter() - t0
    assert t_fluid < t_exact


# ---------------------------------------------------------------------------
# the screening property: exact winners survive the default frontier
# ---------------------------------------------------------------------------

def _containment_point(model, cluster, reqs, objective, **kw):
    search = ApexSearch(model, cluster)
    exact = search.search(reqs, objective=objective, **kw)
    mf = MultiFidelitySearch(search)
    mres = mf.search(reqs, objective=objective, **kw)
    survivors = {mres.surrogate_reports[i].plan_label
                 for i in mres.survivor_indices}
    assert exact.best.plan_label in survivors, (
        f"exact best {exact.best.plan_label} not among "
        f"{mres.num_survivors} survivors of {mres.num_candidates}")
    # with the winner in the frontier, exact confirmation must agree on
    # the objective value (label may differ only on exact ties)
    key = OBJECTIVES[objective]
    assert key(mres.best) == pytest.approx(key(exact.best), rel=1e-9)
    return mres


@pytest.mark.parametrize("objective", ["latency", "throughput"])
def test_exact_best_survives_light_load(objective):
    """Seeded point 1: small model, light chat load, colocated."""
    reqs = get_trace("chat", arrival_rate=2.0, seed=0, num_requests=32)
    _containment_point(small_model(), h100_node(4), reqs, objective,
                       feasible_only=True)


@pytest.mark.parametrize("objective", ["latency", "throughput"])
def test_exact_best_survives_heavy_load(objective):
    """Seeded point 2: deeper model, bursty summarization load."""
    reqs = get_trace("summarization", arrival_rate=100.0, seed=7,
                     num_requests=40)
    _containment_point(medium_model(), h100_node(8), reqs, objective,
                       feasible_only=True)


@pytest.mark.parametrize("objective", ["latency", "throughput"])
def test_exact_best_survives_joint_disagg(objective):
    """Seeded point 3: joint colocated + heterogeneous-pool disagg."""
    reqs = get_trace("creation", arrival_rate=4.0, seed=11,
                     num_requests=24)
    mres = _containment_point(
        small_model(), h100_node(8), reqs, objective,
        feasible_only=True, disaggregated=True, max_disagg_plans=24,
        pool_menu=[h100_node(4), h200_node(4)])
    assert mres.num_candidates > mres.result.num_feasible >= 1
