"""Fault injection, graceful degradation, and resilience-aware search.

Covers the four contracts the fault subsystem makes:

  * DETERMINISM — a seeded ``FaultSchedule`` replays bit-identically,
    and an EMPTY schedule is bit-identical to the frozen pre-fault
    goldens (tests/golden/core_golden.json), colocated AND disagg: the
    fault machinery is provably inert when no fault fires.
  * DEGRADATION — killing a replica mid-trace re-queues its in-flight
    work to survivors (KV lost, recompute path), hurts the latency tail,
    and is accounted in the ``ResilienceReport``.
  * ISOLATION — step costs priced under a degraded cluster state live in
    their own ``SharedCostStore`` bucket; a degraded-link run can never
    reuse (or pollute) a healthy-state cost entry.
  * SEARCH — ``objective="degraded_goodput"`` re-simulates candidates
    under the ensemble, identically serial or forked; the multi-fidelity
    ladder screens fault-free and pays for faults only at final confirm;
    bad inputs raise ``ValueError`` early; a crash inside a candidate
    evaluation names the candidate (``PlanEvaluationError``).
"""

import dataclasses
import json
import os

import pytest

from repro.core import (ApexSearch, CollectiveModel, FaultSchedule,
                        LinkDegradation, MultiFidelitySearch,
                        PlanEvaluationError, ProfileStore, ReplicaFault,
                        SharedCostStore, Straggler, fault_ensemble,
                        fork_map, generate_schemes, get_trace, h100_node,
                        ir_from_hf_config, map_scheme, normalize_faults)
from repro.core.batching import BatchingPolicy
from repro.core.profiles import AnalyticBackend
from repro.core.simulator import PlanSimulator
from repro.disagg import DisaggSimulator, generate_disagg_schemes, \
    map_disagg_scheme

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "core_golden.json")
SMALL = dict(hidden_size=256, num_hidden_layers=4, num_attention_heads=8,
             num_key_value_heads=4, intermediate_size=1024, vocab_size=1024)

POLICIES = {
    "continuous": BatchingPolicy(),
    "chunked": BatchingPolicy(chunked_prefill=128),
    "static": BatchingPolicy(mode="static", max_batch_size=8),
    "capped": BatchingPolicy(max_batch_size=4, fast_forward=False),
}


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def ctx():
    model = ir_from_hf_config(SMALL, name="tiny")
    cluster = h100_node(8)
    return model, cluster, ProfileStore(AnalyticBackend(cluster)), \
        CollectiveModel(cluster)


def _colocated_plan(model, cluster, dp):
    scheme = next(s for s in generate_schemes(model, 8, quant="fp16")
                  if s.model_dp == dp and s.pp_stages == 1
                  and s.is_feasible_for_current_systems())
    return map_scheme(scheme, cluster)


def _disagg_plan(model, cluster, mode="layerwise"):
    scheme = next(
        s for s in generate_disagg_schemes(model, cluster,
                                           max_plans=100000,
                                           transfer_mode=mode)
        if s.prefill_devices == 4 and s.decode_devices == 4
        and s.prefill.model_dp == 1 and s.decode.model_dp == 1
        and s.prefill.pp_stages == 1 and s.decode.pp_stages == 1)
    return map_disagg_scheme(scheme, cluster)


def _assert_report_matches(rep, want):
    for field, expect in want.items():
        if field == "records":
            got = sorted((r.rid, r.first_token_time, r.finish_time,
                          r.preemptions, r.refetch_s) for r in rep.records)
            assert got == [tuple(r) for r in expect]
        else:
            assert getattr(rep, field) == expect, field


# ---------------------------------------------------------------------------
# determinism: empty schedule == frozen goldens; same seed == same bits
# ---------------------------------------------------------------------------

def test_empty_schedule_matches_colocated_goldens_exactly(golden, ctx):
    """faults=FaultSchedule() must be invisible: every frozen colocated
    golden case reproduces bit for bit with the (empty) schedule
    threaded through the whole fault plumbing."""
    model, cluster, store, coll = ctx
    plans = {dp: _colocated_plan(model, cluster, dp) for dp in (1, 2)}
    empty = FaultSchedule()
    assert empty.empty and empty.cost_key() == ()
    for case in golden["colocated"]:
        reqs = get_trace(case["trace"], arrival_rate=case["rate"], seed=11,
                         num_requests=48)
        sim = PlanSimulator(plans[case["dp"]], store, coll)
        rep = sim.simulate(reqs, policy=POLICIES[case["policy"]],
                           keep_records=True, faults=empty)
        _assert_report_matches(rep, case["report"])
        assert rep.resilience is None


def test_empty_schedule_matches_disagg_goldens_exactly(golden, ctx):
    model, cluster, store, coll = ctx
    for case in golden["disagg"]:
        plan = _disagg_plan(model, cluster, case["mode"])
        reqs = get_trace(case["trace"], arrival_rate=case["rate"], seed=11,
                         num_requests=48)
        sim = DisaggSimulator(plan, store, coll)
        rep = sim.simulate(reqs, keep_records=True, congestion=False,
                           reprefill_occupancy=False,
                           faults=FaultSchedule())
        _assert_report_matches(rep, case["report"])
        assert rep.resilience is None


def test_seeded_schedule_is_deterministic(ctx):
    """Same seed -> same schedule -> bit-identical faulted reports,
    including every ``ResilienceReport`` field."""
    model, cluster, store, coll = ctx
    plan = _colocated_plan(model, cluster, 2)
    reqs = get_trace("summarization", arrival_rate=4.0, seed=3,
                     num_requests=32)
    assert FaultSchedule.sample(7, 30.0, 2, replica_mtbf_s=10.0) == \
        FaultSchedule.sample(7, 30.0, 2, replica_mtbf_s=10.0)
    sched = FaultSchedule.sample(7, 30.0, 2, replica_mtbf_s=10.0,
                                 straggler_mtbf_s=20.0)
    assert not sched.empty
    reps = [PlanSimulator(plan, store, coll).simulate(reqs, faults=sched)
            for _ in range(2)]
    assert reps[0].resilience is not None
    assert dataclasses.asdict(reps[0]) == dataclasses.asdict(reps[1])


# ---------------------------------------------------------------------------
# graceful degradation
# ---------------------------------------------------------------------------

def test_replica_failure_requeues_to_survivors(ctx):
    """Kill replica 0 of a DP-2 plan mid-trace: its in-flight requests
    lose their KV and re-queue to the survivor (preemption path), the
    TTFT tail degrades, nothing is dropped, and the report says so."""
    model, cluster, store, coll = ctx
    plan = _colocated_plan(model, cluster, 2)
    # arrivals every ~15ms against ~35ms of service keep both replicas
    # busy through the burst, so the kill catches in-flight work
    reqs = get_trace("summarization", arrival_rate=64.0, seed=3,
                     num_requests=32)
    sim = PlanSimulator(plan, store, coll)
    nominal = sim.simulate(reqs)
    kill = FaultSchedule(replica_faults=(
        ReplicaFault(replica=0, start=0.15, repair=1.0, pool="serve"),))
    faulted = sim.simulate(reqs, faults=kill)
    res = faulted.resilience
    assert res is not None
    assert res.requests_requeued > 0
    assert faulted.preemptions >= nominal.preemptions + res.requests_requeued
    assert res.requests_dropped == 0
    assert res.requests_finished == len(reqs)
    assert res.availability < 1.0
    assert res.degraded_seconds > 0.0
    assert faulted.ttft_p95 >= nominal.ttft_p95
    assert res.goodput_rps <= nominal.goodput_rps + 1e-12


def test_unrepaired_failure_of_sole_replica_drops_requests(ctx):
    """A dp=1 plan losing its only replica forever cannot finish the
    queued work — the report must say DROPPED, not hang or lie."""
    model, cluster, store, coll = ctx
    plan = _colocated_plan(model, cluster, 1)
    reqs = get_trace("summarization", arrival_rate=4.0, seed=3,
                     num_requests=32)
    sim = PlanSimulator(plan, store, coll)
    rep = sim.simulate(reqs, faults=FaultSchedule(replica_faults=(
        ReplicaFault(replica=0, start=1.0),)))
    assert rep.resilience.requests_dropped > 0
    assert rep.resilience.requests_finished < len(reqs)


def test_straggler_slows_without_polluting_costs(ctx):
    """A straggler window raises e2e/energy; the step-cost scale is
    applied after the cache lookup, so a subsequent fault-free run on
    the SAME simulator still matches its own baseline bit for bit."""
    model, cluster, store, coll = ctx
    plan = _colocated_plan(model, cluster, 1)
    reqs = get_trace("summarization", arrival_rate=4.0, seed=3,
                     num_requests=24)
    sim = PlanSimulator(plan, store, coll)
    before = sim.simulate(reqs)
    slow = sim.simulate(reqs, faults=FaultSchedule(stragglers=(
        Straggler(replica=0, start=0.0, end=1e9, slowdown=3.0),)))
    after = sim.simulate(reqs)
    assert slow.e2e_latency > before.e2e_latency
    assert slow.total_energy > before.total_energy
    assert dataclasses.asdict(before) == dataclasses.asdict(after)


def test_staged_disagg_mode_rejects_faults(ctx):
    """reprefill_occupancy=False runs the pools as two staged engines —
    there is no coupled timeline to inject into, so a non-empty
    schedule must be rejected loudly rather than half-applied."""
    model, cluster, store, coll = ctx
    plan = _disagg_plan(model, cluster)
    reqs = get_trace("summarization", arrival_rate=4.0, seed=3,
                     num_requests=16)
    sim = DisaggSimulator(plan, store, coll)
    with pytest.raises(ValueError, match="reprefill_occupancy"):
        sim.simulate(reqs, reprefill_occupancy=False,
                     faults=FaultSchedule(replica_faults=(
                         ReplicaFault(replica=0, start=1.0, repair=2.0,
                                      pool="decode"),)))


# ---------------------------------------------------------------------------
# cost-store isolation (adversarial)
# ---------------------------------------------------------------------------

def test_degraded_state_never_reuses_healthy_cost_entries(ctx):
    """Adversarial: a link-degraded disagg run and a straggler-degraded
    colocated run must open NEW SharedCostStore buckets (fingerprint
    carries the fault key), leaving every healthy bucket untouched —
    even though the degraded runs price the very same workloads."""
    model, cluster, _, _ = ctx
    store = ProfileStore(AnalyticBackend(cluster))
    coll = CollectiveModel(cluster)
    cost_store = SharedCostStore()
    reqs = get_trace("summarization", arrival_rate=4.0, seed=3,
                     num_requests=16)

    plan = _disagg_plan(model, cluster)
    sim = DisaggSimulator(plan, store, coll, cost_store=cost_store)
    sim.simulate(reqs)
    healthy_keys = set(cost_store.tables)
    healthy_sizes = {k: len(t) for k, t in cost_store.tables.items()}

    def has_fault_marker(key):
        return any(isinstance(el, tuple) and el[:1] == ("faults",)
                   for el in key)

    assert healthy_keys and not any(map(has_fault_marker, healthy_keys))

    degr = FaultSchedule(link_faults=(
        LinkDegradation(start=0.0, end=1e9, factor=8.0),))
    sim.simulate(reqs, faults=degr)
    new_keys = set(cost_store.tables) - healthy_keys
    assert new_keys, "degraded run must not share a healthy bucket"
    assert all(has_fault_marker(key) for key in new_keys)
    assert all(("faults",) + degr.cost_key() in key for key in new_keys)
    # healthy buckets neither grew nor shrank: zero cross-pollution
    assert {k: len(cost_store.tables[k]) for k in healthy_keys} == \
        healthy_sizes

    cplan = _colocated_plan(model, cluster, 1)
    csim = PlanSimulator(cplan, store, coll, cost_store=cost_store)
    csim.simulate(reqs)
    base_keys = set(cost_store.tables)
    csim.simulate(reqs, faults=FaultSchedule(stragglers=(
        Straggler(replica=0, start=0.0, end=1e9, slowdown=2.0),)))
    assert set(cost_store.tables) - base_keys, \
        "straggler run must open its own bucket"


def test_distinct_schedules_get_distinct_buckets():
    a = FaultSchedule(link_faults=(LinkDegradation(0.0, 5.0, 4.0),))
    b = FaultSchedule(link_faults=(LinkDegradation(0.0, 5.0, 8.0),))
    assert a.cost_key() != b.cost_key()
    assert FaultSchedule().cost_key() == ()


# ---------------------------------------------------------------------------
# resilience-aware search
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def search_ctx():
    model = ir_from_hf_config(SMALL, name="tiny")
    reqs = get_trace("summarization", arrival_rate=4.0, seed=3,
                     num_requests=24)
    ens = fault_ensemble(11, 2, horizon_s=10.0, n_replicas=2,
                         pool="serve", replica_mtbf_s=6.0,
                         replica_mttr_s=4.0)
    return model, reqs, ens


def test_degraded_goodput_search_serial_equals_forked(search_ctx):
    model, reqs, ens = search_ctx
    r1 = ApexSearch(model, h100_node(8)).search(
        reqs, objective="degraded_goodput", faults=ens, max_model_dp=2)
    r2 = ApexSearch(model, h100_node(8)).search(
        reqs, objective="degraded_goodput", faults=ens, max_model_dp=2,
        jobs=2)
    assert dataclasses.asdict(r1.best) == dataclasses.asdict(r2.best)
    assert [dataclasses.asdict(r) for r in r1.all_reports] == \
        [dataclasses.asdict(r) for r in r2.all_reports]
    assert all(r.resilience is not None and
               r.resilience.ensemble_size == len(ens)
               for r in r1.all_reports if r.feasible)


def test_multifid_faults_confirm_only(search_ctx):
    """Screening and rungs stay fault-free; only confirmed finalists
    carry resilience — and the winner agrees with the exact search."""
    model, reqs, ens = search_ctx
    exact = ApexSearch(model, h100_node(8)).search(
        reqs, objective="degraded_goodput", faults=ens, max_model_dp=2)
    mres = MultiFidelitySearch(ApexSearch(model, h100_node(8)),
                               frontier_k=4).search(
        reqs, objective="degraded_goodput", faults=ens, max_model_dp=2)
    assert all(r.resilience is None for r in mres.surrogate_reports)
    assert all(r.resilience is not None
               for r in mres.result.all_reports if r.feasible)
    assert mres.best.plan_label == exact.best.plan_label


# ---------------------------------------------------------------------------
# input validation
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError):
        ReplicaFault(replica=-1, start=0.0, repair=1.0)
    with pytest.raises(ValueError):
        ReplicaFault(replica=0, start=5.0, repair=5.0)
    with pytest.raises(ValueError):
        LinkDegradation(start=0.0, end=1.0, factor=0.5)
    with pytest.raises(ValueError):
        Straggler(replica=0, start=0.0, end=1.0, slowdown=0.9)
    with pytest.raises(ValueError):
        FaultSchedule(throttle=0.0)
    with pytest.raises(ValueError):
        fault_ensemble(1, 0, horizon_s=10.0, n_replicas=2)
    with pytest.raises(TypeError):
        normalize_faults(["not a schedule"])
    assert normalize_faults(None) == ()
    assert normalize_faults(FaultSchedule()) == ()


def test_search_validation(search_ctx):
    model, reqs, ens = search_ctx
    s = ApexSearch(model, h100_node(8))
    with pytest.raises(ValueError, match="unknown objective"):
        s.search(reqs, objective="nope")
    with pytest.raises(ValueError, match="jobs"):
        s.search(reqs, jobs=-1)
    with pytest.raises(ValueError, match="degraded_goodput"):
        s.search(reqs, objective="degraded_goodput")
    with pytest.raises(ValueError, match="frontier_k"):
        MultiFidelitySearch(s, frontier_k=0)
    with pytest.raises(ValueError, match="strictly increasing"):
        MultiFidelitySearch(s, rungs=(0.5, 0.25))
    with pytest.raises(ValueError, match="rung fractions"):
        MultiFidelitySearch(s, rungs=(0.25, 1.5))
    mf = MultiFidelitySearch(s)
    with pytest.raises(ValueError, match="degraded_goodput"):
        mf.search(reqs, objective="degraded_goodput")
    with pytest.raises(ValueError, match="jobs"):
        mf.search(reqs, jobs=-2)


# ---------------------------------------------------------------------------
# fork_map failure identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("jobs", [1, 2])
def test_fork_map_names_the_failing_candidate(jobs):
    def boom(i):
        if i == 2:
            raise RuntimeError("kaput")
        return i

    with pytest.raises(PlanEvaluationError) as exc:
        fork_map(boom, 5, jobs, label=lambda i: f"plan-{i}")
    assert exc.value.index == 2
    assert exc.value.label == "plan-2"
    assert "kaput" in str(exc.value)
    # healthy runs are unaffected
    assert fork_map(lambda i: i * i, 4, jobs) == [0, 1, 4, 9]
