"""Golden-report capture for the event-engine refactor (PR 3).

Run ONCE against the pre-refactor per-replica loops (BatchingModule's
``_run_continuous``/``_run_static`` and DisaggSimulator's coupled two-pool
dance) to freeze their numbers; the engine-backed rewrite must reproduce
them exactly (tests/test_engine_golden.py).  The legacy loops are deleted
by the refactor, so this script cannot regenerate the goldens afterwards —
the JSON is a frozen artifact of commit ef964aa.

    PYTHONPATH=src python tests/golden/capture.py
"""

import json
import os

from repro.core import (BatchingPolicy, CollectiveModel, ProfileStore,
                        generate_schemes, get_trace, h100_node,
                        ir_from_hf_config, map_scheme)
from repro.core.profiles import AnalyticBackend
from repro.core.simulator import PlanSimulator
from repro.disagg import DisaggSimulator, generate_disagg_schemes, \
    map_disagg_scheme

SMALL = dict(hidden_size=256, num_hidden_layers=4, num_attention_heads=8,
             num_key_value_heads=4, intermediate_size=1024, vocab_size=1024)

TRACES = [("summarization", 2.0), ("creation", 1.0), ("chat", 4.0)]
N_REQ = 48

REPORT_FIELDS = [
    "plan_label", "e2e_latency", "total_energy", "ttft_mean", "ttft_p95",
    "tpot_mean", "tpot_p95", "latency_p95", "throughput_tok_s", "mfu",
    "mbu", "iterations", "preemptions", "peak_kv_tokens", "peak_batch",
    "feasible",
]


def report_dict(rep):
    d = {f: getattr(rep, f) for f in REPORT_FIELDS}
    d["records"] = sorted(
        (r.rid, r.first_token_time, r.finish_time, r.preemptions,
         r.refetch_s) for r in rep.records)
    return d


def colocated_scheme(model, dp):
    for s in generate_schemes(model, 8, quant="fp16"):
        if (s.model_dp == dp and s.pp_stages == 1
                and s.is_feasible_for_current_systems()):
            return s
    raise RuntimeError("no scheme")


def disagg_scheme(model, cluster, mode):
    for s in generate_disagg_schemes(model, cluster, max_plans=100000,
                                     transfer_mode=mode):
        if (s.prefill_devices == 4 and s.decode_devices == 4
                and s.prefill.model_dp == 1 and s.decode.model_dp == 1
                and s.prefill.pp_stages == 1 and s.decode.pp_stages == 1):
            return s
    raise RuntimeError("no disagg scheme")


def main():
    model = ir_from_hf_config(SMALL, name="tiny")
    cluster = h100_node(8)
    store = ProfileStore(AnalyticBackend(cluster))
    coll = CollectiveModel(cluster)
    out = {"colocated": [], "disagg": []}

    policies = {
        "continuous": BatchingPolicy(),
        "chunked": BatchingPolicy(chunked_prefill=128),
        "static": BatchingPolicy(mode="static", max_batch_size=8),
        "capped": BatchingPolicy(max_batch_size=4, fast_forward=False),
    }
    for dp in (1, 2):
        scheme = colocated_scheme(model, dp)
        plan = map_scheme(scheme, cluster)
        for pname, pol in policies.items():
            for trace, rate in TRACES:
                reqs = get_trace(trace, arrival_rate=rate, seed=11,
                                 num_requests=N_REQ)
                sim = PlanSimulator(plan, store, coll)
                rep = sim.simulate(reqs, policy=pol, keep_records=True)
                out["colocated"].append(
                    {"dp": dp, "policy": pname, "trace": trace,
                     "rate": rate, "report": report_dict(rep)})

    for mode in ("layerwise", "blocking"):
        scheme = disagg_scheme(model, cluster, mode)
        plan = map_disagg_scheme(scheme, cluster)
        for trace, rate in TRACES:
            reqs = get_trace(trace, arrival_rate=rate, seed=11,
                             num_requests=N_REQ)
            sim = DisaggSimulator(plan, store, coll)
            rep = sim.simulate(reqs, keep_records=True)
            out["disagg"].append(
                {"mode": mode, "trace": trace, "rate": rate,
                 "report": report_dict(rep)})

    path = os.path.join(os.path.dirname(__file__), "core_golden.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"wrote {path}: {len(out['colocated'])} colocated + "
          f"{len(out['disagg'])} disagg reports")


if __name__ == "__main__":
    main()
