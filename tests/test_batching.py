"""Property-based tests for the dynamism-aware Batching Module (§3.3)."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.batching import BatchingModule, BatchingPolicy
from repro.core.trace import Request


def const_cost(per_token=1e-3, per_iter=5e-3):
    def step_cost(w):
        t = per_iter + per_token * w.total_tokens
        return t, t * 100.0
    return step_cost


def mk_requests(specs):
    return [Request(rid=i, arrival=a, context_len=c, gen_len=g)
            for i, (a, c, g) in enumerate(specs)]


@given(st.lists(st.tuples(st.floats(0, 10), st.integers(1, 50),
                          st.integers(1, 30)),
                min_size=1, max_size=12))
@settings(max_examples=25, deadline=None)
def test_all_requests_complete(specs):
    reqs = mk_requests(specs)
    mod = BatchingModule(kv_capacity_tokens=100000,
                         policy=BatchingPolicy())
    res = mod.run(reqs, const_cost())
    assert len(res.records) == len(reqs)
    for r in res.records:
        assert r.finish_time >= r.first_token_time >= r.arrival
        assert r.finish_time <= res.total_time + 1e-9


@given(st.integers(60, 200))
@settings(max_examples=10, deadline=None)
def test_capacity_respected_via_preemption(cap):
    # requests that jointly exceed capacity force preemptions; peak KV
    # never exceeds capacity EXCEPT when a single request alone does
    # (the last active sequence is never evicted)
    reqs = mk_requests([(0.0, 40, 60), (0.0, 40, 60), (0.0, 40, 60)])
    mod = BatchingModule(kv_capacity_tokens=cap, policy=BatchingPolicy())
    res = mod.run(reqs, const_cost())
    assert len(res.records) == 3
    single_max = 40 + 60
    assert res.peak_kv_tokens <= max(cap + 3, single_max)


def test_preemption_occurs_at_mid_capacity():
    """Greedy batching over-admits (no reservation for future generated
    tokens — paper §3.3) and must preempt the most recent request."""
    reqs = mk_requests([(0.0, 40, 60), (0.0, 40, 60), (0.0, 40, 60)])
    res = BatchingModule(102, BatchingPolicy()).run(reqs, const_cost())
    assert res.preemptions > 0
    assert len(res.records) == 3
    assert res.peak_kv_tokens <= 102 + 3


def test_fast_forward_matches_exact():
    reqs = mk_requests([(0.0, 20, 40), (0.5, 10, 80), (3.0, 30, 25)])
    fast = BatchingModule(10000, BatchingPolicy(fast_forward=True)).run(
        reqs, const_cost())
    slow = BatchingModule(10000, BatchingPolicy(fast_forward=False)).run(
        reqs, const_cost())
    assert abs(fast.total_time - slow.total_time) / slow.total_time < 0.02
    assert fast.iterations == slow.iterations


def test_static_batching_slower_than_continuous():
    """The paper's §2.3 motivation: static batching wastes time waiting
    for the longest generation."""
    specs = [(i * 0.01, 10, 5 + 45 * (i % 2)) for i in range(8)]
    reqs = mk_requests(specs)
    cont = BatchingModule(10000, BatchingPolicy(mode="continuous")).run(
        reqs, const_cost())
    stat = BatchingModule(10000, BatchingPolicy(
        mode="static", max_batch_size=8)).run(reqs, const_cost())
    assert stat.total_time >= cont.total_time * 0.999


def test_chunked_prefill_bounds_prefill_tokens():
    """Sarathi-style chunked prefill (paper §4.5 extension)."""
    seen = []

    def spy_cost(w):
        seen.append(w.prefill_tokens)
        t = 1e-3 * max(w.total_tokens, 1)
        return t, t

    reqs = mk_requests([(0.0, 500, 10), (0.0, 300, 10)])
    BatchingModule(10000, BatchingPolicy(chunked_prefill=128)).run(
        reqs, spy_cost)
    assert max(seen) <= 2 * 128            # <= chunk per prefill request


def test_chunked_prefill_mixes_decodes():
    mixed = []

    def spy_cost(w):
        if w.prefill_tokens and w.decode_tokens:
            mixed.append(True)
        t = 1e-3 * max(w.total_tokens, 1)
        return t, t

    reqs = mk_requests([(0.0, 50, 500), (0.05, 600, 10)])
    BatchingModule(10000, BatchingPolicy(chunked_prefill=64)).run(
        reqs, spy_cost)
    assert mixed  # decode requests ride along with prefill chunks


@given(st.integers(1, 6))
@settings(max_examples=6, deadline=None)
def test_max_batch_cap(cap):
    reqs = mk_requests([(0.0, 10, 20)] * 10)
    res = BatchingModule(100000, BatchingPolicy(max_batch_size=cap)).run(
        reqs, const_cost())
    assert res.peak_batch <= cap


def test_windowed_workload_aggregates():
    """Window-resolved attention accounting is exact."""
    from repro.core.ir import Workload, _window_area
    chunks = [(16, 16), (8, 24)]
    decode = [100, 5, 33]
    w = Workload.from_batch(chunks, decode, model_windows=(None, 7))
    assert w.prefill_qk(None) == sum(_window_area(q, kv, None)
                                     for q, kv in chunks)
    assert w.prefill_qk(7) == sum(_window_area(q, kv, 7)
                                  for q, kv in chunks)
    assert w.decode_kv(None) == sum(decode)
    assert w.decode_kv(7) == sum(min(k, 7) for k in decode)
    # window area closed form vs brute force
    for q_len, kv_end, wnd in [(5, 9, 3), (4, 4, None), (7, 30, 10)]:
        brute = sum(min(p + 1, wnd if wnd else p + 1)
                    for p in range(kv_end - q_len, kv_end))
        assert _window_area(q_len, kv_end, wnd) == brute
