"""Import-rot guard for examples/ (and the benchmark registry).

Examples are executable scripts, so importing them outright would RUN
them.  Instead we parse each file and resolve its module-level imports:
every ``import x`` / ``from x import y`` must point at something that
exists.  This is what CI's example check runs — a renamed symbol in
repro.core breaks here instead of silently rotting the examples.

Third-party optional dependencies (jax on a simulator-only install) skip
rather than fail; anything rooted in ``repro`` must resolve.
"""

import ast
import importlib
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
EXAMPLES = sorted((ROOT / "examples").glob("*.py"))
BENCHMARKS = sorted(p for p in (ROOT / "benchmarks").glob("*.py")
                    if p.name != "common.py")


def _import_or_skip(module: str):
    try:
        return importlib.import_module(module)
    except ModuleNotFoundError as e:
        if e.name and not e.name.split(".")[0] == "repro":
            pytest.skip(f"optional dependency {e.name!r} unavailable")
        raise


def _check_module_imports(path: pathlib.Path):
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in tree.body:           # module level only: lazy imports are
        # allowed to be conditional
        if isinstance(node, ast.Import):
            for alias in node.names:
                _import_or_skip(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:           # package-relative (benchmarks/common)
                continue
            mod = _import_or_skip(node.module)
            for alias in node.names:
                if alias.name == "*":
                    continue
                if not hasattr(mod, alias.name):
                    # "from pkg import submodule" form
                    _import_or_skip(f"{node.module}.{alias.name}")


def test_examples_exist():
    # outside the parametrization: an empty EXAMPLES list would otherwise
    # collect zero tests and pass green on exactly the rot we guard
    assert EXAMPLES, "examples/ directory went missing"
    assert BENCHMARKS, "benchmarks/ directory went missing"


@pytest.mark.parametrize("path", EXAMPLES, ids=lambda p: p.name)
def test_example_imports_resolve(path):
    _check_module_imports(path)


@pytest.mark.parametrize("path", BENCHMARKS, ids=lambda p: p.name)
def test_benchmark_imports_resolve(path):
    _check_module_imports(path)
