"""Multi-fidelity search + the PR's search-infrastructure satellites:
``jobs=N`` parallel evaluation equals serial, numpy-Generator trace
determinism, progress/verbose reporting, and cache counters surfaced in
``SearchResult``."""

import numpy as np
import pytest

from repro.core import (ApexSearch, MultiFidelitySearch, get_trace,
                        h100_node, ir_from_hf_config, synthesize_trace)
from repro.core.search import fork_map
from repro.core.trace import TRACE_SPECS

SMALL = dict(hidden_size=256, num_hidden_layers=4, num_attention_heads=8,
             num_key_value_heads=4, intermediate_size=1024, vocab_size=1024)


def small_model():
    return ir_from_hf_config(SMALL, name="tiny")


def _setup(n_req=24, rate=4.0, seed=0, devices=4):
    search = ApexSearch(small_model(), h100_node(devices))
    reqs = get_trace("chat", arrival_rate=rate, seed=seed,
                     num_requests=n_req)
    return search, reqs


# ---------------------------------------------------------------------------
# parallel evaluation: jobs=N reproduces serial bit-for-bit
# ---------------------------------------------------------------------------

def test_fork_map_matches_serial():
    assert fork_map(lambda i: i * i, 7, 3) == [i * i for i in range(7)]
    assert fork_map(lambda i: i, 0, 4) == []


def test_search_jobs_equals_serial():
    search, reqs = _setup()
    serial = search.search(reqs, feasible_only=True)
    par = search.search(reqs, feasible_only=True, jobs=2)
    assert par.all_reports == serial.all_reports
    assert par.best == serial.best


def test_search_jobs_counter_parity_with_private_caches():
    """With the shared store disabled, two fresh search contexts see the
    same cache traffic whether evaluation is serial or forked (with the
    store ON, a second run on one context legitimately hits the first
    run's entries, so counters are only comparable across fresh
    contexts)."""
    serial_search, reqs = _setup()
    serial_search.cost_store = None
    serial = serial_search.search(reqs, feasible_only=True)
    par_search, _ = _setup()
    par_search.cost_store = None
    par = par_search.search(reqs, feasible_only=True, jobs=2)
    assert par.all_reports == serial.all_reports
    assert (par.cache_hits, par.cache_misses) == \
        (serial.cache_hits, serial.cache_misses)


def test_multifid_jobs_equals_serial():
    search, reqs = _setup()
    mf = MultiFidelitySearch(search)
    serial = mf.search(reqs, feasible_only=True)
    par = mf.search(reqs, feasible_only=True, jobs=2)
    assert par.survivor_indices == serial.survivor_indices
    assert par.result.all_reports == serial.result.all_reports
    assert par.best == serial.best


# ---------------------------------------------------------------------------
# trace synthesis with an explicit numpy Generator
# ---------------------------------------------------------------------------

def test_numpy_generator_traces_identical_across_instances():
    """Two independently seeded Generators — the stand-in for two worker
    processes — produce byte-identical traces."""
    spec = TRACE_SPECS["chat"]
    a = synthesize_trace(spec, arrival_rate=1.0,
                         rng=np.random.default_rng(42))
    b = synthesize_trace(spec, arrival_rate=1.0,
                         rng=np.random.default_rng(42))
    assert a == b
    c = synthesize_trace(spec, arrival_rate=1.0,
                         rng=np.random.default_rng(43))
    assert a != c


def test_numpy_generator_trace_has_spec_moments():
    spec = TRACE_SPECS["chat"]
    reqs = synthesize_trace(spec, arrival_rate=1.0, num_requests=4000,
                            rng=np.random.default_rng(0))
    from repro.core import trace_stats
    stats = trace_stats(reqs)
    assert stats["ctx_mean"] == pytest.approx(spec.ctx_mean, rel=0.15)
    assert stats["gen_mean"] == pytest.approx(spec.gen_mean, rel=0.15)


def test_default_rng_path_unchanged():
    """Passing no rng still uses the seeded random.Random draws."""
    spec = TRACE_SPECS["chat"]
    import random
    a = synthesize_trace(spec, arrival_rate=1.0, seed=5)
    b = synthesize_trace(spec, arrival_rate=1.0,
                         rng=random.Random(5))
    assert a == b


# ---------------------------------------------------------------------------
# progress callbacks + verbose output
# ---------------------------------------------------------------------------

def test_progress_two_and_three_arg():
    search, reqs = _setup(n_req=12)
    seen2, seen3 = [], []
    search.search(reqs, feasible_only=True,
                  progress=lambda done, total: seen2.append((done, total)))
    search.search(reqs, feasible_only=True,
                  progress=lambda done, total, best:
                  seen3.append((done, total, best)))
    total = seen2[-1][1]
    assert [d for d, _ in seen2] == list(range(1, total + 1))
    assert len(seen3) == total
    # once a feasible plan has been seen, the running best is a report
    assert seen3[-1][2] is not None
    assert seen3[-1][2].feasible


def test_verbose_prints_progress(capsys):
    search, reqs = _setup(n_req=12)
    search.search(reqs, feasible_only=True, verbose=True)
    out = capsys.readouterr().out
    assert "[search]" in out
    assert "evaluated" in out and "best=" in out


def test_multifid_verbose_and_progress(capsys):
    search, reqs = _setup(n_req=12)
    mf = MultiFidelitySearch(search)
    calls = []
    mf.search(reqs, feasible_only=True, verbose=True,
              progress=lambda done, total: calls.append((done, total)))
    out = capsys.readouterr().out
    assert "[screen]" in out and "survivors" in out
    assert "[confirm]" in out
    # progress covers the confirmation sweep
    assert calls and calls[-1][0] == calls[-1][1]


# ---------------------------------------------------------------------------
# cache counters in SearchResult
# ---------------------------------------------------------------------------

def test_search_result_has_cache_counters():
    search, reqs = _setup(n_req=16)
    res = search.search(reqs, feasible_only=True)
    assert res.cache_misses > 0
    assert res.cache_hits > 0          # repeated steps within a trace
    mf = MultiFidelitySearch(search)
    mres = mf.search(reqs, feasible_only=True)
    # the screening probes pre-seeded the shared store, so confirmation
    # may be all hits — but the counters must show real cache traffic
    assert mres.result.cache_hits > 0
    assert mres.result.cache_hits + mres.result.cache_misses > 0


# ---------------------------------------------------------------------------
# multi-fidelity mechanics
# ---------------------------------------------------------------------------

def test_multifid_prunes_under_load():
    """On a loaded trace the surrogate separates candidates, so the
    frontier is a strict subset of the candidate set."""
    model = ir_from_hf_config(
        dict(hidden_size=512, num_hidden_layers=8, num_attention_heads=8,
             num_key_value_heads=4, intermediate_size=2048,
             vocab_size=4096), name="tiny8")
    search = ApexSearch(model, h100_node(8))
    reqs = get_trace("summarization", arrival_rate=100.0, seed=0,
                     num_requests=40)
    mf = MultiFidelitySearch(search)
    mres = mf.search(reqs, feasible_only=True)
    assert mres.num_survivors < mres.num_candidates
    assert len(mres.surrogate_reports) == mres.num_candidates
    assert len(mres.result.all_reports) == mres.num_survivors
    assert mres.screen_seconds > 0 and mres.confirm_seconds > 0
    assert mres.surrogate_plans_per_sec > 0


def test_multifid_narrow_frontier_still_returns_feasible():
    search, reqs = _setup(n_req=16)
    mf = MultiFidelitySearch(search, frontier_k=1,
                             screen_objectives=["latency"], tie_rel=0.0)
    mres = mf.search(reqs, feasible_only=True)
    assert mres.best.feasible
    assert 1 <= mres.num_survivors <= mres.num_candidates


def test_multifid_rejects_unknown_screen_objective():
    search, _ = _setup()
    with pytest.raises(KeyError):
        MultiFidelitySearch(search, screen_objectives=["nope"])


def test_multifid_slo_band_widens_frontier():
    """With an SLO set, near-feasible candidates under the slackened
    band join the frontier."""
    search, reqs = _setup(n_req=16)
    mf = MultiFidelitySearch(search)
    base = mf.search(reqs, feasible_only=True)
    slo = mf.search(reqs, feasible_only=True,
                    slo_ttft_s=base.best.ttft_p95 * 4)
    assert slo.best.feasible
    assert slo.num_survivors >= 1
