"""Regression tests for three Batching Module correctness fixes.

1. Decode-role preemption used to re-materialize the victim's shipped
   prompt KV for free; re-admission now waits out a re-fetch delay.
2. ``peak_kv_tokens`` was never sampled inside fast-forwarded decode runs
   (and exact stepping sampled only AFTER completions released their KV),
   understating the reported peak.
3. ``_run_static`` crashed (``max()`` of an empty batch) when the head
   request's prompt alone exceeded KV capacity, and stamped ``gen_len==1``
   finishes at batch-drain instead of prefill end.

Hypothesis-free on purpose: these must run on the minimal dev install.
"""

import math

import pytest

from repro.core.batching import BatchingModule, BatchingPolicy
from repro.core.trace import Request


def const_cost(per_token=1e-3, per_iter=5e-3):
    def step_cost(w):
        t = per_iter + per_token * w.total_tokens
        return t, t * 100.0
    return step_cost


def mk_requests(specs):
    return [Request(rid=i, arrival=a, context_len=c, gen_len=g)
            for i, (a, c, g) in enumerate(specs)]


def _tpot_p95(res):
    ts = sorted((r.finish_time - r.first_token_time) / (r.gen_len - 1)
                for r in res.records if r.gen_len > 1)
    return ts[min(len(ts) - 1, int(math.ceil(0.95 * len(ts))) - 1)]


# ---------------------------------------------------------------------------
# 1. decode-pool preemption re-fetch is charged
# ---------------------------------------------------------------------------

def test_decode_refetch_charged_raises_tpot_p95():
    """A KV-constrained decode pool must pay for preemption re-fetches:
    TPOT p95 is strictly higher than the (buggy) free-re-fetch baseline."""
    # both admitted (2*201 + headroom = capacity), so decode growth
    # overflows immediately and preempts the most recent request; the
    # short request then drains the pool while the victim re-fetches
    reqs = mk_requests([(0.0, 200, 5), (0.0, 200, 60)])
    free = BatchingModule(404, BatchingPolicy(), role="decode",
                          refetch_delay=lambda r: 0.0).run(
        reqs, const_cost())
    paid = BatchingModule(404, BatchingPolicy(), role="decode").run(
        reqs, const_cost())
    assert free.preemptions > 0 and paid.preemptions > 0
    assert paid.kv_refetch_s > 0.0
    assert free.kv_refetch_s == 0.0
    assert _tpot_p95(paid) > _tpot_p95(free)
    # the charge is recorded on the victim, not spread over all requests
    victim = next(r for r in paid.records if r.preemptions > 0)
    assert victim.refetch_s == pytest.approx(paid.kv_refetch_s)


def test_decode_refetch_callback_is_authoritative():
    reqs = mk_requests([(0.0, 200, 5), (0.0, 200, 60)])
    res = BatchingModule(404, BatchingPolicy(), role="decode",
                         refetch_delay=lambda r: 0.25).run(
        reqs, const_cost())
    assert res.preemptions > 0
    assert res.kv_refetch_s == pytest.approx(0.25 * res.preemptions)


def test_decode_refetch_keeps_first_token_time():
    """Re-admission after preemption must NOT re-stamp the first token:
    it was already emitted before the victim was evicted."""
    reqs = mk_requests([(0.0, 200, 5), (0.0, 200, 60)])
    res = BatchingModule(404, BatchingPolicy(), role="decode").run(
        reqs, const_cost())
    victim = next(r for r in res.records if r.preemptions > 0)
    assert victim.first_token_time == 0.0  # admitted at t=0, never re-set


def test_colocated_preemption_unchanged():
    """role="both" already pays for preemption via prompt recompute; no
    re-fetch delay is charged there."""
    reqs = mk_requests([(0.0, 40, 60), (0.0, 40, 60), (0.0, 40, 60)])
    res = BatchingModule(102, BatchingPolicy()).run(reqs, const_cost())
    assert res.preemptions > 0
    assert res.kv_refetch_s == 0.0
    assert all(r.refetch_s == 0.0 for r in res.records)


# ---------------------------------------------------------------------------
# 2. fast-forward peak KV sampling
# ---------------------------------------------------------------------------

def test_fast_forward_peak_kv_matches_exact():
    """peak_kv_tokens must be sampled inside fast-forwarded decode runs:
    fast and exact stepping agree exactly on the peak."""
    reqs = mk_requests([(0.0, 20, 40), (0.5, 10, 80), (3.0, 30, 25)])
    fast = BatchingModule(10000, BatchingPolicy(fast_forward=True)).run(
        reqs, const_cost())
    slow = BatchingModule(10000, BatchingPolicy(fast_forward=False)).run(
        reqs, const_cost())
    assert fast.peak_kv_tokens == slow.peak_kv_tokens
    # the peak includes every request's final generated token (sampled
    # before completions release their KV): the single long-lived request
    # alone ends at 10 + 80 = 90 resident tokens
    assert slow.peak_kv_tokens >= 90


def test_fast_forward_peak_kv_decode_role():
    reqs = mk_requests([(0.0, 64, 50) for _ in range(4)])
    fast = BatchingModule(10000, BatchingPolicy(fast_forward=True),
                          role="decode").run(reqs, const_cost())
    slow = BatchingModule(10000, BatchingPolicy(fast_forward=False),
                          role="decode").run(reqs, const_cost())
    assert fast.peak_kv_tokens == slow.peak_kv_tokens
    assert fast.peak_kv_tokens == 4 * (64 + 50)   # analytic: all max out


# ---------------------------------------------------------------------------
# 3. static batching: oversized head prompt + gen_len==1 finish
# ---------------------------------------------------------------------------

def test_static_over_capacity_prompt_terminates():
    """A head prompt larger than KV capacity used to crash _run_static
    (max() of an empty batch); it must run solo and finish."""
    reqs = mk_requests([(0.0, 500, 3), (0.0, 10, 2)])
    res = BatchingModule(100, BatchingPolicy(
        mode="static", max_batch_size=4)).run(reqs, const_cost())
    assert len(res.records) == 2
    for r in res.records:
        assert r.finish_time >= r.first_token_time > 0.0
    # the oversized prompt really was admitted (solo) and overshot
    assert res.peak_kv_tokens >= 500


def test_static_gen1_finishes_at_prefill_end():
    reqs = mk_requests([(0.0, 10, 1), (0.0, 10, 40)])
    res = BatchingModule(10000, BatchingPolicy(
        mode="static", max_batch_size=4)).run(reqs, const_cost())
    short = next(r for r in res.records if r.gen_len == 1)
    long = next(r for r in res.records if r.gen_len == 40)
    # one shared prefill iteration, then the gen1 request is done; it must
    # not wait for the whole batch to drain
    assert short.finish_time == pytest.approx(short.first_token_time)
    assert short.finish_time < long.finish_time
