"""Loop-aware HLO accounting (launch/hlo_utils.py) vs hand-counted ops."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_utils import HloModule, analyze


def _flops_of(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return analyze(comp.as_text())["dot_flops"]


def test_single_matmul():
    x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    f = _flops_of(lambda a, b: a @ b, x, w)
    assert f == 2 * 64 * 32 * 16


def test_scan_multiplies_body():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a, b):
        def body(c, _):
            return c @ b, None
        y, _ = jax.lax.scan(body, a, None, length=12)
        return y

    assert _flops_of(f, x, w) == 12 * 2 * 64 ** 3


def test_nested_scans():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(a, b):
        def outer(c, _):
            def inner(ci, _):
                return ci @ b, None
            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None
        y, _ = jax.lax.scan(outer, a, None, length=3)
        return y

    assert _flops_of(f, x, w) == 15 * 2 * 32 ** 3


def test_raw_cost_analysis_undercounts():
    """Documents WHY the loop-aware analyzer exists."""
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a, b):
        def body(c, _):
            return c @ b, None
        y, _ = jax.lax.scan(body, a, None, length=10)
        return y

    comp = jax.jit(f).lower(x, w).compile()
    cost = comp.cost_analysis()
    if isinstance(cost, list):      # older jax returns [dict]
        cost = cost[0]
    raw = cost["flops"]
    corrected = analyze(comp.as_text())["dot_flops"]
    assert corrected >= 9 * raw * 0.9      # raw counts the body once


def test_collective_parsing_smoke():
    hlo = """
HloModule test

ENTRY %main (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  ROOT %all-reduce.1 = f32[16]{0} all-reduce(%p), to_apply=%add
}
"""
    mod = HloModule(hlo)
    out = mod.total_collective_bytes()
    assert out.get("all-reduce") == 16 * 4
