"""Property-based tests for Algorithm 1 (scheme generation) + mapper."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (assign_physical_ids, generate_schemes, h100_node,
                        ir_from_hf_config, map_scheme, tpu_v5e_pod)

CFG = dict(hidden_size=1024, num_hidden_layers=24, num_attention_heads=16,
           num_key_value_heads=8, intermediate_size=4096, vocab_size=32000)


def _model():
    return ir_from_hf_config(CFG, name="tiny")


@given(n=st.sampled_from([1, 2, 4, 8, 16, 32]))
@settings(max_examples=6, deadline=None)
def test_schemes_device_accounting(n):
    model = _model()
    for s in generate_schemes(model, n):
        # every scheme uses exactly n devices, evenly partitioned
        assert s.total_devices == n
        assert n % s.model_dp == 0
        assert (n // s.model_dp) % s.pp_stages == 0
        assert model.block.repeat % s.pp_stages == 0     # even layer split
        for cs in s.cell_schemes:
            assert cs.devices == s.stage_devices
            assert cs.valid()


@given(n=st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=4, deadline=None)
def test_feasible_subset(n):
    model = _model()
    all_s = generate_schemes(model, n)
    feas = generate_schemes(model, n, allow_cell_dp=False)
    feas_labels = {s.label() for s in feas if
                   s.is_feasible_for_current_systems()}
    all_labels = {s.label() for s in all_s}
    assert feas_labels <= all_labels
    assert len(all_labels) >= len(feas_labels)


@given(n=st.sampled_from([2, 4, 8]))
@settings(max_examples=3, deadline=None)
def test_weight_bytes_conservation(n):
    """Sum over devices of per-device weight bytes >= total model bytes
    (equality without replication; cell-DP / kv-replication inflate)."""
    from repro.core import get_format
    model = _model()
    q = get_format("fp16")
    total = model.weight_bytes(q)
    for s in generate_schemes(model, n)[:20]:
        per_dev = s.weight_bytes_per_device()
        assert per_dev * s.total_devices >= total * 0.5 / s.model_dp
        # model-DP replicates fully:
        assert per_dev * s.devices_per_replica >= \
            total * 0.45  # embeddings shared on boundary stages


def test_mapper_physical_ids_cover_and_nest():
    model = _model()
    schemes = [s for s in generate_schemes(model, 8)
               if s.model_dp == 2 and s.pp_stages == 2]
    s = schemes[0]
    ids = assign_physical_ids(s, h100_node(8))
    # replicas partition the device space
    flat = [d for grp in ids["replica"] for d in grp]
    assert sorted(flat) == list(range(8))
    # cell groups are contiguous and within one replica
    for grp in ids["cell"]:
        assert list(grp) == list(range(grp[0], grp[-1] + 1))
    # stage boundaries are adjacent id pairs
    for a, b in ids["stage_p2p"]:
        assert b == a + 1


def test_mapper_levels_prefer_low():
    model = _model()
    cluster = tpu_v5e_pod(256)
    s = [x for x in generate_schemes(model, 256)
         if x.model_dp == 16 and x.pp_stages == 1][0]
    plan = map_scheme(s, cluster)
    for g, cs in zip(plan.cell_groups, s.cell_schemes):
        lvl = cluster.level_for_group(g.span)
        if cs.shard <= 16:
            assert lvl.name == "ici-ring"   # TP fits in the fast domain
