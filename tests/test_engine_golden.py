"""Golden-equivalence + congestion properties of the event engine.

``tests/golden/core_golden.json`` freezes the reports the pre-refactor
per-replica loops (``BatchingModule._run_continuous``/``_run_static`` and
the coupled two-pool ``DisaggSimulator``) produced on the three paper
traces (captured at commit ef964aa by tests/golden/capture.py; the legacy
loops are gone, so the JSON cannot be regenerated — that is the point).

  * engine-backed colocated simulation must match the goldens EXACTLY
    (continuous, chunked-prefill, static, batch-capped; model-DP 1 and 2);
  * engine-backed homogeneous disagg with the engine couplings disabled
    (independent transfers, delay-only re-fetch) must match the goldens
    EXACTLY — the engine reproduces the independent-transfer model;
  * with the default couplings ON, congestion is monotone: a narrower
    cross-pool link never improves TTFT/TPOT p95, and an effectively
    infinite link reproduces the independent-transfer numbers.
"""

import dataclasses
import json
import os

import pytest

from repro.core import (CollectiveModel, NetworkLevel, ProfileStore,
                        generate_schemes, get_trace, h100_node, h200_node,
                        ir_from_hf_config, map_scheme)
from repro.core.batching import BatchingPolicy
from repro.core.profiles import AnalyticBackend
from repro.core.simulator import PlanSimulator
from repro.disagg import DisaggSimulator, generate_disagg_schemes, \
    map_disagg_scheme

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "core_golden.json")
SMALL = dict(hidden_size=256, num_hidden_layers=4, num_attention_heads=8,
             num_key_value_heads=4, intermediate_size=1024, vocab_size=1024)

POLICIES = {
    "continuous": BatchingPolicy(),
    "chunked": BatchingPolicy(chunked_prefill=128),
    "static": BatchingPolicy(mode="static", max_batch_size=8),
    "capped": BatchingPolicy(max_batch_size=4, fast_forward=False),
}


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def ctx():
    model = ir_from_hf_config(SMALL, name="tiny")
    cluster = h100_node(8)
    return model, cluster, ProfileStore(AnalyticBackend(cluster)), \
        CollectiveModel(cluster)


def _colocated_scheme(model, dp):
    for s in generate_schemes(model, 8, quant="fp16"):
        if (s.model_dp == dp and s.pp_stages == 1
                and s.is_feasible_for_current_systems()):
            return s
    raise RuntimeError("no scheme")


def _disagg_scheme(model, cluster, mode):
    for s in generate_disagg_schemes(model, cluster, max_plans=100000,
                                     transfer_mode=mode):
        if (s.prefill_devices == 4 and s.decode_devices == 4
                and s.prefill.model_dp == 1 and s.decode.model_dp == 1
                and s.prefill.pp_stages == 1 and s.decode.pp_stages == 1):
            return s
    raise RuntimeError("no disagg scheme")


def _assert_report_matches(rep, want):
    for field, expect in want.items():
        if field == "records":
            got = sorted((r.rid, r.first_token_time, r.finish_time,
                          r.preemptions, r.refetch_s) for r in rep.records)
            assert got == [tuple(r) for r in expect]
        else:
            assert getattr(rep, field) == expect, field


def test_colocated_reports_match_legacy_loop_exactly(golden, ctx):
    model, cluster, store, coll = ctx
    plans = {dp: map_scheme(_colocated_scheme(model, dp), cluster)
             for dp in (1, 2)}
    assert len(golden["colocated"]) == 24
    for case in golden["colocated"]:
        reqs = get_trace(case["trace"], arrival_rate=case["rate"], seed=11,
                         num_requests=48)
        sim = PlanSimulator(plans[case["dp"]], store, coll)
        rep = sim.simulate(reqs, policy=POLICIES[case["policy"]],
                           keep_records=True)
        _assert_report_matches(rep, case["report"])


def test_disagg_compat_reports_match_legacy_loop_exactly(golden, ctx):
    """Engine couplings OFF == the pre-engine independent-transfer +
    delay-only-re-fetch model, bit for bit."""
    model, cluster, store, coll = ctx
    assert len(golden["disagg"]) == 6
    for case in golden["disagg"]:
        scheme = _disagg_scheme(model, cluster, case["mode"])
        plan = map_disagg_scheme(scheme, cluster)
        reqs = get_trace(case["trace"], arrival_rate=case["rate"], seed=11,
                         num_requests=48)
        sim = DisaggSimulator(plan, store, coll)
        rep = sim.simulate(reqs, keep_records=True, congestion=False,
                           reprefill_occupancy=False)
        _assert_report_matches(rep, case["report"])


# ---------------------------------------------------------------------------
# SharedLink congestion properties
# ---------------------------------------------------------------------------

def _hetero_plan(model, bw):
    pre_c, dec_c = h100_node(4), h200_node(4)
    for s in generate_disagg_schemes(model, prefill_cluster=pre_c,
                                     decode_cluster=dec_c,
                                     max_plans=100000):
        if (s.prefill.model_dp == 1 and s.decode.model_dp == 1
                and s.prefill.pp_stages == 1 and s.decode.pp_stages == 1):
            link = NetworkLevel("xlink", 8, bw, 2e-6, launch_s=1e-5)
            return map_disagg_scheme(s, prefill_cluster=pre_c,
                                     decode_cluster=dec_c,
                                     cross_level=link), pre_c
    raise RuntimeError("no hetero scheme")


def _simulate_bw(model, reqs, bw, **kw):
    plan, pre_c = _hetero_plan(model, bw)
    sim = DisaggSimulator(plan, ProfileStore(AnalyticBackend(pre_c)),
                          CollectiveModel(pre_c))
    return sim.simulate(reqs, **kw)


def test_shared_link_fifo_monotone_in_service_time():
    """SharedLink invariant: scaling every transfer's wire time up (a
    narrower link) never completes any transfer earlier, and strictly
    queues once requests overlap."""
    from repro.core import SharedLink

    @dataclasses.dataclass
    class Est:
        wire_s: float
        delay_s: float

        @property
        def stream_lead_s(self):
            return max(0.0, self.wire_s - self.delay_s)

    finishes = [0.0, 0.1, 0.11, 0.12, 0.5, 0.51]
    for scale in (1.0, 4.0, 16.0):
        base = [Est(wire_s=0.08, delay_s=0.02)] * len(finishes)
        wide_link, narrow_link = SharedLink(), SharedLink()
        wide = [wide_link.transfer(t, e) for t, e in zip(finishes, base)]
        scaled = [Est(e.wire_s * scale, e.delay_s * scale) for e in base]
        narrow = [narrow_link.transfer(t, e)
                  for t, e in zip(finishes, scaled)]
        for w, n in zip(wide, narrow):
            assert n >= w - 1e-12
        if scale > 1.0:
            assert narrow_link.queued_s > wide_link.queued_s
    # independent mode never queues
    free = SharedLink(congestion=False)
    for t in finishes:
        free.transfer(t, Est(0.08, 0.02))
    assert free.queued_s == 0.0


def test_congestion_monotone_in_link_bandwidth():
    """Summarization (many large simultaneous KV handoffs): narrowing the
    shared wire monotonically queues more transfer time and never
    improves TTFT p95."""
    from repro.core import SharedLink
    model = ir_from_hf_config(SMALL, name="tiny")
    reqs = get_trace("summarization", arrival_rate=8.0, seed=5,
                     num_requests=32)
    bws = [1e13, 2e9, 2e8]          # effectively-infinite -> narrow
    links = [SharedLink() for _ in bws]
    reports = [_simulate_bw(model, reqs, bw, link=link)
               for bw, link in zip(bws, links)]
    for (wide, wl), (narrow, nl) in zip(zip(reports, links),
                                        zip(reports[1:], links[1:])):
        assert narrow.ttft_p95 >= wide.ttft_p95 - 1e-12
        assert nl.queued_s >= wl.queued_s - 1e-12
    # the narrow wire really queues, and the queueing reaches the
    # decode pool: strictly later drain than the uncontended regime
    assert links[-1].queued_s > links[0].queued_s
    assert reports[-1].e2e_latency > reports[0].e2e_latency


def test_infinite_link_reproduces_independent_transfers():
    """A wire fast enough never to queue makes the FIFO invisible: the
    default congestion model returns the independent per-request
    numbers exactly."""
    model = ir_from_hf_config(SMALL, name="tiny")
    reqs = get_trace("summarization", arrival_rate=8.0, seed=5,
                     num_requests=32)
    fifo = _simulate_bw(model, reqs, 1e13, reprefill_occupancy=False)
    indep = _simulate_bw(model, reqs, 1e13, reprefill_occupancy=False,
                         congestion=False)
    for field in ("e2e_latency", "ttft_p95", "tpot_p95", "total_energy",
                  "iterations", "preemptions"):
        assert getattr(fifo, field) == getattr(indep, field), field


def test_congestion_on_by_default_summarization_ttft():
    """Acceptance: with the engine couplings on by default, the
    summarization trace on a hetero pool pair (KV-tight decode pool over
    a narrow cross link) shows TTFT p95 strictly above the PR-2
    independent-transfer model: preempted decode victims re-occupy the
    prefill pool as real re-prefill jobs, delaying other prompts' first
    tokens, and their re-shipped caches queue on the shared wire.

    The model is large enough (16 layers) that prefill takes whole
    milliseconds and arrivals at 60 req/s keep the prefill pool loaded
    while the KV-tight decode pool preempts — so the re-prefills land in
    a busy queue and measurably push the TTFT tail."""
    model = ir_from_hf_config(
        dict(hidden_size=2048, num_hidden_layers=16,
             num_attention_heads=16, num_key_value_heads=8,
             intermediate_size=8192, vocab_size=32000), name="tiny-7b")
    reqs = get_trace("summarization", arrival_rate=60.0, seed=5,
                     num_requests=48)
    pre_c = h100_node(4)
    scheme = next(
        s for s in generate_disagg_schemes(
            model, prefill_cluster=pre_c, decode_cluster=h200_node(4),
            max_plans=100000)
        if s.prefill.model_dp == 1 and s.decode.model_dp == 1
        and s.prefill.pp_stages == 1 and s.decode.pp_stages == 1)
    # decode-pool HBM sized for ~6500 KV tokens: two summarization
    # prompts fit, decode growth overflows -> steady preemption pressure
    per_tok = scheme.decode.kv_bytes_per_token_per_device()
    need = (scheme.decode.weight_bytes_per_device()
            + scheme.decode.state_bytes_per_seq_per_device() * 512
            + 6500 * per_tok)
    kv_tight = dataclasses.replace(h200_node(4).device, name="H200-tight",
                                   hbm_bytes=need / 0.85)
    dec_c = dataclasses.replace(h200_node(4), device=kv_tight,
                                name="h200tight x4")
    link = NetworkLevel("xlink", 8, 2e9, 2e-6, launch_s=1e-5)
    plan = map_disagg_scheme(scheme, prefill_cluster=pre_c,
                             decode_cluster=dec_c, cross_level=link)
    sim = DisaggSimulator(plan, ProfileStore(AnalyticBackend(pre_c)),
                          CollectiveModel(pre_c))
    default = sim.simulate(reqs)
    legacy = sim.simulate(reqs, congestion=False,
                          reprefill_occupancy=False)
    assert default.feasible and legacy.feasible
    assert default.preemptions > 0
    assert default.ttft_p95 > legacy.ttft_p95
