"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp
oracles (the ref.py files)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import \
    flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.decode_attention.decode_attention import \
    decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.rmsnorm.rmsnorm import rms_norm_pallas
from repro.layers.norms import rms_norm


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (B, Sq, Skv, Hq, Hkv, D, window)
    (1, 64, 64, 4, 4, 32, None),       # MHA
    (2, 130, 130, 8, 2, 32, None),     # GQA + ragged length
    (1, 96, 96, 4, 2, 64, 37),         # sliding window
    (1, 257, 257, 2, 1, 16, None),     # odd lengths force padding
])
def test_flash_attention_sweep(shape, dtype):
    B, Sq, Skv, Hq, Hkv, D, window = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=64, block_kv=32, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (2, 8, 2, 64, 128),
    (3, 8, 8, 32, 300),                # MHA, non-multiple length
    (1, 16, 2, 64, 1024),
])
def test_decode_attention_sweep(shape, dtype):
    B, Hq, Hkv, D, Smax = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Smax, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Smax, Hkv, D), dtype)
    lens = jnp.asarray([(Smax * (i + 1)) // (B + 1) + 1 for i in range(B)])
    out = decode_attention_pallas(q, k, v, lens, block_kv=64,
                                  interpret=True)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("shape", [
    # (B, S, H, P, N, chunk)
    (2, 64, 4, 8, 16, 16),
    (1, 100, 2, 16, 32, 32),           # padding path
    (2, 33, 8, 4, 8, 8),
])
def test_ssd_scan_sweep(shape, dtype):
    B, S, H, P, N, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, H))
    b = jax.random.normal(ks[2], (B, S, N)) * 0.3
    c = jax.random.normal(ks[3], (B, S, N)) * 0.3
    out = ssd_scan_pallas(x, dt, a_log, b, c, chunk=chunk, interpret=True)
    ref = ssd_scan_ref(x, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 128), (3, 7, 256), (130, 64)])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(3), shape, dtype)
    w = jax.random.normal(jax.random.PRNGKey(4), (shape[-1],), jnp.float32)
    out = rms_norm_pallas(x, w, block_rows=32, interpret=True)
    ref = rms_norm(x, w)
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), **_tol(dtype))


def test_flash_vjp_matches_naive_grad():
    """The custom flash VJP must match autodiff of the oracle."""
    from repro.layers.attention import blockwise_attention
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    B, S, Hq, Hkv, D = 1, 70, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))

    def f_flash(q, k, v):
        return jnp.sum(jnp.tanh(blockwise_attention(
            q, k, v, causal=True, window=23, q_block=32, kv_block=16)))

    def f_ref(q, k, v):
        return jnp.sum(jnp.tanh(attention_ref(q, k, v, causal=True,
                                              window=23)))

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
