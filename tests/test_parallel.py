"""Multi-device parallel patterns (subprocess with 8 host devices):
pipeline parallelism, EP dispatch, sequence-parallel decode, elastic
resharding, plan->sharding translation."""

import pytest


def test_pipeline_matches_sequential(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import pipeline_forward, make_pp_mesh

n_stages, n_micro, mb, S, d = 4, 8, 2, 8, 16
mesh = make_pp_mesh(n_stages, tp=2)
rng = jax.random.PRNGKey(0)
w = jax.random.normal(rng, (n_stages, d, d)) * 0.3

def stage_fn(wi, x):
    return jnp.tanh(x @ wi)

x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, S, d))
out = pipeline_forward(lambda p, x: stage_fn(p, x), w, x, mesh, n_stages)

# sequential reference
ref = x
for i in range(n_stages):
    ref = jnp.tanh(ref @ w[i])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
print("pipeline OK")
""", devices=8)


def test_ep_matches_dense_oracle(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.layers.moe import init_moe, moe_forward
from repro.parallel.ep import moe_ep_forward

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = jax.random.PRNGKey(0)
d, f, E, k = 16, 32, 8, 2
params = init_moe(rng, d, f, E, k, dtype=jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d), jnp.float32)

dense = moe_forward(params, x, k)
ep, drop = moe_ep_forward(params, x, k, mesh, cap_factor=8.0)
assert float(drop) == 0.0, f"unexpected drops: {float(drop)}"
np.testing.assert_allclose(np.asarray(ep), np.asarray(dense), rtol=2e-4,
                           atol=2e-4)
print("ep OK")
""", devices=8)


def test_sp_decode_matches_ref(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.sp_decode import sp_decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref

mesh = jax.make_mesh((2, 4), ("data", "model"))
ks = jax.random.split(jax.random.PRNGKey(0), 3)
B, Hq, Hkv, D, Smax = 4, 8, 2, 16, 64
q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
k = jax.random.normal(ks[1], (B, Smax, Hkv, D), jnp.float32)
v = jax.random.normal(ks[2], (B, Smax, Hkv, D), jnp.float32)
lens = jnp.asarray([5, 17, 40, 64])
out = sp_decode_attention(q, k, v, lens, mesh)
ref = decode_attention_ref(q, k, v, lens)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                           atol=2e-4)
print("sp_decode OK")
""", devices=8)


def test_elastic_reshard_roundtrip(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.training.elastic import reshard_state

state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
         "b": jnp.ones((8,))}
specs = {"w": P("data", "model"), "b": P("model")}
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
mesh_b = jax.make_mesh((2, 4), ("data", "model"))   # "node failure" remesh
on_a = reshard_state(state, specs, mesh_a)
on_b = reshard_state(on_a, specs, mesh_b)
for k in state:
    np.testing.assert_array_equal(np.asarray(on_b[k]),
                                  np.asarray(state[k]))
print("elastic OK")
""", devices=8)


def test_plan_to_shardings(subproc):
    subproc("""
import jax, jax.numpy as jnp
from repro import configs as C
from repro.core import generate_schemes
from repro.models import transformer as T
from repro.parallel.plan_sharding import plan_to_shardings

cfg = C.get_reduced("internlm2_1_8b")
model_ir = cfg.to_ir()
schemes = generate_schemes(model_ir, 8)
params = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))

dp_tp = [s for s in schemes if s.model_dp == 2 and s.pp_stages == 1
         and s.is_feasible_for_current_systems()][0]
mat = plan_to_shardings(dp_tp, cfg, params)
assert not mat.needs_pipeline
assert mat.mesh.shape == {"data": 2, "model": 4}

pp = [s for s in schemes if s.pp_stages == 2 and s.model_dp == 1][0]
mat2 = plan_to_shardings(pp, cfg, params)
assert mat2.needs_pipeline and mat2.pp_stages == 2
print("plan_sharding OK")
""", devices=8)


def test_distributed_train_step_runs(subproc):
    """A REAL sharded train step executes on an 8-device host mesh and
    matches the single-device loss."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs as C
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.parallel.sharding import param_pspecs
from repro.training.optimizer import adamw_init

cfg = C.get_reduced("internlm2_1_8b")
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = T.init_params(jax.random.PRNGKey(0), cfg)
opt = adamw_init(params)
step = make_train_step(cfg, microbatches=1, remat=True)
batch = {"tokens": jnp.ones((4, 16), jnp.int32),
         "labels": jnp.ones((4, 16), jnp.int32)}

# single device
p1, o1, m1 = jax.jit(step)(params, opt, batch)

# sharded
pspecs = param_pspecs(params, cfg, mesh, fsdp=True)
sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda s: isinstance(s, P))
ps = jax.device_put(params, sh(pspecs))
ospecs = type(opt)(master=pspecs, m=pspecs, v=pspecs, step=P())
os_ = jax.device_put(opt, sh(ospecs))
bs = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
from repro.launch.mesh import mesh_context
with mesh_context(mesh):
    p2, o2, m2 = jax.jit(step, in_shardings=(sh(pspecs), sh(ospecs),
                         NamedSharding(mesh, P("data", None))))(ps, os_, bs)
assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2, \
    (float(m1["loss"]), float(m2["loss"]))
print("distributed train OK")
""", devices=8)
